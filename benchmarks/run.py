"""Benchmark harness: one module per paper table/figure.

  overhead       Table 1/3  runtime overhead of full-trace XFA
  events         Table 4    fold throughput (events/s)
  memory         Table 5    O(#edges) memory vs append logs
  effectiveness  Table 2    six injected bugs found from XFA views
  sampling       Table 6    sampling cannot close the gap
  offline        §4.3.2     offline analysis speed
  merge          (ours)     columnar shard-reduce vs per-edge loop merge
  roofline       §Roofline  (separate: python -m benchmarks.roofline)

Prints ``name,value,note`` CSV. Each module is also runnable standalone.
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (effectiveness, events, memory, merge, offline, overhead,
                   sampling)
    modules = [("overhead", overhead), ("events", events),
               ("memory", memory), ("effectiveness", effectiveness),
               ("sampling", sampling), ("offline", offline),
               ("merge", merge)]
    failures = 0
    print("name,value,note")
    for name, mod in modules:
        t0 = time.time()
        try:
            for row_name, val, note in mod.run():
                print(f"{row_name},{val:.3f},{note}")
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        print(f"{name}.elapsed_s,{time.time()-t0:.1f},")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
