"""Serving engine: continuous batching over a fixed slot pool.

vLLM-style iteration-level scheduling adapted to XLA's static shapes:
  * a fixed pool of `max_batch` slots, each owning one row of the batched
    KV cache (the cache pytree is [L, max_batch, ...] — slots never move,
    requests are assigned to free slots);
  * every engine tick runs ONE compiled decode_step over the whole pool
    (finished/empty slots are masked out of sampling — no recompilation as
    requests come and go);
  * prefill runs per-request (optionally chunked) into the slot's cache rows
    using dynamic_update_slice at the slot index.

Boundaries are XFA-instrumented ('serve'): queue wait, prefill, decode tick,
detokenize — the API view over 'serve' is the serving latency breakdown.
"""

from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.core import tracer as xfa
from repro.models.api import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 32
    submitted_at: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0                        # next cache position to write
    remaining: int = 0


class ServingEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig) -> None:
        self.model = model
        self.params = params
        self.scfg = scfg
        self.slots = [_Slot() for _ in range(scfg.max_batch)]
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.table = model.table()
        self.cache = model.init_cache(scfg.max_batch, scfg.max_seq_len)
        self._decode = jax.jit(model.decode_step, donate_argnums=(3,))
        self._uid = 0
        self.completed: List[Request] = []
        self._profile_store = None
        self._ticks = 0
        if scfg.profile_dir:
            from repro.profile import (ProfileStore, RetentionPolicy,
                                       register_run)
            self._profile_store = ProfileStore(
                scfg.profile_dir,
                retention=RetentionPolicy(
                    keep_last=scfg.profile_keep_last,
                    max_age_s=scfg.profile_max_age_s,
                    max_bytes=scfg.profile_max_bytes))
            # index this replica in the run registry so fleets of serving
            # runs are queryable (`repro.profile query --kind serve ...`)
            from repro.parallel.axes import get_runtime_mesh
            mesh = get_runtime_mesh()
            register_run(
                scfg.profile_dir,
                config=model.cfg.name, arch=model.cfg.family,
                mesh_shape=tuple(mesh.devices.shape)
                if mesh is not None else None,
                mesh_axes=tuple(mesh.axis_names)
                if mesh is not None else None,
                label=scfg.profile_label, kind="serve",
                meta={"max_batch": scfg.max_batch,
                      "max_seq_len": scfg.max_seq_len,
                      **dict(scfg.profile_meta)})

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens, submitted_at=time.monotonic())
        self.queue.put(req)
        return req

    # -- engine internals -----------------------------------------------------
    @xfa.api("serve", "prefill_request")
    def _admit(self, slot_idx: int, req: Request) -> None:
        """Prefill `req` into slot `slot_idx`'s cache rows, chunked."""
        model, scfg = self.model, self.scfg
        prompt = req.prompt[: scfg.max_seq_len - req.max_new_tokens - 1]
        # single-slot prefill: run the whole-prompt prefill at batch=1 and
        # scatter the resulting rows into the pool cache at slot_idx
        tiny_cache = model.init_cache(1, scfg.max_seq_len)
        batch = {"tokens": jnp.asarray(prompt[None])}
        logits, tiny_cache, self.table = model.prefill(
            self.params, batch, self.table, tiny_cache)
        self.cache = jax.tree.map(
            lambda pool, one: jax.lax.dynamic_update_slice(
                pool, one.astype(pool.dtype),
                (0, slot_idx) + (0,) * (pool.ndim - 2)),
            self.cache, tiny_cache)
        first = int(jnp.argmax(logits[0]))
        req.output.append(first)
        req.first_token_at = time.monotonic()
        slot = self.slots[slot_idx]
        slot.request = req
        slot.pos = len(prompt)
        slot.remaining = req.max_new_tokens - 1

    @xfa.api("serve", "decode_tick")
    def _tick(self) -> int:
        """One pooled decode step; returns #active slots."""
        active = [i for i, s in enumerate(self.slots) if s.request is not None]
        if not active:
            return 0
        tokens = np.zeros((self.scfg.max_batch,), np.int32)
        for i, s in enumerate(self.slots):
            if s.request is not None and s.request.output:
                tokens[i] = s.request.output[-1]
        # pool-wide position: slots decode at their own pos; the decode step
        # takes a single pos per call, so we tick the max and mask per-slot
        # validity through kv_len = slot.pos (cache rows beyond are zeros).
        pos = max(self.slots[i].pos for i in active)
        logits, self.cache, self.table = self._decode(
            self.params, jnp.asarray(tokens), self.table, self.cache,
            jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, -1))
        now = time.monotonic()
        for i in active:
            s = self.slots[i]
            tok = int(nxt[i])
            s.request.output.append(tok)
            s.pos += 1
            s.remaining -= 1
            if s.remaining <= 0 or tok == self.scfg.eos_token:
                s.request.done = True
                s.request.finished_at = now
                self.completed.append(s.request)
                self.slots[i] = _Slot()
        return len(active)

    @xfa.wait("serve", "queue_wait")
    def _poll(self) -> Optional[Request]:
        try:
            return self.queue.get_nowait()
        except queue.Empty:
            return None

    def write_profile_shard(self) -> None:
        """Refresh this replica's profile shard (host tracer folds)."""
        if self._profile_store is None:
            return
        from repro.profile import tracer_folded
        self._profile_store.write_shard(
            tracer_folded(), label=self.scfg.profile_label,
            meta={"ticks": self._ticks, "completed": len(self.completed)})

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Admit from the queue into free slots, tick until all done."""
        interval = self.scfg.profile_interval_ticks
        for _ in range(max_ticks):
            free = [i for i, s in enumerate(self.slots) if s.request is None]
            while free and not self.queue.empty():
                req = self._poll()
                if req is None:
                    break
                self._admit(free.pop(0), req)
            n = self._tick()
            self._ticks += 1
            if self._profile_store is not None and interval \
                    and self._ticks % interval == 0:
                self.write_profile_shard()
            if n == 0 and self.queue.empty():
                break
        self.write_profile_shard()
        return self.completed
