"""Production meshes. A FUNCTION, not a module constant — importing this
module never touches jax device state (required: the dry-run sets
XLA_FLAGS before any jax init; tests must see 1 device)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# v5e-class hardware constants for the roofline (per chip / per link)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link
