"""End-to-end CLI tests: `python -m repro.profile ...` as real OS processes.

Everything the README advertises is exercised the way an operator (or CI)
runs it — argv in, stdout/exit-code out: report, merge, diff (exit 1 on an
injected regression, 0 otherwise), query (exit 1 on no match), gc, and
timeline.  The fixtures build run dirs through the public writer API so
the subprocesses see exactly what trainers/serving replicas leave behind.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.core.folding import fold_event_log
from repro.profile import (ProfileSnapshot, ProfileStore, RetentionPolicy,
                           register_run)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

EVENTS = [
    ("app", "glibc", "read", 18), ("app", "glibc", "write", 35),
    ("app", "alloc", "malloc", 10), ("moe", "pthread", "lock", 900),
]


def run_cli(*args, timeout=120):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.profile", *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.fixture()
def registry(tmp_path):
    """Two registered runs: 'train' (3-deep ring, 4x2 mesh) + 'serve'."""
    train = tmp_path / "train"
    store = ProfileStore(str(train))
    for i in range(1, 4):
        store.write_shard(fold_event_log(EVENTS * i), label="train-r0",
                          meta={"step": i})
    register_run(str(train), config="tinyllama_1_1b", arch="dense",
                 mesh_shape="4x2", label="train-r0", kind="train")

    serve = tmp_path / "serve"
    ProfileStore(str(serve)).write_shard(fold_event_log(EVENTS),
                                         label="serve-0")
    register_run(str(serve), config="qwen3_14b", arch="dense",
                 mesh_shape=(8,), label="serve-0", kind="serve")
    return tmp_path


class TestReportMergeCLI:
    def test_report_renders_views(self, registry):
        p = run_cli("report", registry / "train")
        assert p.returncode == 0, p.stderr
        assert "Component view: app" in p.stdout
        assert "Flow matrix" in p.stdout

    def test_report_json(self, registry):
        p = run_cli("report", registry / "train", "--json")
        assert p.returncode == 0, p.stderr
        doc = json.loads(p.stdout)
        assert doc["meta"]["label"] == "train-r0"
        assert len(doc["edges"]) == len(fold_event_log(EVENTS))

    def test_merge_reduces_newest_per_shard(self, registry, tmp_path):
        out = tmp_path / "merged.xfa.npz"
        p = run_cli("merge", registry / "train", registry / "serve",
                    "-o", out)
        assert p.returncode == 0, p.stderr
        merged = ProfileSnapshot.load(str(out)).to_folded()
        # newest train ring entry (EVENTS*3) + the serve shard (EVENTS*1):
        # older ring entries must NOT be double-counted
        assert merged.edges[("app", "glibc", "read")].count == 4

    def test_report_missing_dir_fails(self, tmp_path):
        p = run_cli("report", tmp_path / "nope")
        assert p.returncode != 0


class TestDiffCLI:
    def test_exit_codes_gate_regressions(self, registry, tmp_path):
        base = tmp_path / "base.xfa.npz"
        slow = tmp_path / "slow.xfa.npz"
        t = fold_event_log(EVENTS)
        ProfileSnapshot.from_folded(t).save(str(base))
        t.edges[("app", "glibc", "write")].total_ns *= 3   # injected 3x
        ProfileSnapshot.from_folded(t).save(str(slow))

        clean = run_cli("diff", base, base, "--threshold", "0.5")
        assert clean.returncode == 0, clean.stderr
        assert "0 regressed" in clean.stdout

        hot = run_cli("diff", base, slow, "--threshold", "0.5")
        assert hot.returncode == 1, hot.stderr
        assert "REG" in hot.stdout and "glibc.write" in hot.stdout

    def test_diff_run_dir_uses_newest_snapshot(self, registry, tmp_path):
        """diff against a run DIR reduces it first — and a new ring entry
        with more folded work is a regression the gate catches."""
        base = tmp_path / "base.xfa.npz"
        ProfileSnapshot.from_folded(fold_event_log(EVENTS)).save(str(base))
        p = run_cli("diff", base, registry / "train", "--threshold", "0.5")
        assert p.returncode == 1   # newest ring entry folded EVENTS*3


class TestQueryCLI:
    def test_filters_and_exit_codes(self, registry):
        p = run_cli("query", registry, "--config", "tinyllama_1_1b",
                    "--mesh", "4x2", "--label", "train-*")
        assert p.returncode == 0, p.stderr
        assert "train" in p.stdout and "serve" not in p.stdout

        none = run_cli("query", registry, "--label", "nope")
        assert none.returncode == 1            # grep-like: no match -> 1
        assert none.stdout.strip() == ""

    def test_json_output_carries_manifest(self, registry):
        p = run_cli("query", registry, "--kind", "serve", "--json")
        assert p.returncode == 0, p.stderr
        [run] = json.loads(p.stdout)
        assert run["config"] == "qwen3_14b"
        assert run["mesh_shape"] == [8]
        assert run["run_dir"].endswith("serve")

    def test_where_predicate(self, registry):
        p = run_cli("query", registry, "--where", "arch=dense")
        assert p.returncode == 0
        assert len(p.stdout.strip().splitlines()) == 2

    def test_malformed_where_is_a_usage_error(self, registry):
        p = run_cli("query", registry, "--where", "archdense")
        assert p.returncode == 2               # argparse usage error
        assert "KEY=VALUE" in p.stderr


class TestGcCLI:
    def test_gc_enforces_keep_last_across_runs(self, registry):
        train_store = ProfileStore(str(registry / "train"))
        assert len(train_store.snapshot_paths()) == 3
        p = run_cli("gc", registry, "--keep-last", "1")
        assert p.returncode == 0, p.stderr
        assert "deleted 2 snapshot(s)" in p.stdout
        # newest ring entry + manifest survive; reduce still works
        assert len(train_store.snapshot_paths()) == 1
        assert os.path.exists(registry / "train" / "manifest.json")
        assert train_store.reduce().to_folded().edges[
            ("app", "glibc", "read")].count == 3

    def test_gc_dry_run_keeps_everything(self, registry):
        p = run_cli("gc", registry, "--keep-last", "1", "--dry-run",
                    "--json")
        assert p.returncode == 0, p.stderr
        doc = json.loads(p.stdout)
        assert doc["dry_run"] is True
        assert sum(len(v) for v in doc["deleted"].values()) == 2
        assert len(ProfileStore(str(registry / "train"))
                   .snapshot_paths()) == 3


class TestTimelineCLI:
    def test_renders_deltas_across_ring(self, registry):
        p = run_cli("timeline", registry / "train", "--field", "count")
        assert p.returncode == 0, p.stderr
        assert "3 snapshots" in p.stdout
        assert "app -> glibc.read" in p.stdout
        assert "+1" in p.stdout                # per-interval delta columns

    def test_json_and_empty_exit_code(self, registry, tmp_path):
        p = run_cli("timeline", registry / "train", "--json",
                    "--field", "count")
        assert p.returncode == 0, p.stderr
        [tl] = json.loads(p.stdout)
        assert tl["edges"]["app -> glibc.read"]["deltas"] == [1.0, 1.0, 1.0]
        # a dir with no multi-entry ring renders nothing -> exit 1
        empty = run_cli("timeline", tmp_path)
        assert empty.returncode == 1


class TestCIBaselineLane:
    """The non-blocking CI profile-diff lane, run here as a gating test:
    the synthetic workload must regenerate the checked-in baseline and
    diff clean; injected slowdowns/new edges must trip the gate."""

    BASELINE = os.path.join(os.path.dirname(__file__), "data",
                            "ci_baseline.xfa.npz")
    SCRIPT = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "baseline_profile.py")

    def _gen(self, out, *extra):
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.run(
            [sys.executable, self.SCRIPT, "-o", str(out), *extra],
            capture_output=True, text=True, timeout=120, env=env)

    def test_workload_reproduces_checked_in_baseline(self, tmp_path):
        cand = tmp_path / "cand.xfa.npz"
        p = self._gen(cand)
        assert p.returncode == 0, p.stderr
        with open(self.BASELINE, "rb") as a, open(cand, "rb") as b:
            assert a.read() == b.read(), \
                "baseline drifted: regenerate tests/data/ci_baseline" \
                ".xfa.npz deliberately (see benchmarks/baseline_profile.py)"
        d = run_cli("diff", self.BASELINE, cand, "--threshold", "0.25")
        assert d.returncode == 0, d.stdout + d.stderr

    def test_injected_regression_trips_the_lane(self, tmp_path):
        slow = tmp_path / "slow.xfa.npz"
        assert self._gen(slow, "--scale", "1.6").returncode == 0
        assert run_cli("diff", self.BASELINE, slow,
                       "--threshold", "0.25").returncode == 1
        new_edge = tmp_path / "new.xfa.npz"
        assert self._gen(new_edge, "--extra-edge").returncode == 0
        assert run_cli("diff", self.BASELINE, new_edge,
                       "--threshold", "0.25").returncode == 1


class TestCalibrateCLI:
    def test_runs_mode_writes_thresholds_json(self, tmp_path):
        from repro.analysis import Thresholds
        snaps = []
        for i in (1, 2, 3):
            p = tmp_path / f"run{i}.xfa.npz"
            ProfileSnapshot.from_folded(fold_event_log(EVENTS)).save(str(p))
            snaps.append(p)
        out = tmp_path / "thr.json"
        p = run_cli("calibrate", *snaps, "-o", out)
        assert p.returncode == 0, p.stderr
        assert "3 input(s)" in p.stdout
        thr = Thresholds.load(str(out))
        assert len(thr) == len(fold_event_log(EVENTS))
        assert thr.meta["mode"] == "runs"

    def test_ring_mode_and_empty_input_exit_code(self, registry, tmp_path):
        out = tmp_path / "thr.json"
        p = run_cli("calibrate", registry / "train", "-o", out,
                    "--mode", "ring")
        assert p.returncode == 0, p.stderr
        doc = json.loads(out.read_text())
        assert doc["meta"]["mode"] == "ring"
        empty = run_cli("calibrate", tmp_path / "nope", "-o", out,
                        "--mode", "ring")
        assert empty.returncode == 1


class TestDiffThresholdsCLI:
    """`diff --thresholds`: the calibrated profile-diff gate (the first
    concrete step toward flipping the CI lane to gating)."""

    BASELINE = os.path.join(os.path.dirname(__file__), "data",
                            "ci_baseline.xfa.npz")
    THRESHOLDS = os.path.join(os.path.dirname(__file__), "data",
                              "ci_thresholds.json")

    def _gen(self, out, *extra):
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        script = os.path.join(os.path.dirname(__file__), "..",
                              "benchmarks", "baseline_profile.py")
        return subprocess.run(
            [sys.executable, script, "-o", str(out), *extra],
            capture_output=True, text=True, timeout=120, env=env)

    def test_checked_in_thresholds_regenerate_identically(self, tmp_path):
        cand = tmp_path / "thr.json"
        p = self._gen(tmp_path / "b.xfa.npz", "--thresholds-out", cand)
        assert p.returncode == 0, p.stderr
        with open(self.THRESHOLDS) as a, open(cand) as b:
            assert json.load(a) == json.load(b), \
                "calibration drifted: regenerate tests/data/" \
                "ci_thresholds.json deliberately (see " \
                "benchmarks/baseline_profile.py --thresholds-out)"

    def test_seed_jitter_passes_injected_regression_fails(self, tmp_path):
        """A different seed of the same workload sits inside the measured
        bands; a 1.6x slowdown and a new edge do not."""
        other_seed = tmp_path / "s1.xfa.npz"
        assert self._gen(other_seed, "--seed", "1").returncode == 0
        ok = run_cli("diff", self.BASELINE, other_seed,
                     "--thresholds", self.THRESHOLDS)
        assert ok.returncode == 0, ok.stdout + ok.stderr
        assert "calibrated bands" in ok.stdout

        slow = tmp_path / "slow.xfa.npz"
        assert self._gen(slow, "--scale", "1.6").returncode == 0
        hot = run_cli("diff", self.BASELINE, slow,
                      "--thresholds", self.THRESHOLDS, "--json")
        assert hot.returncode == 1
        assert json.loads(hot.stdout)["calibrated"] is True

        new_edge = tmp_path / "new.xfa.npz"
        assert self._gen(new_edge, "--extra-edge").returncode == 0
        assert run_cli("diff", self.BASELINE, new_edge,
                       "--thresholds", self.THRESHOLDS).returncode == 1


class TestDiagnoseCLI:
    """`diagnose` as an OS process: text + JSON rendering and the
    --fail-on exit-code contract CI composes on."""

    def _bad_run(self, root):
        """Wait-dominated (crit) + hot-edge (warn at tuned default? no —
        95% share -> crit) pathology run dir."""
        from repro.core.folding import EdgeStats, FoldedTable
        t = FoldedTable({
            ("app", "runtime", "dispatch"): EdgeStats(
                count=100, total_ns=100_000_000, min_ns=1,
                max_ns=2_000_000),
            ("app", "runtime", "device_sync"): EdgeStats(
                count=100, total_ns=900_000_000, min_ns=1,
                max_ns=9_000_000, kind=1),
        })
        run = os.path.join(str(root), "bad")
        ProfileStore(run).write_shard(t, label="train-r0")
        register_run(run, config="cfg", kind="train", label="train-r0")
        return run

    def _good_run(self, root):
        run = os.path.join(str(root), "good")
        ProfileStore(run).write_shard(fold_event_log(EVENTS),
                                      label="train-r0")
        register_run(run, config="cfg", kind="train", label="train-r0")
        return run

    def test_default_reports_without_failing(self, tmp_path):
        run = self._bad_run(tmp_path)
        p = run_cli("diagnose", run)
        assert p.returncode == 0, p.stderr
        assert "wait-dominance" in p.stdout and "[CRIT]" in p.stdout

    def test_fail_on_exit_codes(self, tmp_path):
        run = self._bad_run(tmp_path)
        assert run_cli("diagnose", run, "--fail-on", "crit").returncode == 1
        assert run_cli("diagnose", run, "--fail-on", "warn").returncode == 1
        good = self._good_run(tmp_path)
        for level in ("warn", "crit"):
            p = run_cli("diagnose", good, "--fail-on", level)
            assert p.returncode == 0, p.stdout + p.stderr
        usage = run_cli("diagnose", run, "--fail-on", "nope")
        assert usage.returncode == 2           # argparse usage error

    def test_corrupt_thresholds_is_a_usage_error_not_a_finding(
            self, tmp_path):
        """Exit 1 is the --fail-on contract; a broken bands file must
        exit 2 with a message, never masquerade as a regression."""
        run = self._bad_run(tmp_path)
        bad = tmp_path / "bad.json"
        bad.write_text("{not json")
        p = run_cli("diagnose", run, "--thresholds", bad,
                    "--fail-on", "crit")
        assert p.returncode == 2, p.stdout + p.stderr
        assert "diagnose:" in p.stderr
        schema = tmp_path / "future.json"
        schema.write_text(json.dumps({"schema": 99}))
        p = run_cli("diagnose", run, "--thresholds", schema)
        assert p.returncode == 2
        assert "schema" in p.stderr

    def test_detector_config_tunes_and_rejects(self, tmp_path):
        """--detector-config is the no-code tuning surface: valid files
        change detector behavior; unknown detector names or parameters
        are usage errors (exit 2), per the CLI contract."""
        run = self._bad_run(tmp_path)
        # default: 90% wait share -> crit -> exit 1 under --fail-on crit
        assert run_cli("diagnose", run, "--fail-on", "crit").returncode == 1
        relaxed = tmp_path / "relaxed.json"
        relaxed.write_text(json.dumps(
            {"wait-dominance": {"warn_share": 0.95, "crit_share": 0.99}}))
        p = run_cli("diagnose", run, "--fail-on", "crit",
                    "--detector-config", relaxed)
        assert p.returncode == 0, p.stdout + p.stderr
        assert "detector-config" in p.stdout
        for bad in ({"wait-dominance": {"bogus": 1}},
                    {"not-a-detector": {"warn_share": 0.5}}):
            f = tmp_path / "bad.json"
            f.write_text(json.dumps(bad))
            p = run_cli("diagnose", run, "--detector-config", f)
            assert p.returncode == 2, p.stdout + p.stderr
            assert "diagnose:" in p.stderr
        notjson = tmp_path / "corrupt.json"
        notjson.write_text("{nope")
        assert run_cli("diagnose", run, "--detector-config",
                       notjson).returncode == 2

    def test_json_contract(self, tmp_path):
        run = self._bad_run(tmp_path)
        p = run_cli("diagnose", run, "--json", "--fail-on", "crit")
        assert p.returncode == 1
        doc = json.loads(p.stdout)
        assert doc["failed"] is True and doc["fail_on"] == "crit"
        assert doc["counts"]["crit"] >= 1
        [f] = [f for f in doc["findings"]
               if f["detector"] == "wait-dominance"]
        assert f["severity"] == "crit"
        assert f["evidence"]["top_wait_edge"] == \
            ["app", "runtime", "device_sync"]
        assert doc["manifest"]["config"] == "cfg"

    def test_registry_root_run_selection(self, tmp_path):
        self._bad_run(tmp_path)
        self._good_run(tmp_path)
        p = run_cli("diagnose", tmp_path, "--run", "good")
        assert p.returncode == 0, p.stderr
        assert "no findings" in p.stdout
        amb = run_cli("diagnose", tmp_path, "--run", "*d*")
        assert amb.returncode == 2
        assert "ambiguous" in amb.stderr
        missing = run_cli("diagnose", tmp_path / "void")
        assert missing.returncode == 2

    def test_baseline_flag_resolves_against_registry(self, tmp_path):
        bad = self._bad_run(tmp_path)
        self._good_run(tmp_path)
        p = run_cli("diagnose", bad, "--baseline",
                    os.path.join(str(tmp_path), "good"), "--json")
        assert p.returncode == 0, p.stderr
        assert json.loads(p.stdout)["baseline_dir"].endswith("good")


class TestMachineReadableSatellites:
    """timeline --json structured keys + gc --dry-run byte accounting."""

    def test_timeline_json_carries_structured_keys(self, registry):
        p = run_cli("timeline", registry / "train", "--json")
        assert p.returncode == 0, p.stderr
        [tl] = json.loads(p.stdout)
        e = tl["edges"]["moe -> pthread.lock"]
        assert e["key"] == ["moe", "pthread", "lock"]
        assert e["kind"] == "call"
        assert len(e["series"]) == len(tl["seqs"])

    def test_timeline_diff_json_carries_structured_keys(self, registry,
                                                        tmp_path):
        other = tmp_path / "other"
        store = ProfileStore(str(other))
        for i in range(1, 4):
            store.write_shard(fold_event_log(EVENTS * i), label="train-r0")
        p = run_cli("timeline", registry / "train", "--diff", other,
                    "--json")
        assert p.returncode == 0, p.stderr
        [td] = json.loads(p.stdout)
        e = td["edges"]["app -> glibc.read"]
        assert e["key"] == ["app", "glibc", "read"]
        assert e["kind"] == "call"
        assert len(e["delta_of_deltas"]) == td["aligned"]

    def test_gc_reports_bytes(self, registry):
        dry = run_cli("gc", registry, "--keep-last", "1", "--dry-run",
                      "--json")
        assert dry.returncode == 0, dry.stderr
        doc = json.loads(dry.stdout)
        victims = [e for v in doc["deleted"].values() for e in v]
        assert len(victims) == 2
        assert all(e["bytes"] > 0 for e in victims)
        assert doc["bytes"] == sum(e["bytes"] for e in victims)
        text = run_cli("gc", registry, "--keep-last", "1", "--dry-run")
        assert "KiB" in text.stdout and "would delete 2" in text.stdout


class TestWriterRetentionE2E:
    def test_concurrent_style_writers_stay_bounded(self, tmp_path):
        """Many refreshes through the public writer with a tight policy:
        the run dir footprint stays bounded and the newest fold wins."""
        store = ProfileStore(str(tmp_path),
                             retention=RetentionPolicy(keep_last=2))
        for i in range(1, 8):
            store.write_shard(fold_event_log(EVENTS * i), label="w")
        assert len(store.snapshot_paths()) == 2
        p = run_cli("report", tmp_path, "--json")
        assert p.returncode == 0, p.stderr
        doc = json.loads(p.stdout)
        read = [e for e in doc["edges"]
                if (e["caller"], e["component"], e["api"])
                == ("app", "glibc", "read")]
        assert read[0]["count"] == 7