"""Universal Shadow Table — the host-side slot store for Cross Flow Analysis.

Paper mapping (Scaler §3.2, Figure 2): every interceptable API, regardless of
how it is linked (.rela.plt / .rela.dyn / dlsym), maps to ONE fixed-size
*shadow entry* that carries everything the interceptor needs, so attribution
is O(1), allocation-free and uniform across API kinds.

TPU/JAX adaptation: the "APIs" are framework boundaries (host framework calls,
in-graph module applications, HLO collectives).  A shadow entry is a row in a
set of preallocated flat numpy arrays.  Slot resolution happens ONCE per
(caller-component, callee-component, api) edge — the analogue of lazy PLT
resolution — after which the hot path is two integer loads and a few adds,
with no hashing and no allocation (the paper explicitly rejects hash tables on
the hot path; we intern to dense ids instead).

Relation-awareness (Scaler §3.4): the slot key *includes the caller
component*, so the same callee API invoked from two different components folds
into two distinct slots.  That is exactly the paper's Relation-Aware Data
Folding invariant and is what keeps per-component views accurate.

Threading (Scaler §3.3): every thread owns its own ShadowTable (lock-free hot
path, no false sharing); the SlotRegistry is shared so slot ids agree across
threads, and per-thread tables are merged offline (views.py / folding.py).
"""

from __future__ import annotations

import threading
import weakref
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from .histogram import HIST_BUCKETS, bucket_index

# Slot kinds — 'wait' is separated per Scaler §3.5 ("Wait" pseudo-category:
# condvar/barrier/lock time means the program is not doing useful work).
KIND_CALL = 0
KIND_WAIT = 1
KIND_NAMES = {KIND_CALL: "call", KIND_WAIT: "wait"}

#: the component attributed when nothing is on the caller stack — the paper's
#: "application itself" island.
APP_COMPONENT = "app"

SlotKey = Tuple[str, str, str]  # (caller_component, callee_component, api)


def edge_label(key: SlotKey) -> str:
    """Canonical printable form of an edge key, 'caller -> comp.api'.

    THE one definition: timeline JSON keys, rendered tables, and the
    thresholds-JSON band index all use it — a divergence would silently
    orphan every saved calibration, so nobody re-spells this format."""
    caller, comp, api = key
    return f"{caller} -> {comp}.{api}"


@dataclass(frozen=True)
class SlotInfo:
    """Static metadata of one shadow entry (the paper's per-API struct)."""

    slot: int
    caller: str
    component: str
    api: str
    kind: int = KIND_CALL

    @property
    def key(self) -> SlotKey:
        return (self.caller, self.component, self.api)


class SlotRegistry:
    """Interns (caller, component, api) edges to dense slot ids.

    Shared across threads; the lock is taken only on FIRST resolution of an
    edge (the slow path — mirroring the dynamic linker resolving a PLT entry
    once).  Steady-state lookups go through a plain dict read, which is
    GIL-atomic in CPython; the returned id is then cached by the call site so
    even the dict read disappears from the hot path.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._by_key: Dict[SlotKey, SlotInfo] = {}
        self._infos: List[SlotInfo] = []

    def resolve(self, caller: str, component: str, api: str,
                kind: int = KIND_CALL) -> SlotInfo:
        key = (caller, component, api)
        info = self._by_key.get(key)
        if info is not None:
            return info
        with self._lock:
            info = self._by_key.get(key)
            if info is None:
                info = SlotInfo(len(self._infos), caller, component, api, kind)
                self._infos.append(info)
                self._by_key[key] = info
        return info

    def __len__(self) -> int:
        return len(self._infos)

    def info(self, slot: int) -> SlotInfo:
        return self._infos[slot]

    def infos(self) -> List[SlotInfo]:
        return list(self._infos)


class ShadowTable:
    """One thread's shadow entries: preallocated flat arrays, grown by doubling.

    Per-slot stats (the fold): count, total_ns, child_ns (time spent inside
    callees of this call — used to compute self time), min_ns, max_ns.
    ``record`` is the entire hot path: bounds check + 5 array updates.

    An optional ``hist`` block ([cap, HIST_BUCKETS] uint64 bucket counts,
    see core.histogram) is allocated lazily on the first ``record_hist``:
    call sites that never ask for distributions pay nothing, and the cost
    is bounded per slot, never per event.
    """

    __slots__ = ("count", "total_ns", "child_ns", "min_ns", "max_ns",
                 "hist", "_cap", "thread_name", "group", "group_explicit")

    INITIAL_CAPACITY = 256

    def __init__(self, thread_name: str = "main", group: str = "main",
                 capacity: int = INITIAL_CAPACITY) -> None:
        self._cap = int(capacity)
        self.thread_name = thread_name
        #: thread *group* (e.g. pipeline stage name) for imbalance analysis
        self.group = group
        #: True once the group was set deliberately (vs the thread-name
        #: default) — retired accumulators key on explicit groups only
        self.group_explicit = False
        self.count = np.zeros(self._cap, dtype=np.int64)
        self.total_ns = np.zeros(self._cap, dtype=np.int64)
        self.child_ns = np.zeros(self._cap, dtype=np.int64)
        self.min_ns = np.full(self._cap, np.iinfo(np.int64).max, dtype=np.int64)
        self.max_ns = np.zeros(self._cap, dtype=np.int64)
        #: lazily-allocated [cap, HIST_BUCKETS] uint64 block; None until the
        #: first record_hist keeps hist-less tables at the v1 footprint
        self.hist: Optional[np.ndarray] = None

    # -- hot path ---------------------------------------------------------
    def record(self, slot: int, dur_ns: int, child_ns: int = 0) -> None:
        if slot >= self._cap:
            self._grow(slot + 1)
        self.count[slot] += 1
        self.total_ns[slot] += dur_ns
        self.child_ns[slot] += child_ns
        if dur_ns < self.min_ns[slot]:
            self.min_ns[slot] = dur_ns
        if dur_ns > self.max_ns[slot]:
            self.max_ns[slot] = dur_ns

    def record_count(self, slot: int, n: int = 1) -> None:
        """Count-only fold (paper: counting is always on; timing is optional)."""
        if slot >= self._cap:
            self._grow(slot + 1)
        self.count[slot] += n

    def record_n(self, slot: int, dur_ns: int, n: int) -> None:
        """Fused fold of `n` events of `dur_ns` each — exactly equivalent
        to `n` calls of ``record(slot, dur_ns, 0)`` but O(1): the pooled
        serving tick attributes one tick across its active requests
        without a per-token python loop."""
        if n <= 0:
            return
        if slot >= self._cap:
            self._grow(slot + 1)
        self.count[slot] += n
        self.total_ns[slot] += n * dur_ns
        if dur_ns < self.min_ns[slot]:
            self.min_ns[slot] = dur_ns
        if dur_ns > self.max_ns[slot]:
            self.max_ns[slot] = dur_ns

    def record_scaled(self, slot: int, dur_ns: int, child_ns: int,
                      scale: int) -> None:
        """Fold one TIMED SAMPLE standing for `scale` calls (overhead
        governor, core.sampler): count moves by 1 — the other scale-1
        calls were already counted exactly by ``record_count`` — while
        total/child fold scaled by `scale` (the unbiased estimate of the
        untimed calls' contribution).  Extrema update from the RAW
        sample: min/max are observations, never estimates."""
        if slot >= self._cap:
            self._grow(slot + 1)
        self.count[slot] += 1
        self.total_ns[slot] += dur_ns * scale
        self.child_ns[slot] += child_ns * scale
        if dur_ns < self.min_ns[slot]:
            self.min_ns[slot] = dur_ns
        if dur_ns > self.max_ns[slot]:
            self.max_ns[slot] = dur_ns

    def record_hist(self, slot: int, dur_ns: int, n: int = 1) -> None:
        """Fold `n` events of one duration into the slot's latency
        histogram (n > 1: the fused pooled-tick fold, or a subsampled
        edge's bucket increment scaled by its stride).  Callers pair
        this with ``record``/``record_n`` (it does not touch
        count/total) — only durations belong here, never gauge
        samples."""
        if n <= 0:
            return
        if slot >= self._cap:
            self._grow(slot + 1)
        if self.hist is None:
            self.hist = np.zeros((self._cap, HIST_BUCKETS), dtype=np.uint64)
        self.hist[slot, bucket_index(dur_ns)] += n

    # -- slow paths -------------------------------------------------------
    def _grow(self, needed: int) -> None:
        new_cap = self._cap
        while new_cap < needed:
            new_cap *= 2
        for name in ("count", "total_ns", "child_ns", "max_ns"):
            arr = getattr(self, name)
            new = np.zeros(new_cap, dtype=np.int64)
            new[: self._cap] = arr
            setattr(self, name, new)
        new_min = np.full(new_cap, np.iinfo(np.int64).max, dtype=np.int64)
        new_min[: self._cap] = self.min_ns
        self.min_ns = new_min
        if self.hist is not None:
            new_hist = np.zeros((new_cap, HIST_BUCKETS), dtype=np.uint64)
            new_hist[: self._cap] = self.hist
            self.hist = new_hist
        self._cap = new_cap

    @property
    def capacity(self) -> int:
        return self._cap

    def nbytes(self) -> int:
        """Memory footprint — O(#slots), never O(#events) (paper Table 5)."""
        base = sum(getattr(self, n).nbytes
                   for n in ("count", "total_ns", "child_ns", "min_ns", "max_ns"))
        return base + (self.hist.nbytes if self.hist is not None else 0)

    def active_slots(self) -> np.ndarray:
        return np.nonzero(self.count[: self._cap])[0]

    def snapshot_copy(self) -> "ShadowTable":
        """Deep copy of the stats arrays (taken under the set's lock so a
        concurrent retire-sweep can't mutate data already handed out)."""
        t = ShadowTable(self.thread_name, self.group, capacity=self._cap)
        t.group_explicit = self.group_explicit
        t.count[:] = self.count
        t.total_ns[:] = self.total_ns
        t.child_ns[:] = self.child_ns
        t.min_ns[:] = self.min_ns
        t.max_ns[:] = self.max_ns
        if self.hist is not None:
            t.hist = self.hist.copy()
        return t

    def absorb(self, other: "ShadowTable") -> None:
        """Fold another table's slots into this one (sums + extrema).  Used
        to retire dead threads' tables: slot ids are registry-global, so the
        columns align and the merge is exact."""
        if other.capacity > self._cap:
            self._grow(other.capacity)
        n = other.capacity
        self.count[:n] += other.count
        self.total_ns[:n] += other.total_ns
        self.child_ns[:n] += other.child_ns
        np.minimum(self.min_ns[:n], other.min_ns, out=self.min_ns[:n])
        np.maximum(self.max_ns[:n], other.max_ns, out=self.max_ns[:n])
        if other.hist is not None:
            if self.hist is None:
                self.hist = np.zeros((self._cap, HIST_BUCKETS),
                                     dtype=np.uint64)
            self.hist[:n] += other.hist

    def reset(self) -> None:
        self.count[:] = 0
        self.total_ns[:] = 0
        self.child_ns[:] = 0
        self.min_ns[:] = np.iinfo(np.int64).max
        self.max_ns[:] = 0
        if self.hist is not None:
            self.hist[:] = 0


class ShadowTableSet:
    """All per-thread tables of one process + the shared registry.

    The paper persists each thread's data at thread exit and merges offline;
    we keep tables addressable here and let folding.py do the merge.  Tables
    for exited threads are retained (the paper's __cxa_thread_atexit handler
    keeps the data alive until the main thread persists it).
    """

    #: dead tables tolerated before a sweep folds them into the per-group
    #: retired accumulators (keeps short-lived-thread churn — e.g. one ckpt
    #: writer thread per save — from growing the table list without bound,
    #: while preserving per-thread granularity for small thread counts).
    RETIRE_SWEEP_THRESHOLD = 32

    def __init__(self) -> None:
        self.registry = SlotRegistry()
        # list, NOT a dict keyed on thread ident: CPython recycles `th.ident`
        # once a thread exits, so an ident-keyed map silently overwrites a
        # dead thread's table — losing its folds before the offline merge.
        self._live: List[Tuple[weakref.ref, ShadowTable]] = []
        self._retired: Dict[str, ShadowTable] = {}
        self._lock = threading.Lock()
        self._tls = threading.local()

    def table(self, group: Optional[str] = None) -> ShadowTable:
        t = getattr(self._tls, "table", None)
        if t is None:
            th = threading.current_thread()
            t = ShadowTable(thread_name=th.name, group=group or th.name)
            t.group_explicit = group is not None
            with self._lock:
                self._live.append((weakref.ref(th), t))
                if len(self._live) > self.RETIRE_SWEEP_THRESHOLD:
                    self._sweep_locked()
            self._tls.table = t
        elif group is not None:
            t.group = group
            t.group_explicit = True
        return t

    def _sweep_locked(self) -> None:
        """Fold dead threads' tables into per-group accumulators (the
        paper's persist-at-thread-exit, done lazily under the lock)."""
        live = []
        for ref, t in self._live:
            th = ref()
            if th is not None and th.is_alive():
                live.append((ref, t))
                continue
            # unnamed threads' default group is their (unique) thread name —
            # pool them, or uniquely-named churn would defeat the sweep
            key = t.group if t.group_explicit else "retired"
            acc = self._retired.get(key)
            if acc is None:
                acc = self._retired[key] = ShadowTable(
                    thread_name=f"retired:{key}", group=key)
            acc.absorb(t)
        self._live = live

    def tables(self) -> List[ShadowTable]:
        # retired accumulators are COPIED under the lock: a later sweep
        # absorbs dead live-tables into them in place, and a caller holding
        # both a dead table and a post-sweep accumulator would double-count
        with self._lock:
            return [t for _, t in self._live] + \
                [r.snapshot_copy() for r in self._retired.values()]

    def iter_edges(self) -> Iterator[Tuple[SlotInfo, ShadowTable]]:
        for t in self.tables():
            for slot in t.active_slots():
                yield self.registry.info(int(slot)), t

    def nbytes(self) -> int:
        return sum(t.nbytes() for t in self.tables())

    def reset(self) -> None:
        # operate on the real tables, not the copies tables() hands out
        with self._lock:
            for _, t in self._live:
                t.reset()
            for r in self._retired.values():
                r.reset()
