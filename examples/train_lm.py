"""End-to-end training driver: train a TinyLlama-family LM with the full
production stack — sharded step, checkpoint/restart, XFA profiling.

Defaults are CPU-sized; on a real pod pass --arch tinyllama_1_1b --full
and the same code path runs the published config under the mesh.

    PYTHONPATH=src python examples/train_lm.py --steps 100 --d-model 256
trains a ~10M model for a few hundred steps and prints the loss curve +
the XFA component view; use --steps 300 --d-model 512 for the ~100M run
(slower on CPU).
"""
import argparse
import dataclasses

import jax

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.configs.base import TrainConfig
from repro.core.session import XFASession
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="use the published config (pod-scale)")
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.full:
        cfg = get_config(args.arch)
    else:
        cfg = dataclasses.replace(
            get_smoke(args.arch), d_model=args.d_model,
            n_layers=args.layers, d_ff=args.d_model * 3,
            n_heads=max(4, args.d_model // 64),
            n_kv_heads=max(2, args.d_model // 128), vocab=8192)
    model = build_model(cfg, impl="auto")
    n = cfg.n_params()
    print(f"training {cfg.name}-derived LM: ~{n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    tcfg = TrainConfig(total_steps=args.steps, warmup_steps=args.steps // 10,
                       ckpt_interval=max(args.steps // 4, 1),
                       learning_rate=1e-3, microbatches=1)
    trainer = Trainer(model, tcfg, CheckpointManager(args.ckpt_dir,
                                                     async_save=True),
                      session=XFASession(device_spec=model.fold_spec))
    data = SyntheticLMData(cfg, args.batch, args.seq)
    state, metrics = trainer.run(jax.random.key(0), data, args.steps,
                                 resume=args.resume)
    print(f"final loss: {metrics.get('loss'):.4f} "
          f"(grad_norm {metrics.get('grad_norm'):.3f})")
    print(trainer.session.report().render(components=("app",)))


if __name__ == "__main__":
    main()
