"""qwen3-14b — dense LM with per-head qk RMSNorm, GQA kv=8 [hf:Qwen/Qwen3]."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
).validate()


def smoke():
    return reduced(CONFIG, qk_norm=True)
