"""Tour of the three XFA layers on one training step.

    PYTHONPATH=src python examples/xfa_tour.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.core import tracer as xfa
from repro.core.device_fold import STATIC_COSTS
from repro.core.hlo_analysis import analyze_module
from repro.core.session import KNOWN_COMPONENTS, XFASession
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.runtime.trainer import init_train_state, make_train_step


def main():
    cfg = get_smoke("phi3_5_moe_42b")    # MoE: live device-fold metrics
    model = build_model(cfg, impl="auto")
    tcfg = TrainConfig(microbatches=1)
    sess = XFASession(device_spec=model.fold_spec)

    STATIC_COSTS.reset()
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    state = init_train_state(model, jax.random.key(0), tcfg)
    data = SyntheticLMData(cfg, 4, 64)
    batch = {k: jnp.asarray(v) for k, v in data.generate(0).items()}
    table = model.table()

    # L1 host layer: bracketed dispatch
    import time
    lowered = step.lower(state, batch, table)
    compiled = lowered.compile()
    sess.snapshot_static()               # L3a: analytic costs from the trace
    t0 = time.perf_counter_ns()
    with xfa.scope("runtime", "dispatch_step"):
        state, metrics, table = compiled(state, batch, table)
    with xfa.scope("runtime", "device_sync", xfa.KIND_WAIT):
        jax.block_until_ready(metrics["loss"])
    sess.observe_step(time.perf_counter_ns() - t0)

    # L2 device layer: fetch the fold table once
    sess.finish_device(table)
    # L3b: collective flows from the compiled HLO
    sess.attach_hlo(compiled.as_text(), mesh_axes={})

    report = sess.report()
    print(report.render(components=("app", "runtime")))
    print()
    print(report.metric_view("expert_load[0]").render(max_rows=4))
    mc = analyze_module(compiled.as_text(), KNOWN_COMPONENTS, {})
    print(f"\nL3 loop-aware totals: {mc.flops:.2e} FLOPs, "
          f"{mc.io_bytes/2**20:.0f} MiB buffer IO, "
          f"{mc.n_collectives} collectives")


if __name__ == "__main__":
    main()
