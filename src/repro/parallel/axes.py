"""Ambient mesh + logical-axis environment for sharding constraints.

Model code never mentions concrete meshes; it calls `shard(x, *logical_axes)`
with logical names ('batch', 'seq', 'model', 'expert', ...). The launch layer
installs a concrete mesh + a logical->mesh translation once per run; on plain
CPU tests nothing is installed and `shard` is a no-op — the same model code
runs everywhere.

Logical axes:
  batch    data-parallel batch dim      -> ('pod', 'data') when present
  seq      sequence (context/SP dim)    -> 'data' for long-decode CP, or None
  model    tensor-parallel dim          -> 'model'
  expert   MoE expert dim               -> 'model' (EP shares the TP axis)
  kv_seq   KV-cache sequence dim        -> 'model' when heads unshardable
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

#: default logical->mesh translation; tuple = axis composition
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "model": ("model",),
    "expert": ("model",),
    "kv_seq": (),
    "vocab": ("model",),
}


def set_runtime_mesh(mesh: Optional[Mesh],
                     rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> None:
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))


def get_runtime_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def get_rules() -> Dict[str, Tuple[str, ...]]:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextmanager
def runtime_mesh(mesh: Optional[Mesh],
                 rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev_mesh = get_runtime_mesh()
    prev_rules = getattr(_state, "rules", None)
    set_runtime_mesh(mesh, rules)
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        if prev_rules is not None:
            _state.rules = prev_rules


def resolve_spec(*logical_axes: Optional[str]) -> P:
    """Translate logical axis names to a PartitionSpec under current rules,
    dropping mesh axes that do not exist in the installed mesh."""
    mesh = get_runtime_mesh()
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    rules = get_rules()
    parts = []
    for ax in logical_axes:
        if ax is None:
            parts.append(None)
            continue
        mapped = tuple(m for m in rules.get(ax, ()) if m in mesh_axes)
        if len(mapped) == 0:
            parts.append(None)
        elif len(mapped) == 1:
            parts.append(mapped[0])
        else:
            parts.append(mapped)
    return P(*parts)


def shard(x, *logical_axes: Optional[str]):
    """with_sharding_constraint against the ambient mesh; no-op without one."""
    mesh = get_runtime_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(*logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_dims(x, dim_axes: Dict[int, str]):
    """with_sharding_constraint mapping dim index -> logical axis, applying
    an axis ONLY when the dim size divides the mesh extent (GQA heads < TP,
    batch=1 long-decode, ... stay replicated instead of unevenly sharded).

    Use inside kernel-pattern scan bodies/carries: XLA's SPMD partitioner
    picks replicated for unconstrained while-loop carries and then re-gathers
    operands EVERY iteration (measured: a 16 GB all-gather per kv-block on
    deepseek MLA train — EXPERIMENTS.md §Perf)."""
    mesh = get_runtime_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = get_rules()
    parts: list = [None] * x.ndim
    used: set = set()
    for dim, logical in dim_axes.items():
        mapped = tuple(m for m in rules.get(logical, (logical,))
                       if m in sizes and m not in used)
        extent = 1
        for m in mapped:
            extent *= sizes[m]
        if mapped and extent > 1 and x.shape[dim] % extent == 0:
            parts[dim] = mapped[0] if len(mapped) == 1 else mapped
            used.update(mapped)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    mesh = get_runtime_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(*logical_axes))


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 without a mesh)."""
    mesh = get_runtime_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for m in get_rules().get(logical, ()):
        n *= sizes.get(m, 1)
    return n
