"""phi3.5-moe-42b-a6.6b — 16 experts top-2, GQA kv=8
[hf:microsoft/Phi-3.5-MoE-instruct]. All layers MoE, no shared experts."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi3.5-moe-42b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=0, vocab=32064, head_dim=128,
    moe=True, n_experts=16, top_k=2, n_shared_experts=0, moe_d_ff=6400,
    first_dense_layers=0,
).validate()


def smoke():
    return reduced(CONFIG, d_ff=0)
