"""Serving launcher: continuous-batching engine over a model checkpoint.

Closed-loop (default): submit --requests up front, drain synchronously —
a throughput benchmark.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --smoke --requests 8 [--ckpt artifacts/train]

Open-loop: Poisson arrivals at --rate req/s against the engine running
on its background thread — the latency-under-load benchmark (queue wait
and TTFT are only meaningful here).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --smoke --mode open --rate 4 --requests 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serving import ServingEngine, latency_stats, run_workload


def summarize(done, wall_s: float) -> str:
    s = latency_stats(done, wall_s)
    lines = [f"served {s['requests']:.0f} requests / {s['tokens']:.0f} "
             f"tokens in {s['wall_s']:.2f}s "
             f"({s['throughput_tok_s']:.1f} tok/s)"]
    if "ttft_mean_s" in s:
        lines.append(f"ttft       mean {s['ttft_mean_s'] * 1e3:.1f}ms  "
                     f"p50 {s['ttft_p50_s'] * 1e3:.1f}ms  "
                     f"p95 {s['ttft_p95_s'] * 1e3:.1f}ms")
    if "queue_wait_mean_s" in s:
        lines.append(f"queue_wait mean {s['queue_wait_mean_s'] * 1e3:.1f}ms  "
                     f"p95 {s['queue_wait_p95_s'] * 1e3:.1f}ms")
    if s["truncated"]:
        lines.append(f"truncated prompts: {s['truncated']:.0f}")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt", default="")
    # -- workload ------------------------------------------------------------
    ap.add_argument("--mode", choices=("closed", "open"), default="closed",
                    help="closed: submit all then drain (throughput); open: "
                         "Poisson arrivals on a live engine (latency)")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="open-loop mean arrival rate, requests/s")
    # -- scheduler -----------------------------------------------------------
    ap.add_argument("--prefill-chunk", type=int, default=512,
                    help="tokens per in-model prefill chunk: the admission "
                         "chunk and every continuation chunk of a longer "
                         "prompt run one positioned forward_chunk each")
    ap.add_argument("--tail-chunk", type=int, default=0,
                    help="continuation-chunk width (0: same as "
                         "--prefill-chunk; 1 reproduces the legacy "
                         "one-token-per-tick tail feed)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="per-tick prefill token budget across admission "
                         "and continuation chunks (0: unbounded)")
    ap.add_argument("--no-bucket-chunks", action="store_true",
                    help="disable power-of-two chunk-width bucketing "
                         "(every distinct prompt length compiles its own "
                         "prefill program)")
    ap.add_argument("--min-chunk-bucket", type=int, default=8,
                    help="smallest power-of-two chunk bucket")
    ap.add_argument("--prefill-batch", type=int, default=8,
                    help="max slots whose same-width prefill chunks batch "
                         "into ONE forward_chunk call per tick (capped at "
                         "--max-batch; 1 reproduces per-slot batch=1 "
                         "prefill)")
    # -- paged KV-cache pool -------------------------------------------------
    ap.add_argument("--max-cache-pages", type=int, default=0,
                    help="swap the contiguous [max_batch, max_seq] cache "
                         "for a paged arena of this many pages (0: off); "
                         "admission is then gated by free pages, not slot "
                         "count — page 0 is reserved scratch.  Transformer/"
                         "MLA families only; recurrent families keep their "
                         "dense O(1)-per-slot state")
    ap.add_argument("--page-size", type=int, default=64,
                    help="cache rows per page of the paged pool")
    # -- sampling ------------------------------------------------------------
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--sample-seed", type=int, default=0)
    # -- profiling -----------------------------------------------------------
    ap.add_argument("--profile-dir", default="",
                    help="write this replica's XFA profile shard here "
                         "(reduce with: python -m repro.profile report DIR)")
    ap.add_argument("--profile-interval", type=int, default=256,
                    help="decode ticks between shard refreshes")
    ap.add_argument("--profile-label", default="serve",
                    help="shard label; give replicas sharing a host "
                         "distinct labels (serve-0, serve-1, ...)")
    ap.add_argument("--profile-keep-last", type=int, default=8,
                    help="snapshots kept per shard ring (0: unbounded)")
    ap.add_argument("--profile-max-age-s", type=float, default=0.0,
                    help="delete ring snapshots older than this (0: never)")
    ap.add_argument("--profile-max-bytes", type=int, default=0,
                    help="per-run-dir snapshot byte budget (0: unbounded)")
    from repro.profile import kv_pair
    ap.add_argument("--profile-meta", action="append", default=[],
                    type=kv_pair, metavar="KEY=VALUE",
                    help="extra run-manifest metadata (repeatable)")
    ap.add_argument("--xfa-collector", default="", metavar="HOST:PORT",
                    help="stream snapshot-ring deltas to a fleet collector "
                         "(python -m repro.profile collect); failures "
                         "degrade to the local ring, never stall serving")
    ap.add_argument("--xfa-host-label", default="",
                    help="override this replica's host label in shard "
                         "names and manifests (default: hostname)")
    ap.add_argument("--xfa-budget-pct", type=float, default=0.0,
                    help="host-tracer overhead budget as a percent of wall "
                         "time (0: governor off, every boundary fully "
                         "timed); hot edges back off to 1-in-k timing "
                         "with unbiased scale-up, counting stays exact")
    args = ap.parse_args()

    if args.xfa_host_label:
        from repro.profile import set_host_label
        set_host_label(args.xfa_host_label)
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, impl="auto")
    if args.ckpt:
        like = jax.eval_shape(model.init, jax.random.key(0))
        mgr = CheckpointManager(args.ckpt)
        # restore params out of a full train state checkpoint
        import jax.numpy as jnp
        tree, _ = mgr.restore({"params": jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), like)})
        params = tree["params"]
    else:
        params = model.init(jax.random.key(0))

    engine = ServingEngine(model, params, ServeConfig(
        max_batch=args.max_batch, max_seq_len=args.max_seq,
        prefill_chunk=args.prefill_chunk,
        tail_chunk=args.tail_chunk,
        prefill_budget_tokens=args.prefill_budget,
        bucket_chunks=not args.no_bucket_chunks,
        min_chunk_bucket=args.min_chunk_bucket,
        prefill_batch=args.prefill_batch,
        page_size=args.page_size,
        max_cache_pages=args.max_cache_pages,
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        sample_seed=args.sample_seed,
        profile_dir=args.profile_dir,
        profile_interval_ticks=args.profile_interval,
        profile_label=args.profile_label,
        profile_keep_last=args.profile_keep_last,
        profile_max_age_s=args.profile_max_age_s,
        profile_max_bytes=args.profile_max_bytes,
        profile_meta=tuple(args.profile_meta),
        xfa_collector=args.xfa_collector,
        xfa_overhead_budget=args.xfa_budget_pct / 100.0))
    # sampling knobs ride in ServeConfig: submit() defaults to them
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab,
                            int(rng.integers(4, args.max_seq // 4)))
               for _ in range(args.requests)]
    t0 = time.monotonic()
    done = run_workload(engine, prompts, args.max_new, mode=args.mode,
                        rate=args.rate, rng=rng)
    print(summarize(done, time.monotonic() - t0))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
