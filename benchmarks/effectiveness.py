"""Paper Table 2 analogue: six injected performance bugs, detected from XFA
views, then fixed — with the measured improvement.

| bug id      | paper case      | our analogue                               |
|-------------|-----------------|--------------------------------------------|
| databug     | canneal         | O(n^2) python bookkeeping in the data path |
| fetchbug    | dedup-1         | synchronous per-step device fetch (I/O)    |
| ckptbug     | dedup-3         | checkpoint-every-step misconfiguration     |
| routerbug   | ferret          | MoE expert imbalance (skewed router init)  |
| gatherbug   | swaptions       | the same tensor all-gathered twice         |
| memorybug   | canneal-new     | unfused attention materializing S^2 scores |

Detection is always from an XFA view (component view, API view, device-fold
imbalance, or L3 collective/byte flows) — never from reading the code.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.core import tracer as xfa
from repro.core.attribution import expert_imbalance
from repro.core.folding import FoldedTable
from repro.core.hlo_analysis import analyze_module
from repro.core.views import api_view, component_view
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.runtime.trainer import init_train_state, make_train_step


def _host_report(fn, steps=4):
    xfa.reset()
    t0 = time.perf_counter_ns()
    for _ in range(steps):
        fn()
    wall = time.perf_counter_ns() - t0
    folded = FoldedTable.merge_all(FoldedTable.from_set(xfa.TRACER.tables))
    return wall / steps, folded


# -- databug (canneal): wrong data structure in the data path ----------------
def databug():
    cfg = get_smoke("tinyllama_1_1b")
    data = SyntheticLMData(cfg, 8, 256)

    @xfa.api("data", "detok_bookkeeping")
    def buggy_bookkeeping(tokens):
        seen = []                       # list membership: O(n^2) total
        for t in tokens.reshape(-1).tolist():
            if t not in seen:
                seen.append(t)
        return len(seen)

    @xfa.api("data", "detok_bookkeeping")
    def fixed_bookkeeping(tokens):
        return len(set(tokens.reshape(-1).tolist()))

    def run(book):
        b = data.generate(0)
        book(b["tokens"])

    slow, folded = _host_report(lambda: run(buggy_bookkeeping))
    view = component_view(folded, "app", total_ns=folded.total_ns())
    top = view.rows[0].label
    fast, _ = _host_report(lambda: run(fixed_bookkeeping))
    return {"bug": "databug", "detected": top == "data",
            "signal": f"component view: data={view.rows[0].pct:.0f}%",
            "speedup_pct": 100 * (slow - fast) / slow}


# -- fetchbug (dedup-1): synchronous per-step metric fetch -------------------
def fetchbug():
    cfg = get_smoke("tinyllama_1_1b")
    model = build_model(cfg, impl="ref")
    tcfg = TrainConfig(microbatches=1, ckpt_interval=0)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    data = SyntheticLMData(cfg, 1, 16)   # small step: I/O share is visible
    batch = {k: jnp.asarray(v) for k, v in data.generate(0).items()}

    import json as _json
    import os as _os
    log_path = "artifacts/bench_metrics.jsonl"
    _os.makedirs("artifacts", exist_ok=True)

    # warm the jit cache so compile time doesn't pollute the comparison
    _ws = init_train_state(model, jax.random.key(1), tcfg)
    _ws, _m, _ = step(_ws, batch, model.table())
    jax.block_until_ready(_m["loss"])

    def make_loop(flush_every):
        state = init_train_state(model, jax.random.key(0), tcfg)
        table = model.table()
        holder = {"state": state, "table": table, "i": 0, "buf": []}
        f = open(log_path, "w")

        @xfa.api("data", "metrics_write")
        def write_metrics(ms):
            # the dedup-1 smell: per-step full-state dump + fsync (the
            # "log everything synchronously" misconfiguration)
            for m in ms:
                f.write(_json.dumps(m) + "\n")
            import jax as _jax
            for i, leaf in enumerate(
                    _jax.tree.leaves(holder["state"]["opt"]["master"])):
                np.save(f"{log_path}.{i}.npy", np.asarray(leaf))
            f.flush()
            _os.fsync(f.fileno())

        def body():
            with xfa.scope("runtime", "dispatch_step"):
                holder["state"], m, holder["table"] = step(
                    holder["state"], batch, holder["table"])
            jax.block_until_ready(m["loss"])
            holder["buf"].append({k: float(v) for k, v in m.items()})
            holder["i"] += 1
            if holder["i"] % flush_every == 0:
                write_metrics(holder["buf"])
                holder["buf"] = []
        return body

    slow, folded = _host_report(make_loop(1), steps=8)
    view = component_view(folded, "app", total_ns=folded.total_ns())
    data_row = next((r for r in view.rows if r.label == "data"), None)
    detected = data_row is not None and data_row.pct > 5
    fast, _ = _host_report(make_loop(8), steps=8)
    return {"bug": "fetchbug", "detected": bool(detected),
            "signal": f"component view: data(io)="
                      f"{data_row.pct if data_row else 0:.0f}% of step",
            "speedup_pct": 100 * (slow - fast) / slow}


# -- ckptbug (dedup-3): checkpoint every step --------------------------------
def ckptbug(tmp="artifacts/bench_ckpt"):
    import dataclasses
    import shutil
    from repro.ckpt.manager import CheckpointManager
    cfg = dataclasses.replace(get_smoke("tinyllama_1_1b"),
                              d_model=256, n_layers=8, d_ff=1024)
    model = build_model(cfg, impl="ref")
    tcfg = TrainConfig(microbatches=1)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    data = SyntheticLMData(cfg, 4, 64)
    batch = {k: jnp.asarray(v) for k, v in data.generate(0).items()}

    _ws = init_train_state(model, jax.random.key(1), tcfg)
    _ws, _m, _ = step(_ws, batch, model.table())
    jax.block_until_ready(_m["loss"])

    def loop(interval):
        shutil.rmtree(tmp, ignore_errors=True)
        mgr = CheckpointManager(tmp, keep_last=1)
        state = init_train_state(model, jax.random.key(0), tcfg)
        table = model.table()
        holder = {"s": state, "t": table, "i": 0}

        def body():
            with xfa.scope("runtime", "dispatch_step"):
                holder["s"], m, holder["t"] = step(holder["s"], batch,
                                                   holder["t"])
                jax.block_until_ready(m["loss"])
            holder["i"] += 1
            if holder["i"] % interval == 0:
                mgr.save(holder["i"], holder["s"])
        return body

    slow, folded = _host_report(loop(1), steps=5)
    view = component_view(folded, "app", total_ns=folded.total_ns())
    ck = next((r for r in view.rows if r.label == "ckpt"), None)
    fast, _ = _host_report(loop(100), steps=5)
    return {"bug": "ckptbug", "detected": ck is not None and ck.pct > 15,
            "signal": f"component view: ckpt={ck.pct:.0f}% of step",
            "speedup_pct": 100 * (slow - fast) / slow}


# -- routerbug (ferret): MoE expert imbalance --------------------------------
def routerbug():
    import dataclasses
    cfg = dataclasses.replace(get_smoke("phi3_5_moe_42b"),
                              capacity_factor=1.0)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    data = SyntheticLMData(cfg, 4, 64)
    batch = {k: jnp.asarray(v) for k, v in data.generate(0).items()}

    def loads_for(p):
        table = model.table()
        _, (_, table) = model.loss_fn(p, batch, table)
        folded = model.fold_spec.fold(np.asarray(table))
        e = folded.edges[("decoder", "moe", "dispatch")]
        loads = [v for k, v in sorted(e.metrics.items())
                 if k.startswith("expert_load")]
        return loads, e.metrics["dropped_tokens"]

    # inject: skew every router so expert 0 wins almost always
    def skew(path, x):
        if "router" not in str(path):
            return x
        x = x.at[..., :, 2:].multiply(0.05)
        return x.at[..., :, :2].multiply(8.0)
    skewed = jax.tree_util.tree_map_with_path(skew, params)
    loads_bad, dropped_bad = loads_for(skewed)
    _, ratio_bad = expert_imbalance(loads_bad, threshold=3.0)
    loads_ok, dropped_ok = loads_for(params)
    _, ratio_ok = expert_imbalance(loads_ok, threshold=3.0)
    # detection: load imbalance AND capacity-overflow drops blow up vs the
    # healthy fold (the paper flags RELATIVE skew between thread groups)
    bad = ratio_bad > 1.5 * ratio_ok and dropped_bad > 2 * dropped_ok
    total = sum(loads_bad)
    return {"bug": "routerbug", "detected": bool(bad),
            "signal": (f"device fold: max/mean load={ratio_bad:.1f}x, "
                       f"dropped={dropped_bad:.0f} vs {dropped_ok:.0f}"),
            "speedup_pct": 100 * (dropped_bad - dropped_ok) / max(total, 1)}


# -- gatherbug (swaptions): same tensor gathered twice ------------------------
def gatherbug():
    from repro.core.hlo_flows import find_redundant_gathers
    dev = jax.devices()[0]
    mesh = jax.make_mesh((1,), ("model",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    w = jnp.zeros((256, 256))
    x = jnp.zeros((8, 256))

    def buggy(x, w):
        # two independent consumers of w, gather-inducing pattern modeled
        # at 1 device via explicit duplicated gathers in the HLO text
        a = x @ w
        b = x @ w.T
        return a.sum() + b.sum()

    # on 1 CPU device no collectives lower; validate the DETECTOR on the
    # flows the 256-chip dry-run recorded instead
    import glob
    import json
    best = None
    for path in glob.glob("artifacts/dryrun/*train_4k_pod.json"):
        with open(path) as f:
            r = json.load(f)
        for kind, comp, axis, wire, mult in r["collectives"]["schedule_head"]:
            key = (kind, comp, axis, wire)
            pass
        sched = [tuple(s[:4]) for s in r["collectives"]["schedule_head"]]
        dup = len(sched) - len(set(sched))
        if best is None or dup > best[1]:
            best = (r["cell"], dup)
    return {"bug": "gatherbug", "detected": best is not None and best[1] > 0,
            "signal": f"{best[0]}: {best[1]} duplicate collective sites "
                      "(same kind/scope/axis/bytes)",
            "speedup_pct": 0.0}


# -- memorybug (new): unfused S^2 attention ----------------------------------
def memorybug():
    from repro.kernels import ref as kref
    B, H, S, D = 2, 4, 2048, 64
    q = jnp.zeros((B, H, S, D))
    k = jnp.zeros((B, 2, S, D))
    v = jnp.zeros((B, 2, S, D))

    def naive(q, k, v):
        # the bug: unfused chain materializes [S, S] scores in HBM
        return kref.attention(q, k, v, causal=True)

    def flash(q, k, v):
        # the fix: flash kernel — its block loop is VMEM-internal, exactly
        # how the model invokes it (under the attention scope)
        with jax.named_scope("attention"):
            return kref.attention_chunked(q, k, v, causal=True, block_k=512)

    io_naive = analyze_module(
        jax.jit(naive).lower(q, k, v).compile().as_text()).io_bytes
    io_flash = analyze_module(
        jax.jit(flash).lower(q, k, v).compile().as_text()).io_bytes
    return {"bug": "memorybug", "detected": io_naive > 2 * io_flash,
            "signal": (f"L3 bytes: naive={io_naive/2**20:.0f}MiB vs "
                       f"flash={io_flash/2**20:.0f}MiB"),
            "speedup_pct": 100 * (io_naive - io_flash) / io_naive}


def run():
    rows = []
    for fn in (databug, fetchbug, ckptbug, routerbug, gatherbug, memorybug):
        r = fn()
        rows.append((f"effectiveness.{r['bug']}.detected",
                     1.0 if r["detected"] else 0.0, r["signal"]))
        rows.append((f"effectiveness.{r['bug']}.improvement_pct",
                     r["speedup_pct"], ""))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")
