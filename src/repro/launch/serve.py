"""Serving launcher: continuous-batching engine over a model checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --smoke --requests 8 [--ckpt artifacts/train]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--profile-dir", default="",
                    help="write this replica's XFA profile shard here "
                         "(reduce with: python -m repro.profile report DIR)")
    ap.add_argument("--profile-interval", type=int, default=256,
                    help="decode ticks between shard refreshes")
    ap.add_argument("--profile-label", default="serve",
                    help="shard label; give replicas sharing a host "
                         "distinct labels (serve-0, serve-1, ...)")
    ap.add_argument("--profile-keep-last", type=int, default=8,
                    help="snapshots kept per shard ring (0: unbounded)")
    ap.add_argument("--profile-max-age-s", type=float, default=0.0,
                    help="delete ring snapshots older than this (0: never)")
    ap.add_argument("--profile-max-bytes", type=int, default=0,
                    help="per-run-dir snapshot byte budget (0: unbounded)")
    from repro.profile import kv_pair
    ap.add_argument("--profile-meta", action="append", default=[],
                    type=kv_pair, metavar="KEY=VALUE",
                    help="extra run-manifest metadata (repeatable)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, impl="auto")
    if args.ckpt:
        like = jax.eval_shape(model.init, jax.random.key(0))
        state_like = {"params": like}
        mgr = CheckpointManager(args.ckpt)
        # restore params out of a full train state checkpoint
        import jax.numpy as jnp
        tree, _ = mgr.restore({"params": jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), like)})
        params = tree["params"]
    else:
        params = model.init(jax.random.key(0))

    engine = ServingEngine(model, params,
                           ServeConfig(max_batch=args.max_batch,
                                       max_seq_len=args.max_seq,
                                       profile_dir=args.profile_dir,
                                       profile_interval_ticks=args.profile_interval,
                                       profile_label=args.profile_label,
                                       profile_keep_last=args.profile_keep_last,
                                       profile_max_age_s=args.profile_max_age_s,
                                       profile_max_bytes=args.profile_max_bytes,
                                       profile_meta=tuple(args.profile_meta)))
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        n = int(rng.integers(4, args.max_seq // 4))
        engine.submit(rng.integers(0, cfg.vocab, n), args.max_new)
    t0 = time.monotonic()
    done = engine.run_until_drained()
    dt = time.monotonic() - t0
    tok = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
