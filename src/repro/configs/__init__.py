"""Architecture registry: one module per assigned arch, exact published dims.

Each module exports CONFIG (full config, dry-run only) and smoke() (reduced
same-family variant instantiable on CPU). get_config(name) / list_archs() are
the public API used by --arch flags across launch/, benchmarks/ and tests/.
"""
from importlib import import_module

from .base import (MeshConfig, ModelConfig, ServeConfig, ShapeConfig, SHAPES,
                   SMOKE_SHAPES, TrainConfig, reduced)

ARCHS = (
    "granite_20b",
    "starcoder2_7b",
    "qwen3_14b",
    "tinyllama_1_1b",
    "zamba2_2_7b",
    "deepseek_v2_lite_16b",
    "phi3_5_moe_42b",
    "xlstm_1_3b",
    "internvl2_1b",
    "seamless_m4t_large_v2",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def list_archs():
    return list(ARCHS)


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_")
    mod = import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_")
    mod = import_module(f"repro.configs.{mod_name}")
    return mod.smoke()
