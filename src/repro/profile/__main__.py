"""CLI for the profile store.

    python -m repro.profile report    RUN_DIR_OR_SNAPSHOT... [--component app]
    python -m repro.profile merge     SHARD_OR_DIR... -o merged.xfa.npz
    python -m repro.profile diff      BASELINE CANDIDATE [--threshold 0.25]
                                      [--thresholds bands.json]
    python -m repro.profile query     ROOT [--config C] [--mesh 4x2]
    python -m repro.profile gc        ROOT... [--keep-last N] [--dry-run]
    python -m repro.profile timeline  RUN_DIR [--field total_ns] [--shard S]
    python -m repro.profile calibrate INPUT... -o bands.json [--mode ring]
    python -m repro.profile diagnose  ROOT [--run GLOB] [--baseline B]
                                      [--thresholds T] [--detector-config C]
                                      [--fail-on warn|crit]
                                      [--fleet [--config GLOB]]
    python -m repro.profile collect   --spool DIR [--bind H] [--port P]
                                      [--max-seconds S]

`report` reduces every given shard/dir into one profile and renders the
paper's component/API views + flow matrix.  `merge` persists that reduction.
`diff` compares two profiles and exits 1 when any per-edge regression
exceeds its threshold (global, or per-edge calibrated bands via
`--thresholds`) — wire it into CI as a perf gate.  `query` filters the
run registry by metadata predicates (exit 1 when nothing matches, so it
composes in shell pipelines).  `gc` applies a retention policy offline;
`timeline` renders per-edge count/total_ns/self_ns trajectories across
one run's sequence-numbered snapshots.  `calibrate` fits per-edge noise
bands from baseline profiles (or ring intervals) into a thresholds JSON;
`diagnose` runs the cross-flow detectors (repro.analysis) over a run and
exits 1 when findings reach `--fail-on` severity; `--detector-config`
loads per-detector constructor parameters from JSON so projects tune
thresholds without code (unknown keys exit 2); `diagnose --fleet`
diagnoses every run matching `--config`/`--run`, adds cross-host
fleet-straggler and cross-run outlier findings, and ranks the union.
`collect` runs the fleet collector daemon: publishers (trainers/servers
launched with `--xfa-collector HOST:PORT`) stream snapshot-ring deltas
to it and it spools them under `SPOOL/<run_id>/<host>/` — a registry
root the other subcommands read directly (see docs/fleet.md).

Full reference with flag tables, worked examples and the exit-code
contract (0 ok / 1 gated finding / 2 usage error): docs/cli.md —
kept honest by tools/check_cli_docs.py in CI.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List

from ..core.views import (api_view_by_caller, component_view,
                          render_flow_matrix, render_percentiles,
                          render_sampling)
from .diff import DIFF_FIELDS, diff_profiles
from .index import RunRegistry, kv_pair
from .snapshot import ProfileSnapshot
from .store import (ProfileStore, RetentionPolicy, find_run_dirs,
                    load_profile)
from .timeline import (TIMELINE_FIELDS, build_timelines, pair_timelines,
                       render_timeline, render_timeline_diff)


def _load_many(paths: List[str]) -> ProfileSnapshot:
    snaps = [load_profile(p) for p in paths]
    return snaps[0] if len(snaps) == 1 else ProfileSnapshot.merge(snaps)


def _cmd_report(args: argparse.Namespace) -> int:
    snap = _load_many(args.inputs)
    folded = snap.to_folded()
    if args.json:
        print(json.dumps({"meta": snap.meta, **folded.to_json()}, indent=1))
        return 0
    total = folded.total_ns()
    print(f"profile: {len(folded)} edges, {total/1e9:.3f}s folded total, "
          f"group={folded.group!r}")
    if snap.meta:
        print(f"meta: {json.dumps(snap.meta, sort_keys=True)}")
    for comp in args.component:
        print()
        print(component_view(folded, comp).render(args.top))
        print()
        print(api_view_by_caller(folded, comp).render(args.top))
    pct = render_percentiles(folded, max_rows=args.top)
    if pct:   # only schema-v2+ profiles carry histograms
        print()
        print(pct)
    smp = render_sampling(folded, max_rows=args.top)
    if smp:   # only schema-v3 profiles carry governor sampling rates
        print()
        print(smp)
    print()
    print(render_flow_matrix(folded))
    return 0


def _cmd_merge(args: argparse.Namespace) -> int:
    merged = _load_many(args.inputs)
    # mark the output as a merge product even for a single input, so a
    # store reduce over a dir containing it knows to skip it
    merged.meta.setdefault("merged_from",
                           [str(merged.meta.get("label", "?"))])
    merged.save(args.output)
    print(f"merged {len(args.inputs)} input(s), {len(merged)} edges "
          f"-> {args.output}")
    return 0


def _cmd_diff(args: argparse.Namespace) -> int:
    base = load_profile(args.baseline).to_folded()
    cand = load_profile(args.candidate).to_folded()
    bands = None
    if args.thresholds:
        from ..analysis import Thresholds
        bands = Thresholds.load(args.thresholds)
    d = diff_profiles(base, cand, threshold=args.threshold,
                      fields=tuple(args.fields.split(",")),
                      min_count=args.min_count,
                      min_total_ns=args.min_total_ns,
                      flag_added=not args.no_flag_added,
                      thresholds=bands)
    if args.json:
        print(json.dumps(d.to_json(), indent=1))
    else:
        print(d.render())
    return 1 if d.has_regressions else 0


def _cmd_query(args: argparse.Namespace) -> int:
    where = dict(args.where)
    since = None
    if args.max_age_s:
        import time
        since = time.time() - args.max_age_s
    runs = RunRegistry(args.root).query(
        config=args.config, arch=args.arch, mesh=args.mesh or None,
        label=args.label, kind=args.kind, since=since, where=where)
    if args.json:
        print(json.dumps([{**m.to_json(), "run_dir": m.run_dir}
                          for m in runs], indent=1))
    else:
        for m in runs:
            line = m.describe()
            if args.verbose:
                store = ProfileStore(m.run_dir)
                line += (f" shards={len(store)} "
                         f"snapshots={len(store.snapshot_paths())}")
            print(line)
        if not runs:
            print("no runs matched", file=sys.stderr)
    return 0 if runs else 1


def _cmd_gc(args: argparse.Namespace) -> int:
    import os
    policy = RetentionPolicy(keep_last=args.keep_last,
                             max_age_s=args.max_age_s,
                             max_bytes=args.max_bytes)
    report = {}
    for root in args.roots:
        for run_dir in find_run_dirs(root):
            # size up the victims BEFORE enforcement so both the dry-run
            # preview and the real pass report the bytes at stake
            victims = policy.doomed(run_dir)
            sized = []
            for v in victims:
                try:
                    sized.append({"path": v, "bytes": os.path.getsize(v)})
                except OSError:        # lost a race with another writer
                    sized.append({"path": v, "bytes": 0})
            if not args.dry_run:
                # delete exactly the sized set: re-running the policy scan
                # could doom additional files (age crossing the bound,
                # concurrent ring growth) that the report would then miss
                for e in sized:
                    try:
                        os.unlink(e["path"])
                    except FileNotFoundError:
                        pass
            if sized:
                report[run_dir] = sized
    verb = "would delete" if args.dry_run else "deleted"
    total = sum(e["bytes"] for v in report.values() for e in v)
    if args.json:
        print(json.dumps({"dry_run": args.dry_run, "deleted": report,
                          "bytes": total}, indent=1))
    else:
        n = sum(len(v) for v in report.values())
        print(f"gc: {verb} {n} snapshot(s) ({total/1024:.1f} KiB) "
              f"across {len(report)} run dir(s)")
        tag = "DRY" if args.dry_run else "DEL"
        for run_dir, victims in sorted(report.items()):
            for e in victims:
                print(f"  {tag}  {e['path']} ({e['bytes']} B)")
    return 0


def _cmd_timeline(args: argparse.Namespace) -> int:
    tls = build_timelines(args.run_dir, shard=args.shard,
                          min_len=args.min_snapshots)
    if not tls:
        print(f"no shard under {args.run_dir!r} has "
              f">= {args.min_snapshots} snapshots", file=sys.stderr)
        return 1
    if args.diff:
        # cross-run drift: align two runs' rings by sequence index and
        # render per-edge delta-of-deltas (see timeline.TimelineDiff)
        other = build_timelines(args.diff, shard=args.shard,
                                min_len=args.min_snapshots)
        if not other:
            print(f"no shard under {args.diff!r} has "
                  f">= {args.min_snapshots} snapshots", file=sys.stderr)
            return 1
        pairs = pair_timelines(tls, other)
        if len(tls) != len(other):
            print(f"warning: {len(tls)} vs {len(other)} shards; diffing "
                  f"the {len(pairs)} stem-ordered pair(s)", file=sys.stderr)
        if not any(len(td) for td in pairs):
            print("no pair of shards shares sequence numbers; the rings "
                  "were retained past each other", file=sys.stderr)
            return 1
        if args.json:
            print(json.dumps([td.to_json(args.field) for td in pairs],
                             indent=1))
            return 0
        for td in pairs:
            print(render_timeline_diff(td, fld=args.field, top=args.top,
                                       edge=args.edge))
            print()
        return 0
    if args.json:
        print(json.dumps([tl.to_json(args.field) for tl in tls], indent=1))
        return 0
    for tl in tls:
        print(render_timeline(tl, fld=args.field, top=args.top,
                              edge=args.edge))
        print()
    return 0


def _cmd_calibrate(args: argparse.Namespace) -> int:
    from ..analysis import calibrate_ring, calibrate_runs
    fields = tuple(args.fields.split(","))
    if args.mode == "ring":
        tls = []
        for root in args.inputs:
            tls.extend(build_timelines(root, min_len=2))
        if not tls:
            print("no input holds a ring with >= 2 snapshots",
                  file=sys.stderr)
            return 1
        thr = calibrate_ring(tls, fields=fields, k_sigma=args.k_sigma,
                             floor=args.floor,
                             meta={"inputs": list(map(str, args.inputs))})
    else:
        tables = [load_profile(p).to_folded() for p in args.inputs]
        thr = calibrate_runs(tables, fields=fields, k_sigma=args.k_sigma,
                             floor=args.floor,
                             meta={"inputs": list(map(str, args.inputs))})
    thr.save(args.output)
    print(f"calibrated {len(thr)} edge band(s) from {len(args.inputs)} "
          f"input(s) ({thr.meta['mode']} mode) -> {args.output}")
    return 0


def _cmd_diagnose(args: argparse.Namespace) -> int:
    from ..analysis import diagnose, diagnose_fleet
    try:
        if args.fleet:
            if args.baseline:
                raise ValueError("--baseline does not apply to --fleet "
                                 "(cross-run comparison is built in)")
            diag = diagnose_fleet(args.root, config=args.config,
                                  run=args.run,
                                  thresholds_path=args.thresholds,
                                  detector_config=args.detector_config)
        else:
            if args.config:
                raise ValueError("--config selects runs for --fleet; use "
                                 "--run to pick the single run to diagnose")
            diag = diagnose(args.root, run=args.run, baseline=args.baseline,
                            thresholds_path=args.thresholds,
                            detector_config=args.detector_config)
    except (FileNotFoundError, LookupError, ValueError) as e:
        # bad inputs (missing run, ambiguous --run, corrupt/unsupported
        # --thresholds json, unknown --detector-config keys) are usage
        # errors: exit 2, never 1 — exit 1 is reserved for real findings
        # under --fail-on
        print(f"diagnose: {e}", file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps({**diag.to_json(), "fail_on": args.fail_on,
                          "failed": diag.should_fail(args.fail_on)},
                         indent=1))
    else:
        print(diag.render(top=args.top))
    return 1 if diag.should_fail(args.fail_on) else 0


def _cmd_collect(args: argparse.Namespace) -> int:
    from .collector import collect_main
    return collect_main(args.spool, host=args.bind, port=args.port,
                        timeout=args.timeout,
                        max_frame_bytes=args.max_frame_bytes,
                        max_seconds=args.max_seconds,
                        self_profile=not args.no_self_profile,
                        self_profile_interval_s=args.self_profile_interval_s)


def build_parser() -> argparse.ArgumentParser:
    """The full CLI parser — separate from main() so tooling (the
    docs-coverage check in tools/check_cli_docs.py) can enumerate every
    subcommand and flag without spawning processes."""
    ap = argparse.ArgumentParser(prog="python -m repro.profile",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    rep = sub.add_parser("report", help="render merged profile views")
    rep.add_argument("inputs", nargs="+",
                     help="snapshot files and/or shard directories")
    rep.add_argument("--component", nargs="*", default=["app"],
                     help="components to render views for")
    rep.add_argument("--top", type=int, default=20)
    rep.add_argument("--json", action="store_true")
    rep.set_defaults(fn=_cmd_report)

    mrg = sub.add_parser("merge", help="reduce shards into one snapshot")
    mrg.add_argument("inputs", nargs="+")
    mrg.add_argument("-o", "--output", required=True)
    mrg.set_defaults(fn=_cmd_merge)

    dif = sub.add_parser("diff", help="flag per-edge regressions")
    dif.add_argument("baseline")
    dif.add_argument("candidate")
    dif.add_argument("--threshold", type=float, default=0.25,
                     help="relative growth beyond which an edge is flagged")
    dif.add_argument("--fields", default="total_ns,self_ns,count",
                     help=f"comma list from {DIFF_FIELDS}")
    dif.add_argument("--min-count", type=int, default=1)
    dif.add_argument("--min-total-ns", type=int, default=0)
    dif.add_argument("--no-flag-added", action="store_true",
                     help="do not fail the gate on significant NEW edges")
    dif.add_argument("--thresholds", metavar="BANDS_JSON",
                     help="per-edge calibrated noise bands (from the "
                          "`calibrate` subcommand); --threshold stays the "
                          "fallback for uncalibrated edges")
    dif.add_argument("--json", action="store_true")
    dif.set_defaults(fn=_cmd_diff)

    qry = sub.add_parser("query", help="filter the run registry by metadata")
    qry.add_argument("root", help="registry root (tree of run dirs)")
    qry.add_argument("--config", help="config name (fnmatch glob ok)")
    qry.add_argument("--arch", help="model arch/family (glob ok)")
    qry.add_argument("--mesh", default="", help="mesh shape, e.g. 4x2")
    qry.add_argument("--label", help="run label (glob ok)")
    qry.add_argument("--kind", help="train | serve (glob ok)")
    qry.add_argument("--max-age-s", type=float, default=0.0,
                     help="only runs started within the last S seconds")
    qry.add_argument("--where", action="append", default=[], type=kv_pair,
                     metavar="KEY=VALUE",
                     help="match a manifest field or free-form meta key")
    qry.add_argument("-v", "--verbose", action="store_true",
                     help="also count each run's shards/snapshots")
    qry.add_argument("--json", action="store_true")
    qry.set_defaults(fn=_cmd_query)

    gcp = sub.add_parser("gc", help="apply a retention policy offline")
    gcp.add_argument("roots", nargs="+",
                     help="run dirs or registry roots (recursed)")
    gcp.add_argument("--keep-last", type=int, default=8,
                     help="ring length kept per shard (0: unbounded)")
    gcp.add_argument("--max-age-s", type=float, default=0.0,
                     help="delete snapshots older than S seconds")
    gcp.add_argument("--max-bytes", type=int, default=0,
                     help="per-run-dir snapshot byte budget")
    gcp.add_argument("-n", "--dry-run", action="store_true")
    gcp.add_argument("--json", action="store_true")
    gcp.set_defaults(fn=_cmd_gc)

    tml = sub.add_parser("timeline",
                         help="per-edge deltas across a shard's snapshots")
    tml.add_argument("run_dir")
    tml.add_argument("--diff", metavar="OTHER_RUN_DIR",
                     help="second run of the same config: align rings by "
                          "sequence index, render per-edge delta-of-deltas")
    tml.add_argument("--field", default="total_ns",
                     help=f"one of {TIMELINE_FIELDS}")
    tml.add_argument("--shard", help="substring filter on shard stems")
    tml.add_argument("--edge", help="substring filter on edge keys")
    tml.add_argument("--top", type=int, default=12)
    tml.add_argument("--min-snapshots", type=int, default=2,
                     help="skip shards with fewer ring entries")
    tml.add_argument("--json", action="store_true")
    tml.set_defaults(fn=_cmd_timeline)

    cal = sub.add_parser("calibrate",
                         help="fit per-edge noise bands -> thresholds json")
    cal.add_argument("inputs", nargs="+",
                     help="runs mode: one profile (snapshot/run dir) per "
                          "sample; ring mode: run dirs whose ring "
                          "intervals are the samples")
    cal.add_argument("-o", "--output", required=True)
    cal.add_argument("--mode", choices=("runs", "ring"), default="runs")
    cal.add_argument("--fields", default="count,total_ns,self_ns,mean_ns",
                     help=f"comma list from {DIFF_FIELDS}")
    cal.add_argument("--k-sigma", type=float, default=3.0,
                     help="band width: allowed growth = k*std/mean")
    cal.add_argument("--floor", type=float, default=0.05,
                     help="minimum relative threshold even for "
                          "zero-variance edges")
    cal.set_defaults(fn=_cmd_calibrate)

    dia = sub.add_parser("diagnose",
                         help="run cross-flow detectors over one run "
                              "(or a whole fleet with --fleet)")
    dia.add_argument("root", help="a run dir, or a registry root "
                                  "(then select with --run)")
    dia.add_argument("--run", help="run-id/label/config glob under ROOT "
                                   "(must match exactly one run; with "
                                   "--fleet, selects every match)")
    dia.add_argument("--fleet", action="store_true",
                     help="diagnose EVERY matching run, add cross-host "
                          "fleet-straggler and cross-run outlier findings, "
                          "rank the union; JSON output groups findings by "
                          "(severity, detector, host)")
    dia.add_argument("--config", help="with --fleet: config-name glob "
                                      "selecting which runs to include")
    dia.add_argument("--baseline", metavar="RUN",
                     help="baseline run dir or registry glob: enables the "
                          "cross-run drift-regression detector")
    dia.add_argument("--thresholds", metavar="BANDS_JSON",
                     help="calibrated noise bands; detectors use them as "
                          "per-edge noise floors")
    dia.add_argument("--detector-config", metavar="CONFIG_JSON",
                     help="per-detector constructor parameters, e.g. "
                          '{"wait-dominance": {"warn_share": 0.5}} — '
                          "tune thresholds without code; unknown detector "
                          "names or parameters exit 2")
    dia.add_argument("--fail-on", choices=("none", "warn", "crit"),
                     default="none",
                     help="exit 1 when any finding is at/above this "
                          "severity (CI gate); default: always exit 0")
    dia.add_argument("--top", type=int, default=50,
                     help="max findings rendered in text mode")
    dia.add_argument("--json", action="store_true")
    dia.set_defaults(fn=_cmd_diagnose)

    col = sub.add_parser("collect",
                         help="run the fleet collector daemon (spool "
                              "snapshot deltas shipped by publishers)")
    col.add_argument("--spool", required=True,
                     help="spool root: SPOOL/<run_id>/<host>/<shard>."
                          "seq<N>.xfa.npz — a registry root that query/"
                          "merge/diagnose understand directly")
    col.add_argument("--bind", default="127.0.0.1",
                     help="interface to listen on")
    col.add_argument("--port", type=int, default=0,
                     help="TCP port (0: ephemeral; the bound port is "
                          "printed on startup)")
    col.add_argument("--timeout", type=float, default=30.0,
                     help="per-socket-operation timeout in seconds")
    col.add_argument("--max-frame-bytes", type=int,
                     default=256 * 1024 * 1024,
                     help="reject frames with larger payloads")
    col.add_argument("--max-seconds", type=float, default=0.0,
                     help="exit after S seconds (0: serve until "
                          "SIGINT/SIGTERM) — CI lanes use this")
    col.add_argument("--no-self-profile", action="store_true",
                     help="do not spool the collector's own ingest "
                          "metrics into SPOOL/_collector")
    col.add_argument("--self-profile-interval-s", type=float, default=30.0,
                     help="seconds between self-metric snapshots")
    col.set_defaults(fn=_cmd_collect)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
