"""Unit tests for the XFA core: shadow table, tracer, folding, views."""

import threading
import time

import numpy as np
import pytest

from repro.core import (FoldedTable, KIND_WAIT, ShadowTable, ShadowTableSet,
                        Tracer, api_view, api_view_by_caller, component_view,
                        fold_event_log, render_flow_matrix, wait_split)
from repro.core.attribution import (attribute_parallel, expert_imbalance,
                                    imbalance_report)


def make_tracer():
    return Tracer()


# ---------------------------------------------------------------- shadow ----
class TestShadowTable:
    def test_slot_interning_is_stable(self):
        t = make_tracer()
        a = t.tables.registry.resolve("app", "ckpt", "save")
        b = t.tables.registry.resolve("app", "ckpt", "save")
        c = t.tables.registry.resolve("optimizer", "ckpt", "save")
        assert a.slot == b.slot
        assert c.slot != a.slot  # relation-aware: caller is part of the key

    def test_growth_preserves_stats(self):
        st = ShadowTable(capacity=2)
        st.record(0, 100)
        st.record(5, 7)  # forces growth past initial capacity
        assert st.count[0] == 1 and st.total_ns[0] == 100
        assert st.count[5] == 1 and st.total_ns[5] == 7
        assert st.capacity >= 6

    def test_memory_is_o_slots_not_o_events(self):
        st = ShadowTable()
        before = st.nbytes()
        for _ in range(100_000):
            st.record(3, 10)
        assert st.nbytes() == before  # folding: no growth with event count

    def test_min_max(self):
        st = ShadowTable()
        for d in (5, 1, 9):
            st.record(0, d)
        assert st.min_ns[0] == 1 and st.max_ns[0] == 9 and st.total_ns[0] == 15


# ---------------------------------------------------------------- tracer ----
class TestTracer:
    def test_caller_attribution(self):
        t = make_tracer()

        @t.api("liba")
        def inner():
            time.sleep(0.001)

        @t.api("libb")
        def outer():
            inner()

        outer()
        inner()  # direct call from app
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        assert ("libb", "liba", "inner") in folds.edges
        assert ("app", "liba", "inner") in folds.edges
        assert ("app", "libb", "outer") in folds.edges
        assert folds.edges[("libb", "liba", "inner")].count == 1
        assert folds.edges[("app", "liba", "inner")].count == 1

    def test_self_time_excludes_children(self):
        t = make_tracer()

        @t.api("liba")
        def child():
            time.sleep(0.005)

        @t.api("libb")
        def parent():
            child()

        parent()
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        p = folds.edges[("app", "libb", "parent")]
        c = folds.edges[("libb", "liba", "child")]
        assert p.child_ns >= c.total_ns * 0.5
        assert p.self_ns < p.total_ns

    def test_disabled_tracer_records_nothing(self):
        t = make_tracer()
        t.enabled = False

        @t.api("liba")
        def f():
            return 42

        assert f() == 42
        assert len(FoldedTable.merge_all(FoldedTable.from_set(t.tables))) == 0

    def test_counting_only_mode(self):
        t = make_tracer()
        t.timing = False

        @t.api("liba")
        def f():
            return 1

        for _ in range(10):
            f()
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        e = folds.edges[("app", "liba", "f")]
        assert e.count == 10 and e.total_ns == 0

    def test_wait_kind(self):
        t = make_tracer()

        @t.wait("runtime", "join")
        def block():
            time.sleep(0.001)

        block()
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        useful, wait = wait_split(folds)
        assert len(wait) == 1 and len(useful) == 0
        assert folds.edges[("app", "runtime", "join")].kind == KIND_WAIT

    def test_scope_and_wrap(self):
        t = make_tracer()
        with t.scope("data", "load"):
            pass
        g = t.wrap(lambda: 3, component="serve", name="dispatched")
        assert g() == 3
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        assert ("app", "data", "load") in folds.edges
        assert ("app", "serve", "dispatched") in folds.edges

    def test_exception_pops_frame(self):
        t = make_tracer()

        @t.api("liba")
        def boom():
            raise ValueError("x")

        with pytest.raises(ValueError):
            boom()
        assert t.stack_depth() == 0
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        assert folds.edges[("app", "liba", "boom")].count == 1

    def test_per_thread_tables(self):
        t = make_tracer()

        @t.api("liba")
        def f():
            pass

        def worker():
            t.set_thread_group("workers")
            for _ in range(5):
                f()

        threads = [threading.Thread(target=worker) for _ in range(3)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        f()  # main thread
        tables = t.tables.tables()
        assert len(tables) == 4  # 3 workers + main
        merged = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        assert merged.edges[("app", "liba", "f")].count == 16

    def test_dead_thread_tables_retire_bounded(self):
        """Thread churn (e.g. one ckpt-writer thread per save) must not grow
        the table list without bound; dead tables fold into per-group
        accumulators and no data is lost."""
        t = make_tracer()

        @t.api("liba")
        def f():
            pass

        n = t.tables.RETIRE_SWEEP_THRESHOLD * 3
        for i in range(n):
            th = threading.Thread(target=f, name=f"w{i}")
            th.start()
            th.join()
        assert len(t.tables.tables()) <= t.tables.RETIRE_SWEEP_THRESHOLD + 2
        merged = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        assert merged.edges[("app", "liba", "f")].count == n


# --------------------------------------------------------------- folding ----
class TestFolding:
    def test_fold_matches_event_log(self):
        events = [("app", "liba", "x", 10), ("app", "liba", "x", 20),
                  ("libb", "liba", "x", 5)]
        folded = fold_event_log(events)
        assert folded.edges[("app", "liba", "x")].count == 2
        assert folded.edges[("app", "liba", "x")].total_ns == 30
        assert folded.edges[("libb", "liba", "x")].count == 1

    def test_merge_identity_and_commutativity(self):
        a = fold_event_log([("app", "l", "x", 10)])
        b = fold_event_log([("app", "l", "x", 5), ("app", "l", "y", 1)])
        ab = a.merge(b)
        ba = b.merge(a)
        assert ab.edges.keys() == ba.edges.keys()
        for k in ab.edges:
            assert ab.edges[k].total_ns == ba.edges[k].total_ns
        empty = FoldedTable()
        ae = a.merge(empty)
        assert ae.edges[("app", "l", "x")].total_ns == 10

    def test_json_roundtrip(self):
        a = fold_event_log([("app", "l", "x", 10), ("m", "l", "x", 3)])
        b = FoldedTable.from_json(a.to_json())
        assert b.edges.keys() == a.edges.keys()
        assert b.edges[("m", "l", "x")].total_ns == 3


# ----------------------------------------------------------- attribution ----
class TestAttribution:
    def test_parallel_division(self):
        f = fold_event_log([("app", "l", "x", 1600)])
        p = attribute_parallel(f, 16)
        assert p.folded.edges[("app", "l", "x")].total_ns == 100

    def test_imbalance_detection(self):
        heavy = fold_event_log([("app", "l", "work", 16_000_000)])
        light = fold_event_log([("app", "l", "work", 1_000_000)])
        heavy.group, light.group = "rank", "seg"
        rep = imbalance_report({"rank": [heavy], "seg": [light]}, threshold=4.0)
        assert rep.imbalanced and rep.max_exec_ratio == pytest.approx(16.0)

    def test_expert_imbalance(self):
        bad, ratio = expert_imbalance([100, 1, 1, 1], threshold=3.0)
        assert bad and ratio > 3
        ok, _ = expert_imbalance([10, 11, 9, 10], threshold=3.0)
        assert not ok


# ----------------------------------------------------------------- views ----
class TestViews:
    def _fold(self):
        return fold_event_log([
            ("app", "glibc", "read", 18), ("app", "glibc", "write", 35),
            ("app", "alloc", "malloc", 10), ("glibc", "alloc", "malloc", 2),
        ])

    def test_component_view_of_app(self):
        v = component_view(self._fold(), "app", total_ns=100)
        glibc = v.find("glibc")
        assert glibc is not None and glibc.time_ns == 53
        assert v.find("Self").time_ns == pytest.approx(100 - 63)

    def test_api_view(self):
        v = api_view(self._fold(), "glibc")
        assert v.top().label == "write"
        assert v.top().pct == pytest.approx(100 * 35 / 53)

    def test_api_view_by_caller_keeps_relation(self):
        v = api_view_by_caller(self._fold(), "alloc")
        labels = {r.label for r in v.rows}
        assert labels == {"app -> malloc", "glibc -> malloc"}

    def test_flow_matrix_renders(self):
        s = render_flow_matrix(self._fold())
        assert "glibc" in s and "alloc" in s
