"""Per-cell jittable + input-spec construction for the dry-run and benches.

A *cell* is (architecture × input shape × mesh). For each cell this module
builds, WITHOUT allocating anything:
  * the step callable to lower (train_step / prefill_step / serve_step),
  * ShapeDtypeStruct stand-ins for every input,
  * in/out shardings (params by rule table; serve caches by the per-family
    leaf rules below).

Shape semantics per the assignment: decode_* / long_* lower `serve_step`
(ONE new token against a seq_len KV cache), not train_step. long_500k runs
only for the sub-quadratic archs (zamba2 hybrid, xlstm ssm) — skips recorded
in DESIGN.md §Arch-applicability.  prefill_* lowers the POSITIONED chunk
forward (`forward_chunk` with a per-slot pos vector) for token-prompt
families — prefill and decode are the same operation at different widths,
so the lowered prefill cell is exactly the program the serving engine
compiles per chunk bucket; vlm/audio keep the prefill wrapper (their
multimodal prefix rides on the pos = 0 chunk).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, TrainConfig)
from repro.models.api import Model, build_model
from repro.parallel.axes import resolve_spec
from repro.parallel.sharding import sharding_tree
from repro.runtime.trainer import (init_train_state, make_train_step,
                                   state_shardings)

PURE_ATTENTION = {"granite-20b", "starcoder2-7b", "qwen3-14b",
                  "tinyllama-1.1b", "deepseek-v2-lite-16b", "phi3.5-moe-42b",
                  "internvl2-1b", "seamless-m4t-large-v2"}


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.kind == "long_decode":
        return cfg.family in ("hybrid", "ssm")   # sub-quadratic state archs
    return True


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    from repro.parallel.axes import get_rules
    return tuple(a for a in get_rules().get("batch", ("pod", "data"))
                 if a in mesh.axis_names)


def _div(n: int, axes: Tuple[str, ...], sizes: Dict[str, int]) -> bool:
    prod = 1
    for a in axes:
        prod *= sizes[a]
    return prod > 1 and n % prod == 0


def cache_leaf_spec(path_names: Tuple[str, ...], shape: Tuple[int, ...],
                    cfg: ModelConfig, mesh: Mesh) -> P:
    """Sharding for one serving-cache leaf. Greedy, family-aware:
    batch dim -> (pod, data); heads -> model when divisible; else the cache
    sequence dim takes whichever axes remain (context sharding)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b_axes = _batch_axes(mesh)
    name = path_names[-1] if path_names else ""
    parts: list = [None] * len(shape)
    used: set = set()

    def place(dim: int, axes: Tuple[str, ...]) -> bool:
        free = tuple(a for a in axes if a not in used)
        if free and _div(shape[dim], free, sizes):
            parts[dim] = free[0] if len(free) == 1 else free
            used.update(free)
            return True
        return False

    if name in ("k", "v", "xk", "xv", "attn_k", "attn_v"):
        # [L, B, H, S, hd]
        place(1, b_axes)
        if not place(2, ("model",)):
            place(3, ("model",))
        place(3, b_axes)           # remaining batch axes onto sequence
    elif name in ("ckv", "krope"):
        # [L, B, S, r] — MLA latent cache has no head dim
        place(1, b_axes)
        place(2, ("model",))
        place(2, b_axes)
    elif name == "conv":
        # [L, B, K-1, ch]
        place(1, b_axes)
        place(3, ("model",))
    elif name == "h":
        # [L, B, H, N, ph] ssd state
        place(1, b_axes)
        place(2, ("model",))
    elif "mlstm" in path_names:
        # tuple state (C [ns,nm,B,H,ph,ph], n [ns,nm,B,H,ph], m [ns,nm,B,H])
        if len(shape) >= 3:
            place(2, b_axes)
        if len(shape) >= 5:
            place(len(shape) - 1, ("model",))
    elif "slstm" in path_names:
        # [ns, B, d]
        if len(shape) >= 2:
            place(1, b_axes)
        if len(shape) >= 3:
            place(2, ("model",))
    else:
        if len(shape) >= 2:
            place(1, b_axes)
    return P(*parts)


def cache_shardings(cache_like, cfg: ModelConfig, mesh: Mesh):
    def leaf(path, x):
        names = tuple(str(getattr(p, "key", getattr(p, "name", p)))
                      for p in path)
        return NamedSharding(mesh, cache_leaf_spec(names, x.shape, cfg, mesh))
    return jax.tree_util.tree_map_with_path(leaf, cache_like)


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch × shape × mesh) combination."""
    name: str
    cfg: ModelConfig
    shape: ShapeConfig
    fn: Callable
    args: Tuple[Any, ...]              # ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any
    donate: Tuple[int, ...] = ()


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               tcfg: Optional[TrainConfig] = None,
               impl: str = "chunked") -> Cell:
    """impl='chunked' lowers flash-PATTERN jnp kernels (Mosaic cannot lower
    on the CPU backend); on a real TPU pass impl='pallas'."""
    model = build_model(cfg, impl=impl)
    tcfg = tcfg or TrainConfig()
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        step = make_train_step(model, tcfg)
        state_like = jax.eval_shape(
            lambda k: init_train_state(model, k, tcfg), jax.random.key(0))
        batch_like = model.batch_spec(shape)
        table_like = jax.ShapeDtypeStruct((model.fold_spec.size,),
                                          jnp.float32)
        ss = state_shardings(state_like, mesh, tcfg.zero1)
        bs = jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(_batch_axes(mesh)) if len(x.shape) == 1 else
                P(_batch_axes(mesh), *([None] * (len(x.shape) - 1)))),
            batch_like)
        return Cell(name=f"{cfg.name}:{shape.name}", cfg=cfg, shape=shape,
                    fn=step, args=(state_like, batch_like, table_like),
                    in_shardings=(ss, bs, rep),
                    out_shardings=(ss, None, rep), donate=(0,))

    params_like = jax.eval_shape(model.init, jax.random.key(0))
    ps = sharding_tree(params_like, mesh)
    table_like = jax.ShapeDtypeStruct((model.fold_spec.size,), jnp.float32)
    B, S = shape.global_batch, shape.seq_len
    b_axes = _batch_axes(mesh)

    if shape.kind == "prefill":
        batch_like = model.batch_spec(shape)
        batch_like.pop("labels", None)
        batch_like.pop("mask", None)
        cache_like = jax.eval_shape(
            lambda: model.init_cache(B, S,
                                     **({"src_len": S} if
                                        cfg.family == "audio" else {})))
        cs = cache_shardings(cache_like, cfg, mesh)
        bs = jax.tree.map(
            lambda x: NamedSharding(mesh, P(b_axes,
                                            *([None] * (len(x.shape) - 1)))),
            batch_like)

        if cfg.family in ("vlm", "audio"):
            # multimodal prefixes ride only through the prefill wrapper
            # (patches/frames are per-family extras of the pos = 0 chunk)
            def prefill_step(params, batch, table, cache):
                return model.prefill(params, batch, table, cache)

            return Cell(name=f"{cfg.name}:{shape.name}", cfg=cfg,
                        shape=shape, fn=prefill_step,
                        args=(params_like, batch_like, table_like,
                              cache_like),
                        in_shardings=(ps, bs, rep, cs),
                        out_shardings=(None, cs, rep), donate=(3,))

        # token-prompt families lower the POSITIONED chunk — the program
        # serving actually compiles: prompt chunks land at per-slot cache
        # offsets, bulk prefill being the pos = 0 specialization
        def chunk_step(params, batch, table, cache, pos):
            return model.forward_chunk(params, batch["tokens"], table,
                                       cache, pos)

        pos_like = jax.ShapeDtypeStruct((B,), jnp.int32)
        pos_s = NamedSharding(mesh, P(b_axes) if _div(
            B, b_axes, dict(zip(mesh.axis_names, mesh.devices.shape)))
            else P())
        return Cell(name=f"{cfg.name}:{shape.name}", cfg=cfg, shape=shape,
                    fn=chunk_step,
                    args=(params_like, batch_like, table_like, cache_like,
                          pos_like),
                    in_shardings=(ps, bs, rep, cs, pos_s),
                    out_shardings=(None, cs, rep), donate=(3,))

    # decode / long_decode: one token against a seq_len cache
    cache_like = jax.eval_shape(
        lambda: model.init_cache(B, S,
                                 **({"src_len": S} if cfg.family == "audio"
                                    else {})))
    cs = cache_shardings(cache_like, cfg, mesh)
    tok_like = jax.ShapeDtypeStruct((B,), jnp.int32)
    ts = NamedSharding(mesh, P(b_axes) if _div(
        B, b_axes, dict(zip(mesh.axis_names, mesh.devices.shape))) else P())

    def serve_step(params, token, table, cache, pos):
        return model.decode_step(params, token, table, cache, pos)

    # per-slot positions [B] (continuous batching: every cache row at its
    # own depth) — sharded like the token vector
    pos_like = jax.ShapeDtypeStruct((B,), jnp.int32)
    return Cell(name=f"{cfg.name}:{shape.name}", cfg=cfg, shape=shape,
                fn=serve_step,
                args=(params_like, tok_like, table_like, cache_like,
                      pos_like),
                in_shardings=(ps, ts, rep, cs, ts),
                out_shardings=(None, cs, rep), donate=(3,))
