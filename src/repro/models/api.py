"""build_model(cfg) — one uniform handle over every architecture family.

A Model bundles the family's pure functions behind a stable signature so the
launcher, trainer, server, dry-run and benchmarks never dispatch on family:

    model.init(key)                          -> params
    model.loss_fn(params, batch, table)      -> (loss, (metrics, table))
    model.init_cache(batch, max_len)         -> cache pytree
    model.forward_chunk(params, tokens, table, cache, pos[, valid])
                                             -> (logits, cache, table)
        THE serving entry point: tokens [B, T] written at per-slot cache
        offsets pos [B] int32 (a scalar broadcasts), offset-causal against
        existing cache content; valid [B] masks a bucket-padded chunk and
        logits come from each row's last valid token.  Prefill and decode
        are this operation at different widths.
    model.prefill(params, batch, table, cache) -> (logits, cache, table)
        = forward_chunk at pos 0 over the whole prompt (carries the
        family's multimodal extras: vlm patches, audio frames)
    model.decode_step(params, tok, table, cache, pos) -> (logits, cache, table)
        = forward_chunk at width T = 1 (the pooled decode tick)
    model.batch_spec(shape)                  -> ShapeDtypeStruct pytree
    model.fold_spec                          -> frozen DeviceFoldSpec
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.device_fold import DeviceFoldSpec

from . import encdec, mamba, transformer, xlstm
from .layers import Runtime


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rt: Runtime
    fold_spec: DeviceFoldSpec
    init: Callable
    loss_fn: Callable
    init_cache: Callable
    forward_chunk: Callable
    prefill: Callable
    decode_step: Callable
    # paged serving cache (transformer families only; None elsewhere —
    # recurrent state is O(1) in sequence length, nothing to page):
    #   init_paged_cache(pages, page_size)          -> arena pytree
    #   forward_chunk_paged(params, tokens, table, arena, pos,
    #                       block_table[, valid])   -> (logits, arena, table)
    #   decode_step_paged(params, tok, table, arena, pos, block_table)
    init_paged_cache: Optional[Callable] = None
    forward_chunk_paged: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None

    def batch_spec(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for a training batch (dry-run safe)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        text_s = S - cfg.n_patches if cfg.family == "vlm" else S
        spec = {
            "tokens": jax.ShapeDtypeStruct((B, text_s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, text_s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((B, text_s), jnp.float32),
        }
        if cfg.family == "vlm":
            spec["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.frontend_dim), jnp.float32)
        if cfg.family == "audio":
            spec["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.frontend_dim), jnp.float32)
        return spec

    def cache_spec(self, batch: int, max_len: int) -> Any:
        """ShapeDtypeStructs for the serving cache (no allocation)."""
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def table(self):
        return self.fold_spec.init_table()


def _fold_spec(cfg: ModelConfig, declare) -> DeviceFoldSpec:
    spec = DeviceFoldSpec()
    declare(spec, cfg)
    return spec.freeze()


def build_model(cfg: ModelConfig, impl: str = "auto") -> Model:
    cfg = cfg.validate()
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
    elif cfg.family == "hybrid":
        mod = mamba
    elif cfg.family == "ssm":
        mod = xlstm
    elif cfg.family == "audio":
        mod = encdec
    else:
        raise ValueError(cfg.family)

    spec = _fold_spec(cfg, mod.declare_fold_slots)
    rt = Runtime(cfg=cfg, impl=impl, fold_spec=spec)

    def init(key):
        return mod.init_params(key, cfg)

    def loss_fn(params, batch, table):
        return mod.loss_fn(params, batch, rt, table)

    def init_cache(batch, max_len, src_len: int = 0):
        if cfg.family == "audio":
            return encdec.init_cache(cfg, batch, max_len, src_len=src_len)
        if cfg.family == "ssm":
            return xlstm.init_cache(cfg, batch, max_len)
        if cfg.family == "hybrid":
            return mamba.init_cache(cfg, batch, max_len)
        return transformer.init_cache(cfg, batch, max_len)

    def forward_chunk(params, tokens, table, cache, pos, valid=None):
        # tokens: [B, T] chunk at per-slot offsets pos [B]; valid [B]
        # masks bucket padding.  Each family canonicalizes pos (scalars
        # broadcast there, so direct module callers get it too).
        return mod.forward_chunk(params, tokens, rt, table, cache, pos,
                                 valid=valid)

    def prefill(params, batch, table, cache):
        extra = {}
        if cfg.family == "audio":
            extra["frames"] = batch["frames"]
        elif cfg.family == "vlm":
            extra["prefix_embeds"] = transformer._project_patches(
                params, batch["patches"], rt)
        zero = jnp.zeros((batch["tokens"].shape[0],), jnp.int32)
        return mod.forward_chunk(params, batch["tokens"], rt, table, cache,
                                 zero, **extra)

    def decode_step(params, token, table, cache, pos):
        return mod.forward_chunk(params, token[:, None], rt, table, cache,
                                 pos)

    paged: Dict[str, Any] = {}
    if mod is transformer:
        def init_paged_cache(pages, page_size):
            return transformer.init_paged_cache(cfg, pages, page_size)

        def forward_chunk_paged(params, tokens, table, cache, pos,
                                block_table, valid=None):
            return transformer.forward_chunk_paged(
                params, tokens, rt, table, cache, pos, block_table,
                valid=valid)

        def decode_step_paged(params, token, table, cache, pos, block_table):
            return transformer.decode_step_paged(params, token, rt, table,
                                                 cache, pos, block_table)

        paged = {"init_paged_cache": init_paged_cache,
                 "forward_chunk_paged": forward_chunk_paged,
                 "decode_step_paged": decode_step_paged}

    return Model(cfg=cfg, rt=rt, fold_spec=spec, init=init, loss_fn=loss_fn,
                 init_cache=init_cache, forward_chunk=forward_chunk,
                 prefill=prefill, decode_step=decode_step, **paged)
