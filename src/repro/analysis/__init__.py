"""repro.analysis — automated cross-flow diagnosis over XFA profiles.

Everything repro.profile collects (shadow-table folds -> columnar shards
-> snapshot rings -> run registry) becomes *interpretable* here: a typed
Cross Flow Graph, a set of pathology detectors with structured findings,
noise-band calibration for variance-aware thresholds, and the
orchestration behind `python -m repro.profile diagnose`.

  graph.py      FlowGraph (typed nodes/edges from EdgeColumns) + per-shard
                projections (one comparable subgraph per rank/replica)
  detectors.py  Detector protocol, Finding, and the 9 built-in detectors
  calibrate.py  per-edge noise bands (mean/std/p95) from baseline runs or
                a ring, serialized as a thresholds JSON
  diagnose.py   run selection -> DiagnosisContext -> findings -> report
  fleet.py      cross-run/cross-host ranking behind `diagnose --fleet`:
                per-host merged graphs, fleet-straggler + run-outlier
                findings, reports grouped by (severity, detector, host)
"""

from .graph import (FlowEdge, FlowGraph, FlowNode, edge_label, run_graph,
                    shard_graphs)
from .calibrate import (CALIBRATE_FIELDS, EdgeBand, Thresholds,
                        calibrate_ring, calibrate_runs)
from .detectors import (SEVERITIES, CachePressure, CallAmplification,
                        Detector, DiagnosisContext, DriftRegression,
                        Finding, HotEdgeConcentration, QueueSaturation,
                        RankImbalance, SamplingBackoff, SloViolation,
                        WaitDominance, builtin_detectors, detector_classes,
                        run_detectors, severity_rank)
from .diagnose import (Diagnosis, build_context, diagnose,
                       load_detector_config, resolve_run_dir)
from .fleet import (FleetDiagnosis, diagnose_fleet, fleet_straggler_findings,
                    host_graphs, stem_host)

__all__ = [
    "FlowEdge", "FlowGraph", "FlowNode", "edge_label", "run_graph",
    "shard_graphs",
    "CALIBRATE_FIELDS", "EdgeBand", "Thresholds", "calibrate_ring",
    "calibrate_runs",
    "SEVERITIES", "CachePressure", "CallAmplification", "Detector",
    "DiagnosisContext",
    "DriftRegression", "Finding", "HotEdgeConcentration", "QueueSaturation",
    "RankImbalance", "SamplingBackoff", "SloViolation", "WaitDominance",
    "builtin_detectors", "detector_classes", "run_detectors",
    "severity_rank",
    "Diagnosis", "build_context", "diagnose", "load_detector_config",
    "resolve_run_dir",
    "FleetDiagnosis", "diagnose_fleet", "fleet_straggler_findings",
    "host_graphs", "stem_host",
]
