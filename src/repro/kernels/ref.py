"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes/dtypes in interpret mode) AND the
CPU-executable implementation the models fall back to when no TPU is present
(ops.py `impl='auto'`). They favour clarity over speed; the `*_chunked`
variants mirror the kernels' blocking algebra and are themselves validated
against the naive forms.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------ attention ----
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: Optional[float] = None,
              logit_softcap: float = 0.0,
              q_offset: int = 0) -> jax.Array:
    """Reference GQA attention.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D]; Hq % Hkv == 0.
    q_offset: absolute position of q[0] (for decode: Sk - Sq).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Hkv, g, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    if logit_softcap > 0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if causal:
        rows = jnp.arange(Sq)[:, None] + q_offset
        cols = jnp.arange(Sk)[None, :]
        s = jnp.where(cols <= rows, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, sm_scale: Optional[float] = None,
                      logit_softcap: float = 0.0, block_k: int = 1024,
                      q_offset: int = 0) -> jax.Array:
    """Flash-pattern attention in pure jnp: online softmax over KV blocks via
    lax.scan, never materializing the [Sq, Sk] score matrix — with a FLASH
    BACKWARD (custom_vjp below) that saves only (q, k, v, o, lse) and
    recomputes p per block, exactly like the FlashAttention-2 backward.
    Without it, jax AD stacks the per-block p residuals: +1 GiB/layer on
    tinyllama train_4k (measured — EXPERIMENTS.md §Perf iteration 0).

    This is the dry-run stand-in for the Pallas kernel (Mosaic cannot lower
    on the CPU backend): identical FLOPs and O(Sq·block_k) live memory, so
    memory_analysis() reflects the fused-kernel footprint. Causal blocks
    above the diagonal are masked, not skipped (a static scan) — the compute
    roofline term therefore upper-bounds the kernel, which does skip them;
    EXPERIMENTS.md §Roofline notes the ≤2x causal adjustment.
    """
    # Head padding for TP: when Hq does not divide the model axis (qwen3 40,
    # starcoder2 36, internvl 14 vs TP=16), SPMD falls back to factorized
    # head shardings and re-gathers K/V blocks EVERY chunk iteration
    # (measured 1.5 TB/step on qwen3 prefill_32k — EXPERIMENTS.md §Perf).
    # Padding to the next multiple costs <=20% attention FLOPs and keeps
    # every tensor cleanly head-sharded; padded heads are sliced off (and
    # autodiff slices their cotangents to zero).
    from repro.parallel.axes import axis_size
    msize = axis_size("model")
    Hq = q.shape[1]
    Hkv = k.shape[1]
    pad_h = (-Hq) % msize if msize > 1 else 0
    if pad_h:
        # repeat kv heads FIRST (AD of repeat folds dk/dv back), then pad
        # all three uniformly — keeps GQA group alignment for any g
        g = Hq // Hkv
        kr = k if g == 1 else jnp.repeat(k, g, axis=1)
        vr = v if g == 1 else jnp.repeat(v, g, axis=1)
        padded = [jnp.pad(t, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
                  for t in (q, kr, vr)]
        o = attention_chunked(*padded, causal=causal, sm_scale=sm_scale,
                              logit_softcap=logit_softcap,
                              block_k=block_k, q_offset=q_offset)
        return o[:, :Hq]
    if logit_softcap == 0.0:
        scale = sm_scale if sm_scale is not None else q.shape[-1] ** -0.5
        return _flash_chunked(q, k, v, causal, scale, block_k, q_offset)
    return _attention_chunked_impl(q, k, v, causal=causal, sm_scale=sm_scale,
                                   logit_softcap=logit_softcap,
                                   block_k=block_k, q_offset=q_offset)


def _attention_chunked_impl(q, k, v, *, causal, sm_scale, logit_softcap,
                            block_k, q_offset, return_lse: bool = False):
    from repro.parallel.axes import shard_dims  # local: avoid import cycle
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = Hq // Hkv
    block_k = min(block_k, Sk)
    assert Sk % block_k == 0, (Sk, block_k)
    nk = Sk // block_k
    scale = sm_scale if sm_scale is not None else D ** -0.5
    # GQA by kv-head repeat (Megatron TP>kv pattern): every tensor stays 4D
    # [B, Hq, ...] so 'model' shards the q-head dim wherever divisible. The
    # repeat is free per-device under head sharding (each rank gathers only
    # the kv heads its q heads need).
    _c = lambda t: shard_dims(t, {0: "batch", 1: "model"})
    qf = _c(q.astype(jnp.float32) * scale)
    kr = k if g == 1 else jnp.repeat(k, g, axis=1)
    vr = v if g == 1 else jnp.repeat(v, g, axis=1)
    kb = _c(kr.reshape(B, Hq, nk, block_k, D))
    vb = _c(vr.reshape(B, Hq, nk, block_k, D))
    rows = jnp.arange(Sq) + q_offset

    def body(carry, inp):
        m, l, acc = carry
        kk, vv, ik = inp
        # pin the scan carries: unconstrained while-loop carries fall back
        # to replicated under SPMD -> per-iteration all-gathers
        m, l, acc = _c(m), _c(l), _c(acc)
        kk, vv = _c(kk), _c(vv)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kk.astype(jnp.float32))
        if logit_softcap > 0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        if causal:
            cols = ik * block_k + jnp.arange(block_k)
            s = jnp.where(cols[None, None, None, :]
                          <= rows[None, None, :, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
        return (_c(m_new), _c(l), _c(acc)), None

    m0 = _c(jnp.full((B, Hq, Sq), NEG_INF, jnp.float32))
    l0 = _c(jnp.zeros((B, Hq, Sq), jnp.float32))
    a0 = _c(jnp.zeros((B, Hq, Sq, D), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    o = acc / l[..., None]
    if return_lse:
        return o.astype(q.dtype), m + jnp.log(l)
    return o.astype(q.dtype)


# ---- flash backward: save (q, k, v, o, lse); recompute p per kv block ----
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_chunked(q, k, v, causal, sm_scale, block_k, q_offset):
    return _attention_chunked_impl(q, k, v, causal=causal, sm_scale=sm_scale,
                                   logit_softcap=0.0, block_k=block_k,
                                   q_offset=q_offset)


def _flash_chunked_fwd(q, k, v, causal, sm_scale, block_k, q_offset):
    o, lse = _attention_chunked_impl(q, k, v, causal=causal,
                                     sm_scale=sm_scale, logit_softcap=0.0,
                                     block_k=block_k, q_offset=q_offset,
                                     return_lse=True)
    return o, (q, k, v, o, lse)


def _flash_chunked_bwd(causal, sm_scale, block_k, q_offset, res, do):
    # custom_vjp bwd is traced OUTSIDE the model's named_scope — re-enter it
    # so the XFA static layer attributes these loops to the kernel scope
    with jax.named_scope("attention"):
        return _flash_chunked_bwd_impl(causal, sm_scale, block_k, q_offset,
                                       res, do)


def _flash_chunked_bwd_impl(causal, sm_scale, block_k, q_offset, res, do):
    from repro.parallel.axes import shard_dims  # local: avoid import cycle
    q, k, v, o, lse = res
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = Hq // Hkv
    bk = min(block_k, Sk)
    nk = Sk // bk
    _c = lambda t: shard_dims(t, {0: "batch", 1: "model"})
    qs = _c(q.astype(jnp.float32) * sm_scale)
    dof = _c(do.astype(jnp.float32))
    lse_r = _c(lse.astype(jnp.float32))
    # delta_i = rowsum(dO ∘ O)
    delta = _c(jnp.sum(dof * o.astype(jnp.float32), axis=-1))
    kr = k if g == 1 else jnp.repeat(k, g, axis=1)
    vr = v if g == 1 else jnp.repeat(v, g, axis=1)
    kb = _c(kr.reshape(B, Hq, nk, bk, D))
    vb = _c(vr.reshape(B, Hq, nk, bk, D))
    rows = jnp.arange(Sq) + q_offset

    def body(dq_acc, inp):
        kk, vv, ik = inp
        dq_acc = _c(dq_acc)
        kk, vv = _c(kk), _c(vv)
        kf, vf = kk.astype(jnp.float32), vv.astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qs, kf)
        p = jnp.exp(s - lse_r[..., None])                # softmax via lse
        if causal:
            cols = ik * bk + jnp.arange(bk)
            p = jnp.where(cols[None, None, None, :]
                          <= rows[None, None, :, None], p, 0.0)
        dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vf)
        ds = p * (dp - delta[..., None])
        dq_acc = _c(dq_acc + jnp.einsum("bhqk,bhkd->bhqd", ds, kf))
        dk = _c(jnp.einsum("bhqk,bhqd->bhkd", ds, qs))
        return dq_acc, (dk, dv)

    dq0 = _c(jnp.zeros((B, Hq, Sq, D), jnp.float32))
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(
        body, dq0,
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), jnp.arange(nk)))
    dq = (dq * sm_scale).astype(q.dtype)
    # fold repeated-head grads back onto the Hkv heads
    dk_r = jnp.moveaxis(dk_blocks, 0, 2).reshape(B, Hq, Sk, D)
    dv_r = jnp.moveaxis(dv_blocks, 0, 2).reshape(B, Hq, Sk, D)
    if g > 1:
        dk_r = dk_r.reshape(B, Hkv, g, Sk, D).sum(axis=2)
        dv_r = dv_r.reshape(B, Hkv, g, Sk, D).sum(axis=2)
    return dq, dk_r.astype(k.dtype), dv_r.astype(v.dtype)


_flash_chunked.defvjp(_flash_chunked_fwd, _flash_chunked_bwd)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     kv_len: Optional[jax.Array] = None,
                     sm_scale: Optional[float] = None,
                     return_residuals: bool = False):
    """Reference single-token decode attention.

    q: [B, Hq, D]; k, v: [B, Hkv, S, D]. kv_len: [B] valid prefix lengths
    (positions >= kv_len are masked; None = all valid). With
    return_residuals=True also returns (m, l) for cross-shard split-K
    combination (parallel/context.py)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bhsd->bhgs", qf, k.astype(jnp.float32))
    if kv_len is not None:
        mask = jnp.arange(S)[None, None, None, :] < kv_len[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    o_n = (o / l).reshape(B, Hq, D).astype(q.dtype)
    if return_residuals:
        return o_n, (m.reshape(B, Hq), l.reshape(B, Hq))
    return o_n


def chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    pos: jax.Array,
                    sm_scale: Optional[float] = None) -> jax.Array:
    """Reference positioned-chunk attention (offset-causal vs cache).

    q: [B, Hq, T, D] — a chunk of T query tokens whose row-b absolute
    positions are pos[b] .. pos[b]+T-1; k, v: [B, Hkv, S, D] — the FULL
    cache, whose rows [pos[b], pos[b]+T) were just written with this
    chunk's K/V.  Query t of row b attends cache columns <= pos[b] + t
    (its own prefix INCLUDING existing cache content), so one call serves
    mixed-depth serving slots; T == 1 degenerates to decode attention
    with kv_len = pos + 1 and pos == 0, T == S to plain causal prefill.
    Columns past each query's limit get exactly-zero softmax mass, so
    stale cache content beyond a row's frontier can never leak in.
    """
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, T, D)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qf, k.astype(jnp.float32))
    limit = pos[:, None, None, None, None] \
        + jnp.arange(T)[None, None, None, :, None]
    cols = jnp.arange(S)[None, None, None, None, :]
    s = jnp.where(cols <= limit, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgts,bhsd->bhgtd", p, v.astype(jnp.float32))
    return (o / l).reshape(B, Hq, T, D).astype(q.dtype)


def chunk_attention_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            pos: jax.Array,
                            sm_scale: Optional[float] = None,
                            block_k: int = 1024) -> jax.Array:
    """Flash-pattern positioned-chunk attention in pure jnp — the dry-run
    stand-in for the Pallas chunk kernel (same semantics as
    chunk_attention, O(T·block_k) live scores instead of the [T, S]
    matrix).  Mirrors attention_chunked's SPMD discipline: q heads padded
    to the model axis, scan carries and KV blocks pinned to
    (batch, model) so the online-softmax loop never re-gathers."""
    from repro.parallel.axes import axis_size, shard_dims
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    g = Hq // Hkv
    msize = axis_size("model")
    pad_h = (-Hq) % msize if msize > 1 else 0
    if pad_h:
        kr = k if g == 1 else jnp.repeat(k, g, axis=1)
        vr = v if g == 1 else jnp.repeat(v, g, axis=1)
        padded = [jnp.pad(t, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
                  for t in (q, kr, vr)]
        return chunk_attention_blocked(*padded, pos=pos, sm_scale=sm_scale,
                                       block_k=block_k)[:, :Hq]
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    scale = sm_scale if sm_scale is not None else D ** -0.5
    _c = lambda t: shard_dims(t, {0: "batch", 1: "model"})
    qf = _c(q.astype(jnp.float32) * scale)
    kr = k if g == 1 else jnp.repeat(k, g, axis=1)
    vr = v if g == 1 else jnp.repeat(v, g, axis=1)
    kb = _c(kr.reshape(B, Hq, nk, block_k, D))
    vb = _c(vr.reshape(B, Hq, nk, block_k, D))
    limit = pos[:, None] + jnp.arange(T)[None, :]          # [B, T]

    def body(carry, inp):
        m, l, acc = carry
        kk, vv, ik = inp
        m, l, acc = _c(m), _c(l), _c(acc)
        kk, vv = _c(kk), _c(vv)
        s = jnp.einsum("bhtd,bhkd->bhtk", qf, kk.astype(jnp.float32))
        cols = ik * block_k + jnp.arange(block_k)
        s = jnp.where(cols[None, None, None, :]
                      <= limit[:, None, :, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhtk,bhkd->bhtd", p, vv.astype(jnp.float32))
        return (_c(m_new), _c(l), _c(acc)), None

    m0 = _c(jnp.full((B, Hq, T), NEG_INF, jnp.float32))
    l0 = _c(jnp.zeros((B, Hq, T), jnp.float32))
    a0 = _c(jnp.zeros((B, Hq, T, D), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


def gather_kv_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialize a paged KV arena as per-row dense caches.

    pages: [P, Hkv, page_size, D] — the page arena (page 0 is the
    engine's reserved scratch page); block_table: [B, NB] int32 page ids,
    row b's virtual cache row being the concatenation of its NB pages.
    Returns [B, Hkv, NB*page_size, D].  Unassigned block-table entries
    point at page 0; whatever lives there is masked by pos/kv_len on
    every read path, so the gather never has to know the frontier."""
    g = pages[block_table]                       # [B, NB, Hkv, ps, D]
    B, NB, Hkv, ps, D = g.shape
    return jnp.moveaxis(g, 1, 2).reshape(B, Hkv, NB * ps, D)


def chunk_attention_paged(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, *, block_table: jax.Array,
                          pos: jax.Array,
                          sm_scale: Optional[float] = None) -> jax.Array:
    """Paged positioned-chunk attention oracle: gather the visible
    prefix's KV pages through the block table, then run the dense
    offset-causal reference.  q: [B, Hq, T, D]; k_pages/v_pages:
    [P, Hkv, page_size, D]; block_table: [B, NB]; pos: [B].  Numerically
    identical to chunk_attention over the equivalent contiguous cache:
    columns past pos[b] + t get exactly-zero softmax mass, so scratch-page
    content and ungranted pages can never leak in."""
    k = gather_kv_pages(k_pages, block_table)
    v = gather_kv_pages(v_pages, block_table)
    return chunk_attention(q, k, v, pos=pos, sm_scale=sm_scale)


def decode_attention_paged(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, *, block_table: jax.Array,
                           kv_len: Optional[jax.Array] = None,
                           sm_scale: Optional[float] = None) -> jax.Array:
    """Paged single-token decode oracle (gather pages, dense reference).

    q: [B, Hq, D]; k_pages/v_pages: [P, Hkv, page_size, D];
    block_table: [B, NB]; kv_len: [B] valid prefix lengths."""
    k = gather_kv_pages(k_pages, block_table)
    v = gather_kv_pages(v_pages, block_table)
    if kv_len is None:
        kv_len = jnp.full((q.shape[0],), k.shape[2], jnp.int32)
    return decode_attention(q, k, v, kv_len=kv_len, sm_scale=sm_scale)


def chunk_attention_paged_blocked(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array, *,
                                  block_table: jax.Array, pos: jax.Array,
                                  sm_scale: Optional[float] = None
                                  ) -> jax.Array:
    """Flash-pattern PAGED chunk attention in pure jnp — the dry-run
    stand-in for the Pallas paged kernel: one page gathered per scan
    step (never the whole [B, NB*ps] cache), online softmax carried
    across pages.  Block k IS the page: the kernel's KV grid dimension
    walks block-table slots, and this mirrors that blocking exactly."""
    B, Hq, T, D = q.shape
    P, Hkv, ps, _ = k_pages.shape
    NB = block_table.shape[1]
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g * T, D)
    limit = pos[:, None] + jnp.arange(T)[None, :]          # [B, T]
    limit = jnp.tile(limit, (1, g))                        # rows are (g, t)

    def body(carry, ik):
        m, l, acc = carry
        page_ids = block_table[:, ik]                      # [B]
        kk = k_pages[page_ids].astype(jnp.float32)         # [B, Hkv, ps, D]
        vv = v_pages[page_ids].astype(jnp.float32)
        s = jnp.einsum("bhtd,bhkd->bhtk", qf, kk)
        cols = ik * ps + jnp.arange(ps)
        s = jnp.where(cols[None, None, None, :]
                      <= limit[:, None, :, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhtk,bhkd->bhtd", p, vv)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, g * T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g * T), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g * T, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(NB))
    l = jnp.where(l == 0.0, 1.0, l)
    o = (acc / l[..., None]).reshape(B, Hkv, g, T, D)
    return o.reshape(B, Hq, T, D).astype(q.dtype)


def combine_decode_partials(o_parts, m_parts, l_parts):
    """Numerically-stable split-K combine of per-shard decode partials.

    o_parts: [K, B, H, D] unnormalized-then-normalized per-shard outputs
    (each o_k = softmax-local output), m/l: [K, B, H]. Standard flash-decode
    merge: rescale each shard by exp(m_k - m*) l_k and renormalize."""
    m_star = jnp.max(m_parts, axis=0)                       # [B, H]
    alpha = jnp.exp(m_parts - m_star[None])                 # [K, B, H]
    l_star = jnp.sum(alpha * l_parts, axis=0)               # [B, H]
    w = (alpha * l_parts) / l_star[None]
    return jnp.sum(o_parts * w[..., None], axis=0).astype(o_parts.dtype)


# -------------------------------------------------------------- rmsnorm ----
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    """y = x * rsqrt(mean(x^2) + eps) * w, reduction in f32."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


# ----------------------------------------------------------- mamba2 SSD ----
def ssd_naive(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
              c: jax.Array, *, h0: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Naive sequential Mamba2 SSD recurrence — the ground-truth oracle.

    x: [B, L, H, P]  inputs per head
    dt: [B, L, H]    step sizes (already softplus'd, >= 0)
    a: [H]           negative decay rates
    b, c: [B, L, N]  input/output projections (single group)
    h0: [B, H, N, P] initial state
    returns (y [B, L, H, P], h_final [B, H, N, P])
    """
    B, L, H, P = x.shape
    N = b.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)
    af = a.astype(jnp.float32)
    h = jnp.zeros((B, H, N, P), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp            # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(af[None, :] * dt_t)  # [B,H]
        dbx = jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t)
        h = decay[..., None, None] * h + dbx
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, h)
        return h, y_t

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, *, chunk: int = 128,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (Mamba2 'state-space dual' algorithm) in pure jnp.

    Mirrors the Pallas kernel's blocking exactly: within a chunk the output
    is a masked (C B^T ⊙ decay) @ (dt·x) matmul; across chunks a small state
    recurrence carries h. Validated against ssd_naive in tests."""
    B, L, H, P = x.shape
    N = b.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, H)
    bf = b.astype(jnp.float32).reshape(B, nc, chunk, N)
    cf = c.astype(jnp.float32).reshape(B, nc, chunk, N)
    af = a.astype(jnp.float32)

    ldec = af[None, None, None, :] * dtf                   # [B,nc,T,H]
    cum = jnp.cumsum(ldec, axis=2)                         # inclusive cumsum
    dtx = dtf[..., None] * xf                              # [B,nc,T,H,P]

    # intra-chunk: y[i] = sum_{j<=i} exp(cum[i]-cum[j]) (c_i . b_j) dtx[j]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [B,nc,T,T,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    g = jnp.einsum("bktn,bksn->bkts", cf, bf)              # [B,nc,T,T]
    y_intra = jnp.einsum("bkts,bktsh,bkshp->bkthp", g, m, dtx)

    # inter-chunk state recurrence over nc chunks
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [B,nc,H]
    # state contribution of chunk k: sum_j exp(cum[-1]-cum[j]) b_j ⊗ dtx[j]
    w = jnp.exp(cum[:, :, -1:, :] - cum)                   # [B,nc,T,H]
    s_in = jnp.einsum("bktn,bkth,bkthp->bkhnp", bf, w, dtx)

    h_init = (jnp.zeros((B, H, N, P), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def chunk_step(h, inp):
        dec_k, s_k = inp                                   # [B,H], [B,H,N,P]
        h_out = h                                          # state BEFORE chunk
        h = dec_k[..., None, None] * h + s_k
        return h, h_out

    dec_s = jnp.moveaxis(chunk_decay, 1, 0)
    sin_s = jnp.moveaxis(s_in, 1, 0)
    h_final, h_prevs = jax.lax.scan(chunk_step, h_init, (dec_s, sin_s))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # [B,nc,H,N,P]

    y_inter = jnp.einsum("bktn,bkth,bkhnp->bkthp",
                         cf, jnp.exp(cum), h_prevs)
    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y.astype(x.dtype), h_final


# --------------------------------------------------------------- matmul ----
def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """f32-accumulating matmul oracle for the tiled-matmul demo kernel."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(a.dtype)
