"""Version-compatibility shims for jax parallelism APIs.

Two renames happened after jax 0.4.37 (the pinned CI version):

  * `jax.experimental.shard_map.shard_map` graduated to `jax.shard_map`
  * its `check_rep` kwarg became `check_vma`

Call sites in this repo use the modern spelling (`shard_map(...,
check_vma=...)`) and import from here; on old jax the wrapper translates
the kwarg and routes to the experimental module.
"""

from __future__ import annotations

import functools

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)
