"""Training launcher: the production entry point.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --smoke --steps 50 [--mesh 4x2] [--resume]

On a real pod: omit --smoke, pass --mesh 16x16 (the process count must
match); this box runs the same code path on the smoke configs.
"""

from __future__ import annotations

import argparse

import jax

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.configs.base import TrainConfig
from repro.core.session import XFASession
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.parallel.axes import runtime_mesh
from repro.runtime.trainer import Trainer


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="", help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/train")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--profile-dir", default="",
                    help="register the run + write per-process XFA profile "
                         "snapshot rings here (reduce with: python -m "
                         "repro.profile report DIR; browse runs with: "
                         "python -m repro.profile query ROOT)")
    ap.add_argument("--profile-interval", type=int, default=0,
                    help="steps between snapshot-ring refreshes "
                         "(0: only at end)")
    ap.add_argument("--profile-keep-last", type=int, default=8,
                    help="snapshots kept per shard ring (0: unbounded)")
    ap.add_argument("--profile-max-age-s", type=float, default=0.0,
                    help="delete ring snapshots older than this (0: never)")
    ap.add_argument("--profile-max-bytes", type=int, default=0,
                    help="per-run-dir snapshot byte budget (0: unbounded)")
    from repro.profile import kv_pair
    ap.add_argument("--profile-meta", action="append", default=[],
                    type=kv_pair, metavar="KEY=VALUE",
                    help="extra run-manifest metadata (repeatable)")
    ap.add_argument("--xfa-collector", default="", metavar="HOST:PORT",
                    help="stream snapshot-ring deltas to a fleet collector "
                         "(python -m repro.profile collect); failures "
                         "degrade to the local ring, never kill the run")
    ap.add_argument("--xfa-host-label", default="",
                    help="override this process's host label in shard "
                         "names and manifests (default: hostname; tests "
                         "and multi-process-per-host fleets set it)")
    ap.add_argument("--xfa-budget-pct", type=float, default=0.0,
                    help="host-tracer overhead budget as a percent of wall "
                         "time (0: governor off, every boundary fully "
                         "timed); hot edges back off to 1-in-k timing "
                         "with unbiased scale-up, counting stays exact")
    args = ap.parse_args()

    if args.xfa_host_label:
        from repro.profile import set_host_label
        set_host_label(args.xfa_host_label)
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(shape)]
        mesh = jax.make_mesh(shape, axes)

    model = build_model(cfg, impl="auto")
    tcfg = TrainConfig(total_steps=args.steps, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 10, 1),
                       microbatches=args.microbatches,
                       ckpt_interval=args.ckpt_interval,
                       xfa_overhead_budget=args.xfa_budget_pct / 100.0)
    from repro.profile import RetentionPolicy
    trainer = Trainer(model, tcfg,
                      CheckpointManager(args.ckpt_dir, async_save=True),
                      session=XFASession(device_spec=model.fold_spec),
                      profile_dir=args.profile_dir or None,
                      profile_interval=args.profile_interval,
                      profile_retention=RetentionPolicy(
                          keep_last=args.profile_keep_last,
                          max_age_s=args.profile_max_age_s,
                          max_bytes=args.profile_max_bytes),
                      profile_meta=dict(args.profile_meta),
                      xfa_collector=args.xfa_collector)
    data = SyntheticLMData(cfg, args.batch, args.seq)
    with runtime_mesh(mesh):
        state, metrics = trainer.run(jax.random.key(0), data, args.steps,
                                     resume=args.resume)
    print(f"done: {metrics}")
    print(trainer.session.report().render(components=("app",)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
