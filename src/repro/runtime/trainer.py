"""Training driver: step construction + the fault-tolerant run loop.

make_train_step builds the single jitted SPMD step used by the trainer, the
dry-run and the benchmarks — ONE code path from smoke test to 512 chips:

  (train_state, batch, fold_table) -> (train_state, metrics, fold_table)

with gradient microbatching (accumulation), optional int8 error-feedback
gradient compression, AdamW, and the XFA device fold threaded through.

Trainer.run is the production loop: prefetching data, dispatch, periodic
(async) checkpointing, heartbeats, straggler folds, and crash-restart
(resume_from_latest). Failures are injected/simulated in tests via
runtime.fault_tolerance.SimulatedCluster.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.core import tracer as xfa
from repro.core.session import XFASession
from repro.data.pipeline import SyntheticLMData
from repro.models.api import Model
from repro.optim import adamw
from repro.parallel.axes import get_runtime_mesh, named_sharding
from repro.parallel.sharding import sharding_tree, spec_tree


def init_train_state(model: Model, key, tcfg: TrainConfig) -> Dict[str, Any]:
    params = model.init(key)
    state: Dict[str, Any] = {"params": params,
                             "opt": adamw.init_state(params)}
    if tcfg.grad_compression == "int8":
        state["grad_err"] = adamw.init_error_state(params)
    return state


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Build the jittable step. Microbatching splits the batch on axis 0 and
    accumulates grads in f32 (a scan, so the HLO stays small)."""

    def loss_wrapper(params, batch, table):
        return model.loss_fn(params, batch, table)

    def step(state, batch, table):
        params = state["params"]
        n_micro = tcfg.microbatches

        def split(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

        if n_micro <= 1:
            (loss, (metrics, table)), grads = jax.value_and_grad(
                loss_wrapper, has_aux=True)(params, batch, table)
        elif tcfg.deferred_grad_reduce:
            # OPTIMIZED accumulation: differentiate THROUGH the microbatch
            # scan. The backward scan accumulates weight grads in its carry
            # as device-local partials, so the data-axis gradient all-reduce
            # is emitted ONCE after the loop instead of once per microbatch
            # (pjit otherwise reduces inside every iteration) — wire bytes
            # / n_micro. The body is rematted so activations stay per-micro.
            micro = jax.tree.map(split, batch)

            def mean_loss(params, micro, table):
                def body(carry, mb):
                    loss_acc, table = carry
                    l, (m, table) = model.loss_fn(params, mb, table)
                    return (loss_acc + l / n_micro, table), m

                body = jax.checkpoint(body)
                with jax.named_scope("grads"):
                    (loss, table), ms = jax.lax.scan(
                        body, (jnp.float32(0.0), table), micro)
                return loss, (jax.tree.map(lambda x: x[-1], ms), table)

            (loss, (metrics, table)), grads = jax.value_and_grad(
                mean_loss, has_aux=True)(params, micro, table)
            metrics["loss"] = loss
        else:
            # paper-faithful baseline: grad-per-microbatch, reduced each time
            micro = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_body(carry, mb):
                g_acc, table, loss_acc = carry
                (loss, (m, table)), g = jax.value_and_grad(
                    loss_wrapper, has_aux=True)(params, mb, table)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_micro,
                    g_acc, g)
                return (g_acc, table, loss_acc + loss / n_micro), m

            with jax.named_scope("grads"):
                (grads, table, loss), ms = jax.lax.scan(
                    acc_body, (zero_g, table, jnp.float32(0.0)), micro)
            metrics = jax.tree.map(lambda x: x[-1], ms)
            metrics["loss"] = loss

        # pin grads to the PARAMS' natural sharding: without this, GSPMD
        # folds the ZeRO-1 (data-axis) resharding INTO the dw dots and
        # all-gathers the full f32 activations instead (measured: 220 GB/step
        # of [B,S,d] gathers on deepseek train_4k — EXPERIMENTS.md §Perf).
        # The explicit boundary reshards only the (much smaller) grads.
        from repro.parallel.axes import get_runtime_mesh
        from repro.parallel.sharding import sharding_tree
        mesh = get_runtime_mesh()
        if mesh is not None and tcfg.zero1:
            nat = sharding_tree(params, mesh, fsdp=False)
            with jax.named_scope("grads"):
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    grads, nat)

        new_state = dict(state)
        if tcfg.grad_compression == "int8":
            with jax.named_scope("grads"):
                grads, new_err = adamw.compress_grads_with_feedback(
                    grads, state["grad_err"])
                new_state["grad_err"] = new_err

        new_params, new_opt, opt_metrics = adamw.apply_updates(
            params, state["opt"], grads, tcfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        if model.fold_spec is not None:
            table = model.fold_spec.emit(table, "app", "loss", "train_step",
                                         "count", 1.0)
        return new_state, metrics, table

    return step


def state_shardings(state_like, mesh, zero1: bool = True):
    """NamedShardings for the train state: params by rule table; optimizer
    master/moments additionally sharded over 'data' (ZeRO-1)."""
    if mesh is None:
        return None
    out = {}
    out["params"] = sharding_tree(state_like["params"], mesh, fsdp=False)
    zshard = lambda t: sharding_tree(t, mesh, fsdp=zero1)
    out["opt"] = {
        "master": zshard(state_like["opt"]["master"]),
        "mu": zshard(state_like["opt"]["mu"]),
        "nu": zshard(state_like["opt"]["nu"]),
        "step": named_sharding(),
    }
    if "grad_err" in state_like:
        out["grad_err"] = zshard(state_like["grad_err"])
    return out


def batch_shardings(batch_like, mesh):
    if mesh is None:
        return None
    return jax.tree.map(lambda _: named_sharding("batch"), batch_like)


@dataclasses.dataclass
class Trainer:
    model: Model
    tcfg: TrainConfig
    ckpt: CheckpointManager
    session: Optional[XFASession] = None
    #: when set, this process registers the run in `profile_dir`'s manifest
    #: and writes a ring of sequence-numbered profile snapshots there; all
    #: ranks/hosts reduce offline via `python -m repro.profile`, and the
    #: ring is the input to the `timeline` drift view.
    profile_dir: Optional[str] = None
    #: steps between shard refreshes; 0 -> only the final shard at run end
    profile_interval: int = 0
    #: snapshot-ring retention (repro.profile.RetentionPolicy); None keeps
    #: the store default (keep-last 8 per shard, no age/byte bound)
    profile_retention: Optional[Any] = None
    #: extra key=value metadata for the run manifest (experiment name, ...)
    profile_meta: Optional[Dict[str, Any]] = None
    #: collector address 'HOST:PORT' — when set (and profile_dir is set),
    #: every shard refresh also streams the ring's unacked entries to the
    #: fleet collector (repro.profile.FleetPublisher).  Publish failures
    #: degrade to local-only rings; they never interrupt the train loop.
    xfa_collector: str = ""

    def __post_init__(self):
        if self.session is None:
            self.session = XFASession(device_spec=self.model.fold_spec)
        if self.tcfg.xfa_overhead_budget > 0:
            # adaptive overhead governor: hot boundaries back off to 1-in-k
            # timing (counting stays exact) when estimated tracer overhead
            # crosses the budget (core.sampler)
            xfa.TRACER.set_overhead_budget(self.tcfg.xfa_overhead_budget)
        self._profile_store = None
        self._publisher = None
        if self.profile_dir:
            from repro.profile import ProfileStore
            self._profile_store = ProfileStore(
                self.profile_dir, retention=self.profile_retention)
            if self.xfa_collector:
                from repro.profile import FleetPublisher
                self._publisher = FleetPublisher(self.xfa_collector,
                                                 self.profile_dir)

    def _register_run(self, n_steps: int) -> None:
        """Write/merge this rank into the run manifest (the registry index:
        `python -m repro.profile query` filters on these fields)."""
        if self._profile_store is None:
            return
        from repro.profile import register_run
        mesh = get_runtime_mesh()
        cfg = self.model.cfg
        register_run(
            self.profile_dir,
            config=cfg.name, arch=cfg.family,
            mesh_shape=tuple(mesh.devices.shape) if mesh is not None else None,
            mesh_axes=tuple(mesh.axis_names) if mesh is not None else None,
            label=f"train-r{jax.process_index()}", kind="train",
            meta={"n_steps_planned": n_steps,
                  "microbatches": self.tcfg.microbatches,
                  **(self.profile_meta or {})})

    def _write_profile_shard(self, step: int) -> None:
        if self._profile_store is None:
            return
        # device/static folds are replicated across SPMD ranks — only rank 0
        # shards them, or the cross-rank reduce would count them per rank
        rank0 = jax.process_index() == 0
        with xfa.scope("runtime", "profile_snapshot"):
            self._profile_store.write_shard(
                self.session.folded_all(include_replicated=rank0),
                label=f"train-r{jax.process_index()}",
                meta={"step": step, "n_steps": self.session.n_steps,
                      "wall_ns": self.session.wall_ns,
                      "rank": jax.process_index()})
        if self._publisher is not None:
            # local ring first, then stream the delta; a dead collector
            # costs one rate-limited connect attempt, nothing else
            with xfa.scope("runtime", "profile_publish"):
                self._publisher.publish()

    @xfa.api("runtime", "compile_step")
    def _compile(self, step_fn, state, batch, table):
        mesh = get_runtime_mesh()
        if mesh is None:
            return jax.jit(step_fn, donate_argnums=(0,))
        ss = state_shardings(state, mesh, self.tcfg.zero1)
        bs = batch_shardings(batch, mesh)
        ts = named_sharding()
        return jax.jit(step_fn, in_shardings=(ss, bs, ts),
                       out_shardings=(ss, None, ts), donate_argnums=(0,))

    def run(self, key, data: SyntheticLMData, n_steps: int,
            resume: bool = True, state: Optional[Dict] = None
            ) -> Tuple[Dict, Dict[str, float]]:
        """The loop: data -> dispatch -> fold -> ckpt -> heartbeat."""
        model, tcfg = self.model, self.tcfg
        step_fn = make_train_step(model, tcfg)
        start_step = 0

        if state is None:
            with xfa.scope("runtime", "init_state"):
                state = init_train_state(model, key, tcfg)
            if resume:
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state, extra = self.ckpt.restore(state)
                    start_step = int(extra.get("next_step", latest + 1))

        self._register_run(n_steps)
        table = model.table()
        compiled = self._compile(step_fn, state, data.generate(0), table)
        data.start(at_step=start_step)
        last_metrics: Dict[str, float] = {}

        for step in range(start_step, n_steps):
            batch = next(data)
            t0 = time.perf_counter_ns()
            with xfa.scope("runtime", "dispatch_step"):
                state, metrics, table = compiled(state, batch, table)
            with xfa.scope("runtime", "device_sync", xfa.KIND_WAIT):
                jax.block_until_ready(metrics["loss"])
            self.session.observe_step(time.perf_counter_ns() - t0)

            if tcfg.ckpt_interval and (step + 1) % tcfg.ckpt_interval == 0:
                self.ckpt.save(step, state, extra={"next_step": step + 1})

            if self.profile_interval and \
                    (step + 1) % self.profile_interval == 0:
                self._write_profile_shard(step + 1)

            last_metrics = {k: float(v) for k, v in metrics.items()}

        data.stop()
        self.ckpt.wait()
        self.session.finish_device(table)
        # final shard includes the device fold fetched above
        self._write_profile_shard(n_steps)
        if self._publisher is not None:
            self._publisher.close()
        return state, last_metrics
