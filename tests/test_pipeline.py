"""GPipe pipeline (parallel/pipeline.py): output and gradient equivalence
with the sequential stage composition, on a 4-stage subprocess mesh."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import (bubble_fraction, gpipe_apply,
                                         split_stages)

    mesh = jax.make_mesh((4,), ("stage",))
    S, M, B, D = 4, 6, 2, 16
    rng = np.random.default_rng(0)
    # 8 layers -> 4 stages x 2 layers; each layer: x -> tanh(x @ w)
    layer_w = jnp.asarray(rng.standard_normal((8, D, D)) * 0.3, jnp.float32)
    mbs = jnp.asarray(rng.standard_normal((M, B, D)), jnp.float32)

    def stage_fn(w_stack, x):          # w_stack: [2, D, D]
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, w_stack)
        return y

    stages = split_stages({"w": layer_w}, 4)

    def pipelined(w8, mbs):
        st = split_stages({"w": w8}, 4)
        return gpipe_apply(lambda p, x: stage_fn(p["w"], x), st, mbs, mesh)

    def sequential(w8, mbs):
        def per_mb(x):
            return stage_fn(w8, x)
        return jax.vmap(per_mb)(mbs)

    y_pipe = jax.jit(pipelined)(layer_w, mbs)
    y_seq = sequential(layer_w, mbs)
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                               atol=1e-5, rtol=1e-5)

    # gradient THROUGH the pipeline (scan + ppermute are differentiable)
    g_pipe = jax.grad(lambda w: jnp.sum(jnp.sin(pipelined(w, mbs))))(layer_w)
    g_seq = jax.grad(lambda w: jnp.sum(jnp.sin(sequential(w, mbs))))(layer_w)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               atol=1e-4, rtol=1e-4)

    assert abs(bubble_fraction(4, 6) - 3 / 9) < 1e-9
    print("OK")
""")


@pytest.mark.slow
def test_gpipe_equivalence_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=400,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2500:]
    assert "OK" in proc.stdout
