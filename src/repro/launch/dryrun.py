import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

The two lines above run BEFORE any other import (jax locks the device count
at first init). Do NOT import this module from tests — run it as
`python -m repro.launch.dryrun --arch <id> --shape <name> [--multi-pod]`.

Per cell, the dry-run records to artifacts/dryrun/<cell>.json:
  * memory_analysis()  — bytes/device: proves the cell fits 16 GB HBM
  * cost_analysis()    — HLO FLOPs + bytes accessed (per-device, post-SPMD)
  * the collective schedule (kind, scope, mesh axis, wire bytes) parsed from
    the optimized HLO by the XFA static layer (core.hlo_flows)
  * the three roofline terms in seconds + the dominant term
  * MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the useful-compute ratio
"""

import argparse  # noqa: E402
import json      # noqa: E402
import sys       # noqa: E402
import time      # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402

from repro.configs import SHAPES, get_config           # noqa: E402
from repro.configs.base import TrainConfig             # noqa: E402
from repro.core.device_fold import STATIC_COSTS        # noqa: E402
from repro.core.hlo_analysis import (analyze_module,   # noqa: E402
                                     xla_cost_analysis)
from repro.core.session import KNOWN_COMPONENTS        # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW,         # noqa: E402
                               PEAK_FLOPS_BF16, make_production_mesh,
                               mesh_axis_sizes)
from repro.launch.specs import build_cell, cell_is_applicable  # noqa: E402
from repro.parallel.axes import runtime_mesh           # noqa: E402


#: --dp-only: small models should not be tensor-parallel — fold the model
#: axis into data parallelism (params replicated, 256-way DP, ZeRO-1 state)
DP_ONLY_RULES = {"batch": ("pod", "data", "model"), "model": (),
                 "expert": (), "vocab": (), "kv_seq": ()}


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             out_dir: str = "artifacts/dryrun",
             overrides: dict | None = None,
             tcfg: TrainConfig | None = None,
             tag: str = "", rules: dict | None = None) -> dict:
    cfg = get_config(arch)
    import dataclasses
    # dry-run default: full remat (save only layer inputs). dots_saveable
    # would stack every chunked-attention dot residual per layer — measured
    # +40 GiB/device on tinyllama train_4k (EXPERIMENTS.md §Perf).
    cfg = dataclasses.replace(cfg, remat="full")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if not cell_is_applicable(cfg, shape):
        return {"cell": f"{cfg.name}:{shape.name}", "skipped": True,
                "reason": "long_500k needs sub-quadratic attention"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = mesh_axis_sizes(mesh)
    n_chips = mesh.devices.size
    t0 = time.time()
    if rules is None and getattr(cfg, "prefer_dp_only", False) \
            and shape.kind == "train" \
            and shape.global_batch % n_chips == 0:
        # pure DP needs batch >= devices; on the 512-chip mesh batch 256
        # keeps TP (the pod axis still composes with data)
        rules = DP_ONLY_RULES

    with runtime_mesh(mesh, rules):
        cell = build_cell(cfg, shape, mesh, tcfg=tcfg)
        # one clean abstract trace for the XFA static layer: exact analytic
        # kernel FLOPs/HBM-bytes with scan multiplicity (the trace IS the
        # count — no runtime representation needed)
        STATIC_COSTS.reset()
        jax.eval_shape(cell.fn, *cell.args)
        static = STATIC_COSTS.as_folded()
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    kernel_bytes_global = sum(e.metrics.get("bytes", 0.0)
                              for e in static.edges.values())
    kernel_flops_global = sum(e.metrics.get("flops", 0.0)
                              for e in static.edges.values())

    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # loop-aware static analysis (core.hlo_analysis): XLA's cost_analysis
    # counts while bodies ONCE; scan-over-layers models need trip-count-
    # aware totals for FLOPs / bytes / collective wire traffic.
    mc = analyze_module(hlo, KNOWN_COMPONENTS, sizes)

    flops_dev = float(mc.flops)
    # memory model: loop-aware HLO buffer writes OUTSIDE kernel loops (VMEM-
    # internal tiles excluded) + the kernels' analytic HBM traffic (XFA
    # static layer), which the Pallas kernels touch exactly once
    bytes_dev = float(mc.io_bytes) + kernel_bytes_global / n_chips
    wire_dev = float(mc.wire_bytes)

    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_collective = wire_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)

    # useful-FLOPs ratio: 6ND for train, 2·N_active·tokens for serving steps
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        model_flops = 6.0 * n_act * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_act * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_act * shape.global_batch
    model_flops_dev = model_flops / n_chips
    ratio = model_flops_dev / flops_dev if flops_dev else 0.0
    bound = max(terms.values())
    roofline_fraction = (model_flops_dev / PEAK_FLOPS_BF16) / bound \
        if bound else 0.0

    record = {
        "cell": f"{cfg.name}:{shape.name}",
        "tag": tag,
        "mesh": {"shape": list(mesh.devices.shape),
                 "axes": list(mesh.axis_names)},
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "cost_analysis": {
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "hlo_io_bytes_per_device": float(mc.io_bytes),
            "kernel_bytes_per_device": kernel_bytes_global / n_chips,
            "static_kernel_flops_per_device": kernel_flops_global / n_chips,
            "xla_flops_body_once": float(cost.get("flops", 0.0)),
            "xla_bytes_body_once": float(cost.get("bytes accessed", 0.0)),
            "analyzer_flops_body_once": mc.flops_body_once,
        },
        "collectives": {
            "wire_bytes_per_device": wire_dev,
            "by_kind": mc.by_kind_wire,
            "by_axis": mc.by_axis_wire,
            "by_component": mc.by_component_wire,
            "count": mc.n_collectives,
            "schedule_head": mc.collectives[:40],
        },
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_global": model_flops,
            "useful_flops_ratio": ratio,
            "roofline_fraction": roofline_fraction,
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = "multipod" if multi_pod else "pod"
    tagpart = f"_{tag}" if tag else ""
    fname = f"{arch}_{shape_name}_{suffix}{tagpart}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default="",
                    help="comma k=v model-config overrides (perf loop)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--deferred-grads", action="store_true")
    ap.add_argument("--dp-only", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override.split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v == "True":
            v = True
        if v == "False":
            v = False
        overrides[k] = v

    tcfg = TrainConfig(microbatches=args.microbatches,
                       zero1=not args.no_zero1,
                       grad_compression=args.grad_compression,
                       deferred_grad_reduce=args.deferred_grads)
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                   overrides or None, tcfg, args.tag,
                   rules=DP_ONLY_RULES if args.dp_only else None)
    if rec.get("skipped"):
        print(f"SKIP {rec['cell']}: {rec['reason']}")
        return 0
    print(f"OK {rec['cell']} mesh={rec['mesh']['shape']} "
          f"compile={rec['compile_s']}s")
    ma = rec["memory_analysis"]
    print(f"  memory/device: args={ma['argument_bytes']/2**30:.2f}GiB "
          f"temp={ma['temp_bytes']/2**30:.2f}GiB "
          f"peak={ma['peak_bytes']/2**30:.2f}GiB")
    ca = rec["cost_analysis"]
    ro = rec["roofline"]
    print(f"  flops/dev={ca['flops_per_device']:.3e} "
          f"bytes/dev={ca['bytes_per_device']:.3e} "
          f"wire/dev={rec['collectives']['wire_bytes_per_device']:.3e}")
    print(f"  roofline: compute={ro['compute_s']*1e3:.2f}ms "
          f"memory={ro['memory_s']*1e3:.2f}ms "
          f"collective={ro['collective_s']*1e3:.2f}ms "
          f"dominant={ro['dominant']} "
          f"useful_ratio={ro['useful_flops_ratio']:.2f} "
          f"roofline_frac={ro['roofline_fraction']:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
