"""Model zoo: pure-functional JAX model families behind api.build_model."""
from .api import Model, build_model
from .layers import Runtime
