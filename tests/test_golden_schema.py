"""Golden-file pins of snapshot schemas v1, v2 and v3.

`tests/data/golden_v1.xfa.npz` (hist-less), `golden_v2.xfa.npz` (same
table + latency histograms) and `golden_v3.xfa.npz` (v2 + governor
sampling rates) are tiny reference snapshots checked into the repo
(uncompressed, fixed zip metadata — see snapshot._write_npz).  These
tests assert that loading each, reporting over it, and re-saving it
reproduces the file byte-for-byte — and that the writer still emits the
exact v1/v2 layouts for content without rates/histograms (the
minimal-schema rule, docs/schema.md).  If any of them fail after a
change to snapshot.py, the on-disk layout moved: either restore
compatibility or bump SCHEMA_VERSION, regenerate the goldens (run this
file as a script), and say so loudly in the PR — schema bumps must be
deliberate, never a side effect.
"""

import os

import numpy as np
import pytest

from conftest import assert_tables_equal
from repro.core.folding import EdgeStats, FoldedTable
from repro.core.histogram import hist_of
from repro.core.views import (component_view, render_flow_matrix,
                              render_percentiles, render_sampling)
from repro.profile import ProfileSnapshot
from repro.profile.snapshot import SCHEMA_VERSION

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "golden_v1.xfa.npz")
GOLDEN_V2 = os.path.join(os.path.dirname(__file__), "data",
                         "golden_v2.xfa.npz")
GOLDEN_V3 = os.path.join(os.path.dirname(__file__), "data",
                         "golden_v3.xfa.npz")


def golden_table() -> FoldedTable:
    """The reference profile: exercises kinds, wait edges, child_ns, the
    min_ns sentinel (count-0 edge), metric presence and an explicit 0.0
    metric — every v1 field with fixed values."""
    t = FoldedTable(group="golden")
    t.edges[("app", "glibc", "read")] = EdgeStats(
        count=3, total_ns=220, child_ns=20, min_ns=18, max_ns=120)
    t.edges[("app", "glibc", "write")] = EdgeStats(
        count=1, total_ns=35, child_ns=0, min_ns=35, max_ns=35)
    t.edges[("moe", "pthread", "lock")] = EdgeStats(
        count=2, total_ns=900, child_ns=0, min_ns=400, max_ns=500,
        kind=1)  # KIND_WAIT
    t.edges[("app", "moe", "dispatch")] = EdgeStats(   # metrics-only edge
        metrics={"flops": 1e9, "bytes": 0.0})
    t.edges[("optimizer", "alloc", "malloc")] = EdgeStats(
        count=5, total_ns=50, child_ns=5, min_ns=2, max_ns=30,
        metrics={"bytes": 4096.0})
    return t


GOLDEN_META = {"label": "golden", "note": "schema v1 reference"}
GOLDEN_V2_META = {"label": "golden", "note": "schema v2 reference"}
GOLDEN_V3_META = {"label": "golden", "note": "schema v3 reference"}


def golden_table_v2() -> FoldedTable:
    """The v1 reference table plus latency histograms on two edges —
    fixed durations so the bucket counts (and the file bytes) are
    reproducible from source."""
    t = golden_table()
    t.edges[("app", "glibc", "read")].hist = hist_of([18, 82, 120])
    t.edges[("moe", "pthread", "lock")].hist = hist_of([400, 500])
    return t


def golden_table_v3() -> FoldedTable:
    """The v2 reference table plus governor sampling rates on two edges
    (one of them also histogrammed) — exact binary fractions so the
    float64 column bytes are reproducible from source."""
    t = golden_table_v2()
    t.edges[("app", "glibc", "read")].sample_rate = 0.25
    t.edges[("optimizer", "alloc", "malloc")].sample_rate = 0.5
    return t


def write_golden(path: str = GOLDEN) -> str:
    snap = ProfileSnapshot.from_folded(golden_table(), meta=GOLDEN_META)
    return snap.save(path, compress=False)


def write_golden_v2(path: str = GOLDEN_V2) -> str:
    snap = ProfileSnapshot.from_folded(golden_table_v2(),
                                       meta=GOLDEN_V2_META)
    return snap.save(path, compress=False)


def write_golden_v3(path: str = GOLDEN_V3) -> str:
    snap = ProfileSnapshot.from_folded(golden_table_v3(),
                                       meta=GOLDEN_V3_META)
    return snap.save(path, compress=False)


class TestGoldenSchemaV1:
    def test_schema_version_is_v3(self):
        # regenerating the goldens on a bump is a DELIBERATE step; this
        # makes `SCHEMA_VERSION += 1` fail tests until someone does it
        assert SCHEMA_VERSION == 3, \
            "schema bumped: regenerate tests/data/golden_v*.xfa.npz " \
            "(python tests/test_golden_schema.py) and update this test"

    def test_load_matches_reference_content(self):
        snap = ProfileSnapshot.load(GOLDEN)
        assert snap.schema == 1
        assert snap.meta == GOLDEN_META
        assert_tables_equal(snap.to_folded(), golden_table())

    def test_report_views_render(self):
        folded = ProfileSnapshot.load(GOLDEN).to_folded()
        out = component_view(folded, "app").render()
        assert "Component view: app" in out
        moe = component_view(folded, "moe").render()
        assert "Wait" in moe                      # the KIND_WAIT edge shows
        assert "Flow matrix" in render_flow_matrix(folded)

    def test_resave_is_byte_stable(self, tmp_path):
        """load -> save must be the identity on bytes: key order, string
        interning, header json, zip member metadata are all pinned."""
        snap = ProfileSnapshot.load(GOLDEN)
        out = str(tmp_path / "resaved.xfa.npz")
        snap.save(out, compress=False)
        with open(GOLDEN, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read(), \
                "snapshot v1 byte layout changed — bump SCHEMA_VERSION " \
                "and regenerate the golden if this was intentional"

    def test_fresh_build_matches_golden_bytes(self, tmp_path):
        """Rebuilding the reference table from source produces the exact
        checked-in bytes (writer determinism, not just reader identity)."""
        out = write_golden(str(tmp_path / "rebuilt.xfa.npz"))
        with open(GOLDEN, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read()

    def test_compressed_save_is_deterministic(self, tmp_path):
        """Same content -> same bytes for the default compressed writer
        (fixed zip timestamps); lets shard refreshes be content-compared."""
        snap = ProfileSnapshot.load(GOLDEN)
        p1 = str(tmp_path / "a.xfa.npz")
        p2 = str(tmp_path / "b.xfa.npz")
        snap.save(p1)
        snap.save(p2)
        with open(p1, "rb") as a, open(p2, "rb") as b:
            assert a.read() == b.read()

    def test_golden_loads_via_np_load_contract(self):
        """The file stays a plain npz (np.load-readable) — external tooling
        reads snapshots without repro installed."""
        with np.load(GOLDEN) as z:
            assert "__header__" in z and "count" in z
            assert z["count"].dtype == np.int64
            assert z["kind"].dtype == np.int8
            assert z["metric_values"].dtype == np.float64

    def test_histless_writer_emits_v1_layout(self, tmp_path):
        """The minimal-schema rule: content without histograms (or
        sampling rates) serializes as a schema-1 file even under the v3
        writer, so hist-less shards stay readable by schema-1-only
        readers."""
        out = str(tmp_path / "histless.xfa.npz")
        ProfileSnapshot.from_folded(golden_table()).save(out)
        with np.load(out) as z:
            assert "hist" not in z.files
            assert "sample_rate" not in z.files
        assert ProfileSnapshot.load(out).schema == 1


class TestGoldenSchemaV2:
    def test_load_matches_reference_content(self):
        snap = ProfileSnapshot.load(GOLDEN_V2)
        assert snap.schema == 2
        assert snap.meta == GOLDEN_V2_META
        assert_tables_equal(snap.to_folded(), golden_table_v2())

    def test_resave_is_byte_stable(self, tmp_path):
        snap = ProfileSnapshot.load(GOLDEN_V2)
        out = str(tmp_path / "resaved.xfa.npz")
        snap.save(out, compress=False)
        with open(GOLDEN_V2, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read(), \
                "snapshot v2 byte layout changed — bump SCHEMA_VERSION " \
                "and regenerate the golden if this was intentional"

    def test_fresh_build_matches_golden_bytes(self, tmp_path):
        out = write_golden_v2(str(tmp_path / "rebuilt.xfa.npz"))
        with open(GOLDEN_V2, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read()

    def test_hist_block_np_load_contract(self):
        """`hist` is a plain uint64 [N, 160] member, zero row == absent."""
        with np.load(GOLDEN_V2) as z:
            assert z["hist"].dtype == np.uint64
            assert z["hist"].shape == (len(z["count"]), 160)
            # 2 of the 5 reference edges carry a distribution
            assert int((z["hist"].sum(axis=1) > 0).sum()) == 2

    def test_percentiles_render_from_golden(self):
        folded = ProfileSnapshot.load(GOLDEN_V2).to_folded()
        out = render_percentiles(folded)
        assert "Latency percentiles" in out
        assert "glibc.read" in out and "pthread.lock" in out

    def test_v1_loads_and_merges_under_v2_reader(self):
        """Forward compat: a v1 file loads, reports, and merges with a v2
        file — the hist-less side simply contributes no buckets."""
        v1 = ProfileSnapshot.load(GOLDEN)
        v2 = ProfileSnapshot.load(GOLDEN_V2)
        assert v1.columns.hist is None
        assert "Component view: app" in \
            component_view(v1.to_folded(), "app").render()
        merged = ProfileSnapshot.merge([v1, v2]).to_folded()
        # same key set folded at double the counts...
        read = merged.edges[("app", "glibc", "read")]
        assert read.count == 2 * golden_table().edges[
            ("app", "glibc", "read")].count
        # ...but the histogram holds only the v2 side's samples
        assert read.hist is not None and int(read.hist.sum()) == 3
        assert merged.edges[("app", "glibc", "write")].hist is None


class TestGoldenSchemaV3:
    def test_load_matches_reference_content(self):
        snap = ProfileSnapshot.load(GOLDEN_V3)
        assert snap.schema == 3
        assert snap.meta == GOLDEN_V3_META
        assert_tables_equal(snap.to_folded(), golden_table_v3())

    def test_resave_is_byte_stable(self, tmp_path):
        snap = ProfileSnapshot.load(GOLDEN_V3)
        out = str(tmp_path / "resaved.xfa.npz")
        snap.save(out, compress=False)
        with open(GOLDEN_V3, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read(), \
                "snapshot v3 byte layout changed — bump SCHEMA_VERSION " \
                "and regenerate the golden if this was intentional"

    def test_fresh_build_matches_golden_bytes(self, tmp_path):
        out = write_golden_v3(str(tmp_path / "rebuilt.xfa.npz"))
        with open(GOLDEN_V3, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read()

    def test_rate_column_np_load_contract(self):
        """`sample_rate` is a plain float64 [N] member, 1.0 == fully
        sampled for that edge."""
        with np.load(GOLDEN_V3) as z:
            assert z["sample_rate"].dtype == np.float64
            assert z["sample_rate"].shape == (len(z["count"]),)
            # 2 of the 5 reference edges are subsampled
            assert int((z["sample_rate"] < 1.0).sum()) == 2

    def test_rateless_writer_emits_v2_layout(self, tmp_path):
        """Minimal-schema rule, one level up: histogrammed content
        without rates serializes as schema 2 — and its bytes equal the
        checked-in v2 golden."""
        out = str(tmp_path / "rateless.xfa.npz")
        ProfileSnapshot.from_folded(golden_table_v2(),
                                    meta=GOLDEN_V2_META).save(
            out, compress=False)
        assert ProfileSnapshot.load(out).schema == 2
        with open(GOLDEN_V2, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read()

    def test_all_full_rates_shed_the_column(self, tmp_path):
        """A rate column that normalized back to all-1.0 (e.g. after a
        merge dominated by fully-sampled shards) writes as rate-less
        content — None and 1.0 are the same fact on disk too."""
        t = golden_table_v2()
        cols = t.to_columns()
        cols.sample_rate = np.ones(len(cols), dtype=np.float64)
        out = str(tmp_path / "full.xfa.npz")
        ProfileSnapshot(cols).save(out)
        with np.load(out) as z:
            assert "sample_rate" not in z.files
        assert ProfileSnapshot.load(out).schema == 2

    def test_sampling_renders_from_golden(self):
        folded = ProfileSnapshot.load(GOLDEN_V3).to_folded()
        out = render_sampling(folded)
        assert "Sampling back-off" in out
        assert "glibc.read" in out and "alloc.malloc" in out
        # fully-sampled profiles render nothing (report stays v1-clean)
        assert render_sampling(ProfileSnapshot.load(GOLDEN).to_folded()) \
            == ""

    def test_v2_merges_with_v3_under_v3_reader(self):
        """Forward compat: merging a rate-less v2 profile into a v3 one
        count-weights the rate-less side at 1.0."""
        v2 = ProfileSnapshot.load(GOLDEN_V2)
        v3 = ProfileSnapshot.load(GOLDEN_V3)
        merged = ProfileSnapshot.merge([v2, v3]).to_folded()
        read = merged.edges[("app", "glibc", "read")]
        # 3 full-rate counts + 3 counts at 0.25 -> (3*1 + 3*0.25)/6
        assert read.sample_rate == pytest.approx(0.625)
        assert merged.edges[("app", "glibc", "write")].sample_rate is None


if __name__ == "__main__":  # regenerate the goldens after a DELIBERATE bump
    print("wrote", write_golden())
    print("wrote", write_golden_v2())
    print("wrote", write_golden_v3())