"""deepseek-v2-lite-16b — MLA (kv_lora=512) + fine-grained MoE
[arXiv:2405.04434]. 64 routed experts top-6 + 2 shared, first layer dense
(d_ff=10944), expert d_ff=1408. The assignment line's "160 routed" conflicts
with its own "MoE 64e top-6"; we follow the published V2-Lite config (64e)."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab=102400, head_dim=0,
    mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    moe=True, n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    first_dense_layers=1,
).validate()


def smoke():
    return reduced(CONFIG)
