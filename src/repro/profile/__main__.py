"""CLI for the profile store.

    python -m repro.profile report  RUN_DIR_OR_SNAPSHOT... [--component app]
    python -m repro.profile merge   SHARD_OR_DIR... -o merged.xfa.npz
    python -m repro.profile diff    BASELINE CANDIDATE [--threshold 0.25]

`report` reduces every given shard/dir into one profile and renders the
paper's component/API views + flow matrix.  `merge` persists that reduction.
`diff` compares two profiles and exits 1 when any per-edge regression
exceeds the threshold — wire it into CI as a perf gate.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List

from ..core.views import (api_view_by_caller, component_view,
                          render_flow_matrix)
from .diff import DIFF_FIELDS, diff_profiles
from .snapshot import ProfileSnapshot
from .store import load_profile


def _load_many(paths: List[str]) -> ProfileSnapshot:
    snaps = [load_profile(p) for p in paths]
    return snaps[0] if len(snaps) == 1 else ProfileSnapshot.merge(snaps)


def _cmd_report(args: argparse.Namespace) -> int:
    snap = _load_many(args.inputs)
    folded = snap.to_folded()
    if args.json:
        print(json.dumps({"meta": snap.meta, **folded.to_json()}, indent=1))
        return 0
    total = folded.total_ns()
    print(f"profile: {len(folded)} edges, {total/1e9:.3f}s folded total, "
          f"group={folded.group!r}")
    if snap.meta:
        print(f"meta: {json.dumps(snap.meta, sort_keys=True)}")
    for comp in args.component:
        print()
        print(component_view(folded, comp).render(args.top))
        print()
        print(api_view_by_caller(folded, comp).render(args.top))
    print()
    print(render_flow_matrix(folded))
    return 0


def _cmd_merge(args: argparse.Namespace) -> int:
    merged = _load_many(args.inputs)
    # mark the output as a merge product even for a single input, so a
    # store reduce over a dir containing it knows to skip it
    merged.meta.setdefault("merged_from",
                           [str(merged.meta.get("label", "?"))])
    merged.save(args.output)
    print(f"merged {len(args.inputs)} input(s), {len(merged)} edges "
          f"-> {args.output}")
    return 0


def _cmd_diff(args: argparse.Namespace) -> int:
    base = load_profile(args.baseline).to_folded()
    cand = load_profile(args.candidate).to_folded()
    d = diff_profiles(base, cand, threshold=args.threshold,
                      fields=tuple(args.fields.split(",")),
                      min_count=args.min_count,
                      min_total_ns=args.min_total_ns,
                      flag_added=not args.no_flag_added)
    if args.json:
        print(json.dumps(d.to_json(), indent=1))
    else:
        print(d.render())
    return 1 if d.has_regressions else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.profile",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    rep = sub.add_parser("report", help="render merged profile views")
    rep.add_argument("inputs", nargs="+",
                     help="snapshot files and/or shard directories")
    rep.add_argument("--component", nargs="*", default=["app"],
                     help="components to render views for")
    rep.add_argument("--top", type=int, default=20)
    rep.add_argument("--json", action="store_true")
    rep.set_defaults(fn=_cmd_report)

    mrg = sub.add_parser("merge", help="reduce shards into one snapshot")
    mrg.add_argument("inputs", nargs="+")
    mrg.add_argument("-o", "--output", required=True)
    mrg.set_defaults(fn=_cmd_merge)

    dif = sub.add_parser("diff", help="flag per-edge regressions")
    dif.add_argument("baseline")
    dif.add_argument("candidate")
    dif.add_argument("--threshold", type=float, default=0.25,
                     help="relative growth beyond which an edge is flagged")
    dif.add_argument("--fields", default="total_ns,self_ns,count",
                     help=f"comma list from {DIFF_FIELDS}")
    dif.add_argument("--min-count", type=int, default=1)
    dif.add_argument("--min-total-ns", type=int, default=0)
    dif.add_argument("--no-flag-added", action="store_true",
                     help="do not fail the gate on significant NEW edges")
    dif.add_argument("--json", action="store_true")
    dif.set_defaults(fn=_cmd_diff)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
