"""Fleet transport efficiency: delta streaming vs naive ring re-upload.

The publisher (repro.profile.FleetPublisher) ships only ring entries the
collector has not acked.  The naive alternative — re-uploading the whole
ring every interval, which is what a dumb `rsync`/poll loop would do —
costs the *cumulative* ring size per interval.  On a K-interval ring the
naive total is O(K^2) entry-bytes while the delta stream is O(K), so the
gap widens with every interval; this benchmark measures both on a real
localhost collector and GATES on the delta stream being >= 5x cheaper
over a 10-interval ring (exit 1 otherwise, wired into the fleet-e2e CI
lane).

It also asserts the resume contract: a fresh publisher (no client-side
state, as after a process restart) ships exactly the unacked suffix —
never the already-spooled prefix.

  transport.delta_bytes        bytes actually shipped over the wire
  transport.naive_bytes        full-ring re-upload equivalent
  transport.savings_x          naive / delta       (gate: >= 5.0)
  transport.frames             snapshot frames shipped
  transport.resume_reshipped   entries re-shipped by the restarted
                               publisher beyond the 1 new one (gate: 0)
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile

N_INTERVALS = 10
SAVINGS_GATE_X = 5.0


def run():
    from repro.core.folding import fold_event_log
    from repro.profile import (Collector, FleetPublisher, ProfileStore,
                               RetentionPolicy, register_run,
                               set_host_label)

    events = [("app", "runtime", "step", 2_000_000)] * 4 + \
             [("app", "io", "load", 1_000_000)] * 2
    table = fold_event_log(events)

    with tempfile.TemporaryDirectory() as tmp:
        run_dir = os.path.join(tmp, "run")
        spool = os.path.join(tmp, "spool")
        set_host_label("bench-host")
        register_run(run_dir, config="bench", kind="train", label="bench")
        # unbounded ring: the naive competitor re-uploads all of it
        store = ProfileStore(run_dir, retention=RetentionPolicy(keep_last=0))

        delta_bytes = frames = naive_bytes = 0
        resume_reshipped = 0
        with Collector(spool) as col:
            addr = "127.0.0.1:%d" % col.port
            pub = FleetPublisher(addr, run_dir, run_id="bench")
            for i in range(1, N_INTERVALS + 1):
                store.write_shard(table.scale_time(1.0 + 0.01 * i),
                                  label="bench")
                if i == 6:
                    # publisher restart mid-run: fresh client state must
                    # resume from the collector's acks, not re-ship
                    pub.close()
                    pub = FleetPublisher(addr, run_dir, run_id="bench")
                stats = pub.publish()
                assert stats["errors"] == 0, stats
                if i == 6:
                    resume_reshipped = stats["shipped"] - 1
                delta_bytes += stats["bytes"]
                frames += stats["shipped"]
                # what a full-ring re-upload would move this interval
                naive_bytes += sum(
                    os.path.getsize(path)
                    for ring in store.shards().values()
                    for _seq, path in ring)
            pub.close()
        set_host_label(None)

    savings = naive_bytes / delta_bytes if delta_bytes else 0.0
    note = f"{N_INTERVALS}-interval ring"
    yield "transport.delta_bytes", float(delta_bytes), note
    yield "transport.naive_bytes", float(naive_bytes), "full re-upload"
    yield "transport.savings_x", savings, f"gate >= {SAVINGS_GATE_X}"
    yield "transport.frames", float(frames), note
    yield "transport.resume_reshipped", float(resume_reshipped), "gate == 0"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("-o", "--output", help="also write the CSV here")
    args = ap.parse_args(argv)
    rows = list(run())
    lines = ["name,value,note"] + [f"{n},{v:.3f},{note}"
                                   for n, v, note in rows]
    csv = "\n".join(lines)
    print(csv)
    if args.output:
        with open(args.output, "w") as f:
            f.write(csv + "\n")
    vals = {n: v for n, v, _ in rows}
    failed = []
    if vals["transport.savings_x"] < SAVINGS_GATE_X:
        failed.append(f"delta stream only {vals['transport.savings_x']:.2f}x "
                      f"cheaper than naive re-upload (gate "
                      f">= {SAVINGS_GATE_X}x)")
    if vals["transport.resume_reshipped"] != 0:
        failed.append(f"resume re-shipped "
                      f"{int(vals['transport.resume_reshipped'])} already-"
                      f"acked ring entries (gate: 0)")
    for msg in failed:
        print(f"GATE FAILED: {msg}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
