"""Cross Flow Graph construction: typed nodes/edges from EdgeColumns,
mass conservation under arbitrary generated profiles (hypothesis, when
installed — the same assertions also run on hand-built tables so the
invariant is checked even where hypothesis is absent), and the per-shard
projections imbalance detection consumes."""

import numpy as np
import pytest

from repro.core.folding import EdgeStats, FoldedTable, fold_event_log
from repro.core.shadow import KIND_CALL, KIND_WAIT
from repro.analysis import FlowGraph, edge_label, run_graph, shard_graphs

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                    # CI installs it; image may not
    HAVE_HYPOTHESIS = False

CALLERS = ("app", "moe", "optimizer")
COMPONENTS = ("glibc", "alloc", "pthread")
APIS = ("read", "write", "malloc", "lock")
METRIC_NAMES = ("flops", "bytes")

EVENTS = [
    ("app", "glibc", "read", 18), ("app", "glibc", "write", 35),
    ("app", "alloc", "malloc", 10), ("moe", "pthread", "lock", 900),
]


def check_conservation(table: FoldedTable) -> None:
    """Graph construction loses nothing: edge aggregates equal the folded
    stats edge-for-edge, graph totals equal the column sums, and every
    node's inbound/outbound/wait sums equal the sums over its incident
    edges — including wait-kind and count-0 edges."""
    cols = table.to_columns()
    g = FlowGraph.from_columns(cols)

    assert g.edges.keys() == table.edges.keys()
    for k, e in table.edges.items():
        fe = g.edges[k]
        assert (fe.count, fe.total_ns, fe.child_ns, fe.min_ns, fe.max_ns,
                fe.kind) == (e.count, e.total_ns, e.child_ns, e.min_ns,
                             e.max_ns, e.kind), k
        assert fe.metrics == e.metrics, k
        assert fe.self_ns == e.self_ns

    assert g.total_ns() == int(cols.total_ns.sum())
    assert g.total_count() == int(cols.count.sum())

    for name, node in g.nodes.items():
        ins = [e for k, e in table.edges.items() if k[1] == name]
        outs = [e for k, e in table.edges.items() if k[0] == name]
        assert node.in_count == sum(e.count for e in ins)
        assert node.in_total_ns == sum(e.total_ns for e in ins)
        assert node.wait_ns == sum(e.total_ns for e in ins
                                   if e.kind == KIND_WAIT)
        assert node.wait_count == sum(e.count for e in ins
                                      if e.kind == KIND_WAIT)
        assert node.out_total_ns == sum(e.total_ns for e in outs)
        assert node.self_ns == max(node.in_total_ns - node.in_child_ns, 0)
    # sum over nodes' inbound == sum over edges (each edge has ONE callee)
    assert sum(n.in_total_ns for n in g.nodes.values()) == g.total_ns()


def _handmade_tables():
    wait_heavy = FoldedTable({
        ("app", "runtime", "dispatch"): EdgeStats(
            count=10, total_ns=100, child_ns=40, min_ns=1, max_ns=20),
        ("app", "runtime", "sync"): EdgeStats(
            count=10, total_ns=900, min_ns=1, max_ns=100, kind=KIND_WAIT),
        ("runtime", "alloc", "malloc"): EdgeStats(
            count=3, total_ns=40, min_ns=1, max_ns=30),
    })
    declared_only = FoldedTable({
        ("app", "moe", "dispatch"): EdgeStats(
            kind=KIND_CALL, metrics={"flops": 0.0}),   # count-0 + metric
        ("app", "glibc", "read"): EdgeStats(
            count=2, total_ns=7, min_ns=3, max_ns=4,
            metrics={"bytes": 128.0}),
    })
    return [FoldedTable(), fold_event_log(EVENTS), wait_heavy,
            declared_only]


@pytest.mark.parametrize("table", _handmade_tables(),
                         ids=["empty", "events", "wait-heavy", "count0"])
def test_graph_conserves_mass_handmade(table):
    check_conservation(table)


if HAVE_HYPOTHESIS:
    @st.composite
    def edge_stats_st(draw):
        """Full field space incl. count == 0 (declared, never timed),
        wait kind, and explicit metrics — the same envelope the merge
        algebra is property-tested on."""
        count = draw(st.integers(0, 50))
        kind = draw(st.sampled_from((KIND_CALL, KIND_WAIT)))
        metrics = draw(st.dictionaries(
            st.sampled_from(METRIC_NAMES),
            st.floats(min_value=0.0, max_value=1e6, allow_nan=False),
            max_size=2))
        if count == 0:
            return EdgeStats(kind=kind, metrics=metrics)
        total = draw(st.integers(1, 10**6))
        return EdgeStats(count=count, total_ns=total,
                         child_ns=draw(st.integers(0, total)),
                         min_ns=draw(st.integers(1, total)),
                         max_ns=draw(st.integers(1, total)),
                         kind=kind, metrics=metrics)

    folded_table_st = st.dictionaries(
        st.tuples(st.sampled_from(CALLERS), st.sampled_from(COMPONENTS),
                  st.sampled_from(APIS)),
        edge_stats_st(), max_size=12).map(FoldedTable)

    @settings(max_examples=60, deadline=None)
    @given(folded_table_st)
    def test_graph_conserves_mass(table):
        check_conservation(table)

    @settings(max_examples=30, deadline=None)
    @given(folded_table_st)
    def test_graph_adjacency_is_consistent(table):
        g = FlowGraph.from_folded(table)
        for comp in g.components():
            for e in g.in_edges(comp):
                assert e.component == comp
            for e in g.out_edges(comp):
                assert e.caller == comp
            for e in g.in_edges(comp, kind=KIND_WAIT):
                assert e.kind == KIND_WAIT
        # every edge endpoint is a node (callers with no inbound included)
        for (caller, callee, _api) in g.edges:
            assert caller in g.nodes and callee in g.nodes


class TestColumnsProjection:
    def test_select_mask_and_indices(self):
        t = fold_event_log(EVENTS)
        t.edges[("app", "glibc", "read")].metrics = {"flops": 2.0}
        cols = t.to_columns()
        mask = np.array([k[1] == "glibc" for k in cols.keys])
        sub = cols.select(mask)
        assert {k[1] for k in sub.keys} == {"glibc"}
        assert sub.total_ns.sum() == 18 + 35
        # metric columns stay aligned after selection
        j = sub.keys.index(("app", "glibc", "read"))
        i = sub.metric_names.index("flops")
        assert sub.metric_mask[i, j] and sub.metric_values[i, j] == 2.0
        # int-index spelling selects the same rows
        same = cols.select(np.nonzero(mask)[0])
        assert same.keys == sub.keys

    def test_group_rows(self):
        cols = fold_event_log(EVENTS).to_columns()
        by_comp = cols.group_rows("component")
        assert set(by_comp) == {"glibc", "alloc", "pthread"}
        assert int(cols.total_ns[by_comp["glibc"]].sum()) == 53
        by_caller = cols.group_rows("caller")
        assert set(by_caller) == {"app", "moe"}
        assert cols.self_ns.sum() == cols.total_ns.sum()  # no child time

    def test_select_empty_projection(self):
        cols = fold_event_log(EVENTS).to_columns()
        none = cols.select([])               # no rows matched the filter
        assert len(none) == 0 and none.group == cols.group
        also_none = cols.select(np.zeros(len(cols), dtype=bool))
        assert len(also_none) == 0

    def test_two_hop_adjacency(self):
        t = fold_event_log([("app", "db", "query", 10),
                            ("db", "net", "send", 1)])
        g = FlowGraph.from_folded(t)
        [e1] = g.in_edges("db")
        [e2] = g.out_edges("db")
        assert e1.key == ("app", "db", "query")
        assert e2.key == ("db", "net", "send")
        assert g.successors("db") == ["net"]


class TestRunProjections:
    def test_shard_graphs_one_subgraph_per_shard(self, tmp_path):
        from repro.profile import ProfileStore
        store = ProfileStore(str(tmp_path))
        store.write_shard(fold_event_log(EVENTS), label="train-r0")
        store.write_shard(fold_event_log(EVENTS), label="train-r0")  # ring
        store.write_shard(fold_event_log(EVENTS * 3), label="train-r1")
        graphs = shard_graphs(str(tmp_path))
        assert len(graphs) == 2                 # newest per shard, not ring
        r0 = graphs[store.shard_stem("train-r0")]
        r1 = graphs[store.shard_stem("train-r1")]
        assert r1.total_ns() == 3 * r0.total_ns()
        # the merged run graph conserves the per-shard mass
        merged = run_graph(str(tmp_path))
        assert merged.total_ns() == r0.total_ns() + r1.total_ns()
        assert merged.meta["run_dir"] == str(tmp_path)

    def test_merge_products_excluded(self, tmp_path):
        from repro.profile import ProfileSnapshot, ProfileStore
        store = ProfileStore(str(tmp_path))
        store.write_shard(fold_event_log(EVENTS), label="t")
        snap = ProfileSnapshot.from_folded(fold_event_log(EVENTS * 9),
                                           meta={"merged_from": ["x"]})
        snap.save(str(tmp_path / "merged-out.xfa.npz"))
        assert len(shard_graphs(str(tmp_path))) == 1

    def test_edge_label_matches_timeline_spelling(self):
        assert edge_label(("app", "glibc", "read")) == "app -> glibc.read"
