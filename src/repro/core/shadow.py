"""Universal Shadow Table — the host-side slot store for Cross Flow Analysis.

Paper mapping (Scaler §3.2, Figure 2): every interceptable API, regardless of
how it is linked (.rela.plt / .rela.dyn / dlsym), maps to ONE fixed-size
*shadow entry* that carries everything the interceptor needs, so attribution
is O(1), allocation-free and uniform across API kinds.

TPU/JAX adaptation: the "APIs" are framework boundaries (host framework calls,
in-graph module applications, HLO collectives).  A shadow entry is a row in a
set of preallocated flat numpy arrays.  Slot resolution happens ONCE per
(caller-component, callee-component, api) edge — the analogue of lazy PLT
resolution — after which the hot path is two integer loads and a few adds,
with no hashing and no allocation (the paper explicitly rejects hash tables on
the hot path; we intern to dense ids instead).

Relation-awareness (Scaler §3.4): the slot key *includes the caller
component*, so the same callee API invoked from two different components folds
into two distinct slots.  That is exactly the paper's Relation-Aware Data
Folding invariant and is what keeps per-component views accurate.

Threading (Scaler §3.3): every thread owns its own ShadowTable (lock-free hot
path, no false sharing); the SlotRegistry is shared so slot ids agree across
threads, and per-thread tables are merged offline (views.py / folding.py).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

# Slot kinds — 'wait' is separated per Scaler §3.5 ("Wait" pseudo-category:
# condvar/barrier/lock time means the program is not doing useful work).
KIND_CALL = 0
KIND_WAIT = 1
KIND_NAMES = {KIND_CALL: "call", KIND_WAIT: "wait"}

#: the component attributed when nothing is on the caller stack — the paper's
#: "application itself" island.
APP_COMPONENT = "app"

SlotKey = Tuple[str, str, str]  # (caller_component, callee_component, api)


@dataclass(frozen=True)
class SlotInfo:
    """Static metadata of one shadow entry (the paper's per-API struct)."""

    slot: int
    caller: str
    component: str
    api: str
    kind: int = KIND_CALL

    @property
    def key(self) -> SlotKey:
        return (self.caller, self.component, self.api)


class SlotRegistry:
    """Interns (caller, component, api) edges to dense slot ids.

    Shared across threads; the lock is taken only on FIRST resolution of an
    edge (the slow path — mirroring the dynamic linker resolving a PLT entry
    once).  Steady-state lookups go through a plain dict read, which is
    GIL-atomic in CPython; the returned id is then cached by the call site so
    even the dict read disappears from the hot path.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._by_key: Dict[SlotKey, SlotInfo] = {}
        self._infos: List[SlotInfo] = []

    def resolve(self, caller: str, component: str, api: str,
                kind: int = KIND_CALL) -> SlotInfo:
        key = (caller, component, api)
        info = self._by_key.get(key)
        if info is not None:
            return info
        with self._lock:
            info = self._by_key.get(key)
            if info is None:
                info = SlotInfo(len(self._infos), caller, component, api, kind)
                self._infos.append(info)
                self._by_key[key] = info
        return info

    def __len__(self) -> int:
        return len(self._infos)

    def info(self, slot: int) -> SlotInfo:
        return self._infos[slot]

    def infos(self) -> List[SlotInfo]:
        return list(self._infos)


class ShadowTable:
    """One thread's shadow entries: preallocated flat arrays, grown by doubling.

    Per-slot stats (the fold): count, total_ns, child_ns (time spent inside
    callees of this call — used to compute self time), min_ns, max_ns.
    ``record`` is the entire hot path: bounds check + 5 array updates.
    """

    __slots__ = ("count", "total_ns", "child_ns", "min_ns", "max_ns",
                 "_cap", "thread_name", "group")

    INITIAL_CAPACITY = 256

    def __init__(self, thread_name: str = "main", group: str = "main",
                 capacity: int = INITIAL_CAPACITY) -> None:
        self._cap = int(capacity)
        self.thread_name = thread_name
        #: thread *group* (e.g. pipeline stage name) for imbalance analysis
        self.group = group
        self.count = np.zeros(self._cap, dtype=np.int64)
        self.total_ns = np.zeros(self._cap, dtype=np.int64)
        self.child_ns = np.zeros(self._cap, dtype=np.int64)
        self.min_ns = np.full(self._cap, np.iinfo(np.int64).max, dtype=np.int64)
        self.max_ns = np.zeros(self._cap, dtype=np.int64)

    # -- hot path ---------------------------------------------------------
    def record(self, slot: int, dur_ns: int, child_ns: int = 0) -> None:
        if slot >= self._cap:
            self._grow(slot + 1)
        self.count[slot] += 1
        self.total_ns[slot] += dur_ns
        self.child_ns[slot] += child_ns
        if dur_ns < self.min_ns[slot]:
            self.min_ns[slot] = dur_ns
        if dur_ns > self.max_ns[slot]:
            self.max_ns[slot] = dur_ns

    def record_count(self, slot: int, n: int = 1) -> None:
        """Count-only fold (paper: counting is always on; timing is optional)."""
        if slot >= self._cap:
            self._grow(slot + 1)
        self.count[slot] += n

    # -- slow paths -------------------------------------------------------
    def _grow(self, needed: int) -> None:
        new_cap = self._cap
        while new_cap < needed:
            new_cap *= 2
        for name in ("count", "total_ns", "child_ns", "max_ns"):
            arr = getattr(self, name)
            new = np.zeros(new_cap, dtype=np.int64)
            new[: self._cap] = arr
            setattr(self, name, new)
        new_min = np.full(new_cap, np.iinfo(np.int64).max, dtype=np.int64)
        new_min[: self._cap] = self.min_ns
        self.min_ns = new_min
        self._cap = new_cap

    @property
    def capacity(self) -> int:
        return self._cap

    def nbytes(self) -> int:
        """Memory footprint — O(#slots), never O(#events) (paper Table 5)."""
        return sum(getattr(self, n).nbytes
                   for n in ("count", "total_ns", "child_ns", "min_ns", "max_ns"))

    def active_slots(self) -> np.ndarray:
        return np.nonzero(self.count[: self._cap])[0]

    def reset(self) -> None:
        self.count[:] = 0
        self.total_ns[:] = 0
        self.child_ns[:] = 0
        self.min_ns[:] = np.iinfo(np.int64).max
        self.max_ns[:] = 0


class ShadowTableSet:
    """All per-thread tables of one process + the shared registry.

    The paper persists each thread's data at thread exit and merges offline;
    we keep tables addressable here and let folding.py do the merge.  Tables
    for exited threads are retained (the paper's __cxa_thread_atexit handler
    keeps the data alive until the main thread persists it).
    """

    def __init__(self) -> None:
        self.registry = SlotRegistry()
        self._tables: Dict[int, ShadowTable] = {}
        self._lock = threading.Lock()
        self._tls = threading.local()

    def table(self, group: Optional[str] = None) -> ShadowTable:
        t = getattr(self._tls, "table", None)
        if t is None:
            th = threading.current_thread()
            t = ShadowTable(thread_name=th.name, group=group or th.name)
            with self._lock:
                self._tables[th.ident or id(th)] = t
            self._tls.table = t
        elif group is not None:
            t.group = group
        return t

    def tables(self) -> List[ShadowTable]:
        with self._lock:
            return list(self._tables.values())

    def iter_edges(self) -> Iterator[Tuple[SlotInfo, ShadowTable]]:
        for t in self.tables():
            for slot in t.active_slots():
                yield self.registry.info(int(slot)), t

    def nbytes(self) -> int:
        return sum(t.nbytes() for t in self.tables())

    def reset(self) -> None:
        for t in self.tables():
            t.reset()
