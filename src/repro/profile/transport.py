"""Fleet shard transport — framed snapshot-ring deltas over TCP.

Every process of a fleet (trainer rank, serving replica) owns a local
snapshot ring (store.py) whose entries are byte-deterministic and
sequence-numbered.  That makes incremental shipping nearly free: a
publisher only ever needs to send ring entries NEWER than what the
collector has already acknowledged, and resume after a disconnect or a
collector restart is just "ask what you have" — no journals, no client
state files.

Wire protocol (version 1; see docs/fleet.md for the normative frame and
failure-matrix reference):

    frame := u32_be header_len | header_json utf-8 | payload bytes

The header is a small JSON object carrying `type` plus type-specific
fields; `length` (payload byte count, 0 when absent) and `sha256` (hex
digest of the payload) ride in the header so the receiver can validate
before touching its spool.  Client -> collector types:

    hello     {proto, run_id, host}                open a session; the
                                                   collector answers
                                                   ack_state
    snapshot  {run_id, host, shard, seq,           one raw .xfa.npz ring
               length, sha256} + payload           entry
    manifest  {run_id, host, length, sha256}       the run's
              + payload                            manifest.json bytes
    bye       {}                                   graceful close

Collector -> client types:

    ack_state {acked: {shard: max_seq}}            resume point for the
                                                   (run_id, host) session
    ack       {shard, seq, dedup}                  payload spooled (or
                                                   already present)
    reject    {shard, seq, reason}                 checksum/length
                                                   mismatch — re-send
    error     {reason}                             protocol error; the
                                                   collector closes

Every socket operation runs under a timeout; an EOF inside a frame
raises `Disconnect`, malformed bytes raise `FrameError`.  The publisher
(`FleetPublisher`) NEVER raises out of `publish()` — a dead collector
degrades the fleet to local-only rings, it must not kill a train or
serve loop.
"""

from __future__ import annotations

import hashlib
import json
import os
import socket
import struct
import time
from typing import Dict, Optional, Tuple

PROTO_VERSION = 1

#: refuse frames beyond this unless the caller raises it — a fleet
#: snapshot is a few KiB to a few MiB; 256 MiB is a corrupt length
#: prefix, not a profile.
MAX_FRAME_BYTES = 256 * 1024 * 1024

_LEN = struct.Struct("!I")


class FrameError(ValueError):
    """Malformed frame: bad length prefix, bad JSON, missing fields."""


class Disconnect(ConnectionError):
    """Peer closed the connection (possibly mid-frame)."""


def frame_checksum(payload: bytes) -> str:
    return hashlib.sha256(payload).hexdigest()


def parse_addr(addr: str) -> Tuple[str, int]:
    """'host:port' -> (host, port); the launcher flag surface."""
    host, sep, port = addr.rpartition(":")
    if not sep or not host or not port.isdigit():
        raise ValueError(f"collector address {addr!r} is not HOST:PORT")
    return host, int(port)


def send_frame(sock: socket.socket, header: Dict,
               payload: bytes = b"") -> None:
    """One atomic-ish send: length-prefixed header, then the payload.
    `length`/`sha256` are filled in from the payload when absent."""
    h = dict(header)
    h.setdefault("length", len(payload))
    if payload and "sha256" not in h:
        h["sha256"] = frame_checksum(payload)
    raw = json.dumps(h, sort_keys=True).encode("utf-8")
    sock.sendall(_LEN.pack(len(raw)) + raw + payload)


def recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly n bytes or raise Disconnect on EOF mid-read."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise Disconnect(f"peer closed after {len(buf)}/{n} bytes")
        buf.extend(chunk)
    return bytes(buf)


def recv_frame(sock: socket.socket,
               max_bytes: int = MAX_FRAME_BYTES) -> Tuple[Dict, bytes]:
    """Read one (header, payload) frame.  Raises Disconnect on EOF at a
    frame boundary or inside a frame, FrameError on malformed bytes."""
    head = sock.recv(_LEN.size)
    if not head:
        raise Disconnect("peer closed between frames")
    if len(head) < _LEN.size:
        head += recv_exact(sock, _LEN.size - len(head))
    (hlen,) = _LEN.unpack(head)
    if not 0 < hlen <= 1 << 20:
        raise FrameError(f"header length {hlen} out of range")
    try:
        header = json.loads(recv_exact(sock, hlen).decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise FrameError(f"bad frame header: {e}") from e
    if not isinstance(header, dict) or "type" not in header:
        raise FrameError(f"frame header missing 'type': {header!r}")
    plen = int(header.get("length", 0))
    if not 0 <= plen <= max_bytes:
        raise FrameError(f"payload length {plen} exceeds {max_bytes}")
    payload = recv_exact(sock, plen) if plen else b""
    return header, payload


class FleetPublisher:
    """Ships one run dir's snapshot-ring deltas to a collector.

    Tracks the collector's acked `(shard, seq)` state (seeded by the
    `ack_state` reply to `hello`, updated by every `ack`) and on each
    `publish()` sends only ring entries strictly newer than that —
    reconnect (or a collector restart) re-seeds the state, so exactly
    the unacked suffix is re-sent, never the whole ring.

    Failure policy: `publish()` never raises.  Any socket/protocol
    error closes the connection, records `last_error`, and the next
    publish retries (rate-limited by `retry_interval_s`).  The local
    ring is always written first by the caller, so a dead collector
    degrades to local-only profiling.
    """

    def __init__(self, addr, run_dir: str, run_id: Optional[str] = None,
                 host: Optional[str] = None, timeout: float = 5.0,
                 retry_interval_s: float = 5.0) -> None:
        self.addr = parse_addr(addr) if isinstance(addr, str) else tuple(addr)
        self.run_dir = run_dir
        self.run_id = run_id or \
            os.path.basename(os.path.normpath(run_dir)) or "run"
        if host is None:
            from .store import host_label
            host = host_label()
        self.host = host
        self.timeout = timeout
        self.retry_interval_s = retry_interval_s
        self._sock: Optional[socket.socket] = None
        self._acked: Dict[str, int] = {}      # shard stem -> max acked seq
        self._manifest_sig: Optional[Tuple[int, int]] = None
        self._next_retry = 0.0
        self.last_error: Optional[str] = None

    # -- connection ---------------------------------------------------------
    @property
    def connected(self) -> bool:
        return self._sock is not None

    def _connect(self) -> bool:
        if self._sock is not None:
            return True
        now = time.monotonic()
        if now < self._next_retry:
            return False
        try:
            sock = socket.create_connection(self.addr, timeout=self.timeout)
            sock.settimeout(self.timeout)
            send_frame(sock, {"type": "hello", "proto": PROTO_VERSION,
                              "run_id": self.run_id, "host": self.host})
            header, _ = recv_frame(sock)
            if header.get("type") != "ack_state":
                raise FrameError(f"expected ack_state, got {header!r}")
            self._acked = {str(k): int(v)
                           for k, v in dict(header.get("acked", {})).items()}
            self._sock = sock
            self._manifest_sig = None     # collector may have restarted
            self.last_error = None
            return True
        except (OSError, ValueError) as e:
            self._drop(e)
            return False

    def _drop(self, err: Optional[BaseException] = None) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
        if err is not None:
            self.last_error = f"{type(err).__name__}: {err}"
            self._next_retry = time.monotonic() + self.retry_interval_s

    def close(self) -> None:
        if self._sock is not None:
            try:
                send_frame(self._sock, {"type": "bye"})
            except OSError:
                pass
        self._drop()
        self._next_retry = 0.0

    # -- shipping -----------------------------------------------------------
    def _pending(self):
        """Ring entries newer than the collector's ack, oldest first, so
        a partial publish leaves a resumable prefix."""
        from .store import ProfileStore
        out = []
        for stem, ring in sorted(ProfileStore(self.run_dir).shards().items()):
            for seq, path in ring:
                if seq > self._acked.get(stem, 0):
                    out.append((stem, seq, path))
        out.sort(key=lambda e: (e[1], e[0]))
        return out

    def _ship_one(self, sock, header: Dict, payload: bytes,
                  what: str) -> bool:
        """Send one frame and wait for its ack; on `reject` (checksum or
        length mismatch seen by the collector — a torn read, a corrupt
        wire) re-send ONCE with freshly read bytes."""
        for attempt in (0, 1):
            send_frame(sock, header, payload)
            reply, _ = recv_frame(sock)
            kind = reply.get("type")
            if kind == "ack":
                return True
            if kind == "reject" and attempt == 0:
                continue
            raise FrameError(
                f"collector refused {what}: {reply.get('reason', reply)}")
        return False

    def publish(self) -> Dict[str, int]:
        """Ship every unacked ring entry (and the run manifest when it
        changed).  Returns counters; NEVER raises."""
        stats = {"shipped": 0, "bytes": 0, "pending": 0, "errors": 0}
        if not self._connect():
            stats["errors"] = 1
            stats["pending"] = len(self._pending())
            return stats
        sock = self._sock
        try:
            manifest = os.path.join(self.run_dir, "manifest.json")
            if os.path.exists(manifest):
                st = os.stat(manifest)
                sig = (st.st_mtime_ns, st.st_size)
                if sig != self._manifest_sig:
                    with open(manifest, "rb") as f:
                        doc = f.read()
                    self._ship_one(sock, {"type": "manifest",
                                          "run_id": self.run_id,
                                          "host": self.host}, doc,
                                   "manifest")
                    self._manifest_sig = sig
                    stats["bytes"] += len(doc)
            for stem, seq, path in self._pending():
                try:
                    with open(path, "rb") as f:
                        blob = f.read()
                except FileNotFoundError:
                    continue              # retention beat us to it
                ok = self._ship_one(
                    sock, {"type": "snapshot", "run_id": self.run_id,
                           "host": self.host, "shard": stem, "seq": seq},
                    blob, f"{stem} seq {seq}")
                if not ok:
                    stats["errors"] += 1
                    continue
                self._acked[stem] = max(self._acked.get(stem, 0), seq)
                stats["shipped"] += 1
                stats["bytes"] += len(blob)
        except (OSError, ValueError) as e:
            self._drop(e)
            stats["errors"] += 1
        stats["pending"] = len(self._pending())
        return stats
