"""Shared test helpers."""

import math

import numpy as np


def assert_tables_equal(a, b):
    """Full per-edge FoldedTable equality: every stat, kind, the metric
    dict (including presence — absent metric != 0.0 metric), the
    latency histogram (None-aware; None != populated), and the governor
    sampling rate (None == fully sampled; numeric rates compare with
    isclose — count-weighted float merges are not bit-associative)."""
    assert a.edges.keys() == b.edges.keys()
    for k in a.edges:
        ea, eb = a.edges[k], b.edges[k]
        assert (ea.count, ea.total_ns, ea.child_ns, ea.min_ns, ea.max_ns,
                ea.kind) == (eb.count, eb.total_ns, eb.child_ns, eb.min_ns,
                             eb.max_ns, eb.kind), k
        assert ea.metrics == eb.metrics, k
        if ea.hist is None or eb.hist is None:
            assert ea.hist is None and eb.hist is None, k
        else:
            assert np.array_equal(ea.hist, eb.hist), k
        if ea.sample_rate is None or eb.sample_rate is None:
            assert ea.sample_rate is None and eb.sample_rate is None, k
        else:
            assert math.isclose(ea.sample_rate, eb.sample_rate,
                                rel_tol=1e-12), k
