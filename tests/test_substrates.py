"""Substrate tests: data pipeline, checkpointing, optimizer, fault tolerance,
sharding rules."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_smoke, list_archs
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.optim import adamw
from repro.parallel.sharding import spec_tree, validate_rules
from repro.runtime.fault_tolerance import (HeartbeatMonitor, SimulatedCluster,
                                           StragglerDetector, elastic_remesh)


# ------------------------------------------------------------------ data ----
class TestData:
    def test_deterministic(self):
        cfg = get_smoke("tinyllama_1_1b")
        d1 = SyntheticLMData(cfg, 4, 32, seed=7)
        d2 = SyntheticLMData(cfg, 4, 32, seed=7)
        b1, b2 = d1.generate(5), d2.generate(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_and_shards_differ(self):
        cfg = get_smoke("tinyllama_1_1b")
        d = SyntheticLMData(cfg, 4, 32)
        assert not np.array_equal(d.generate(0)["tokens"],
                                  d.generate(1)["tokens"])
        d2 = SyntheticLMData(cfg, 4, 32, shard=1)
        assert not np.array_equal(d.generate(0)["tokens"],
                                  d2.generate(0)["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = get_smoke("tinyllama_1_1b")
        b = SyntheticLMData(cfg, 2, 16).generate(0)
        assert b["tokens"].shape == b["labels"].shape
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetch_iterator(self):
        cfg = get_smoke("tinyllama_1_1b")
        d = SyntheticLMData(cfg, 2, 16).start(at_step=3)
        batches = [next(d) for _ in range(3)]
        d.stop()
        ref = SyntheticLMData(cfg, 2, 16)
        np.testing.assert_array_equal(batches[0]["tokens"],
                                      ref.generate(3)["tokens"])

    def test_multimodal_fields(self):
        for arch, field in (("internvl2_1b", "patches"),
                            ("seamless_m4t_large_v2", "frames")):
            cfg = get_smoke(arch)
            b = SyntheticLMData(cfg, 2, 32).generate(0)
            assert field in b and np.isfinite(b[field]).all()


# ------------------------------------------------------------------ ckpt ----
class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.key(seed)
        return {"params": {"w": jax.random.normal(k, (8, 8)),
                           "b": jnp.zeros((8,))},
                "opt": {"step": jnp.int32(7)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = self._tree()
        mgr.save(10, tree, extra={"next_step": 11})
        restored, extra = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
        assert extra["next_step"] == 11
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_last_prunes(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(s))
        assert mgr.list_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(1, self._tree())
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.zeros((5,))})

    def test_no_tmp_dir_left_behind(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree())
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


# ----------------------------------------------------------------- optim ----
class TestOptim:
    def test_adamw_converges_on_quadratic(self):
        cfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, grad_clip=0)
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros((3,))}
        state = adamw.init_state(params)
        for _ in range(150):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, state, _ = adamw.apply_updates(params, state, g, cfg)
        np.testing.assert_allclose(params["w"], target, atol=0.05)

    def test_grad_clip_caps_update(self):
        cfg = TrainConfig(grad_clip=1.0, warmup_steps=0, learning_rate=1.0,
                          weight_decay=0.0)
        params = {"w": jnp.zeros((4,))}
        state = adamw.init_state(params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adamw.apply_updates(params, state, g, cfg)
        assert metrics["grad_norm"] > 1e5  # reported raw

    def test_decay_mask_skips_norms(self):
        from repro.optim.adamw import _decay_mask
        assert _decay_mask("layers/norm1/scale") == 0.0
        assert _decay_mask("attn/wq") == 1.0
        assert _decay_mask("ssm/a_log") == 0.0

    def test_int8_error_feedback_reduces_bias(self):
        """With error feedback the quantization error must not accumulate:
        sum of compressed grads ~ sum of raw grads."""
        rng = np.random.default_rng(0)
        g_raw = [jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)
                 for _ in range(50)]
        err = adamw.init_error_state({"w": g_raw[0]})
        acc_c = np.zeros(64)
        for g in g_raw:
            cg, err = adamw.compress_grads_with_feedback({"w": g}, err)
            acc_c += np.asarray(cg["w"])
        acc_raw = sum(np.asarray(g) for g in g_raw)
        # relative error of the running sum stays small thanks to feedback
        denom = np.linalg.norm(acc_raw) + 1e-9
        assert np.linalg.norm(acc_c - acc_raw) / denom < 0.05

    def test_quantize_roundtrip(self):
        x = jnp.asarray(np.random.default_rng(1).standard_normal(256),
                        jnp.float32)
        q, s = adamw.quantize_int8(x)
        assert q.dtype == jnp.int8
        err = np.abs(np.asarray(adamw.dequantize_int8(q, s) - x))
        assert err.max() <= float(s) * 0.5 + 1e-7


# -------------------------------------------------------------- sharding ----
class TestSharding:
    @pytest.mark.parametrize("arch", list_archs())
    def test_every_param_has_a_rule(self, arch):
        model = build_model(get_smoke(arch), impl="ref")
        params = jax.eval_shape(model.init, jax.random.key(0))
        assert validate_rules(params) == []

    def test_spec_tree_no_mesh_is_unconstrained(self):
        model = build_model(get_smoke("tinyllama_1_1b"), impl="ref")
        params = jax.eval_shape(model.init, jax.random.key(0))
        specs = jax.tree.leaves(
            spec_tree(params, None),
            is_leaf=lambda x: hasattr(x, "__iter__") or x is None)
        assert specs  # resolvable without a mesh


# --------------------------------------------------------- fault tolerance --
class TestFaultTolerance:
    def test_heartbeat_detects_silence(self):
        mon = HeartbeatMonitor(4, timeout_s=0.5)
        t0 = time.monotonic()
        for h in range(3):
            mon.beat(h, at=t0 + 1.0)
        # host 3 never beat after t0: 1.1s of silence > 0.5s timeout;
        # hosts 0-2 beat 0.1s ago -> alive
        assert mon.check(now=t0 + 1.1) == [3]

    def test_injected_failure_immediate(self):
        mon = HeartbeatMonitor(4, timeout_s=60)
        mon.inject_failure(2)
        assert 2 in mon.check()

    def test_elastic_remesh_preserves_model_axis(self):
        plan = elastic_remesh(alive_hosts=list(range(7)), devices_per_host=32,
                              model_axis=16)
        assert plan.shape[-1] == 16
        assert plan.shape[0] * 16 <= 7 * 32
        with pytest.raises(RuntimeError):
            elastic_remesh(alive_hosts=[0], devices_per_host=8, model_axis=16)

    def test_straggler_detection(self):
        det = StragglerDetector(4, threshold=1.5)
        for step in range(10):
            for h in range(4):
                det.observe(h, 100.0 if h != 2 else 400.0)
        rep = det.report()
        assert rep.stragglers == [2]

    def test_simulated_cluster_failure_and_recovery(self):
        mon = HeartbeatMonitor(4, timeout_s=10)
        done = []
        cluster = SimulatedCluster(4, mon, lambda h, s: done.append((h, s)))
        cluster.start(n_steps=50)
        cluster.kill(1)
        cluster.join()
        dead = mon.check()
        assert 1 in dead
        plan = elastic_remesh([h for h in range(4) if h not in dead],
                              devices_per_host=64, model_axis=16)
        assert plan.n_devices == 192  # 3 hosts x 64, model axis intact
