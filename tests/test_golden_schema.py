"""Golden-file pin of snapshot schema v1.

`tests/data/golden_v1.xfa.npz` is a tiny reference snapshot checked into
the repo (uncompressed, fixed zip metadata — see snapshot._write_npz).
These tests assert that loading it, reporting over it, and re-saving it
reproduces the file byte-for-byte.  If any of them fail after a change to
snapshot.py, the on-disk layout moved: either restore compatibility or
bump SCHEMA_VERSION, regenerate the golden (run this file as a script),
and say so loudly in the PR — schema bumps must be deliberate, never a
side effect.
"""

import os

import pytest

from conftest import assert_tables_equal
from repro.core.folding import EdgeStats, FoldedTable
from repro.core.views import component_view, render_flow_matrix
from repro.profile import ProfileSnapshot
from repro.profile.snapshot import SCHEMA_VERSION

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "golden_v1.xfa.npz")


def golden_table() -> FoldedTable:
    """The reference profile: exercises kinds, wait edges, child_ns, the
    min_ns sentinel (count-0 edge), metric presence and an explicit 0.0
    metric — every v1 field with fixed values."""
    t = FoldedTable(group="golden")
    t.edges[("app", "glibc", "read")] = EdgeStats(
        count=3, total_ns=220, child_ns=20, min_ns=18, max_ns=120)
    t.edges[("app", "glibc", "write")] = EdgeStats(
        count=1, total_ns=35, child_ns=0, min_ns=35, max_ns=35)
    t.edges[("moe", "pthread", "lock")] = EdgeStats(
        count=2, total_ns=900, child_ns=0, min_ns=400, max_ns=500,
        kind=1)  # KIND_WAIT
    t.edges[("app", "moe", "dispatch")] = EdgeStats(   # metrics-only edge
        metrics={"flops": 1e9, "bytes": 0.0})
    t.edges[("optimizer", "alloc", "malloc")] = EdgeStats(
        count=5, total_ns=50, child_ns=5, min_ns=2, max_ns=30,
        metrics={"bytes": 4096.0})
    return t


GOLDEN_META = {"label": "golden", "note": "schema v1 reference"}


def write_golden(path: str = GOLDEN) -> str:
    snap = ProfileSnapshot.from_folded(golden_table(), meta=GOLDEN_META)
    return snap.save(path, compress=False)


class TestGoldenSchemaV1:
    def test_schema_version_still_v1(self):
        # regenerating the golden on a bump is a DELIBERATE step; this
        # makes `SCHEMA_VERSION += 1` fail tests until someone does it
        assert SCHEMA_VERSION == 1, \
            "schema bumped: regenerate tests/data/golden_v1.xfa.npz " \
            "(python tests/test_golden_schema.py) and update this test"

    def test_load_matches_reference_content(self):
        snap = ProfileSnapshot.load(GOLDEN)
        assert snap.schema == 1
        assert snap.meta == GOLDEN_META
        assert_tables_equal(snap.to_folded(), golden_table())

    def test_report_views_render(self):
        folded = ProfileSnapshot.load(GOLDEN).to_folded()
        out = component_view(folded, "app").render()
        assert "Component view: app" in out
        moe = component_view(folded, "moe").render()
        assert "Wait" in moe                      # the KIND_WAIT edge shows
        assert "Flow matrix" in render_flow_matrix(folded)

    def test_resave_is_byte_stable(self, tmp_path):
        """load -> save must be the identity on bytes: key order, string
        interning, header json, zip member metadata are all pinned."""
        snap = ProfileSnapshot.load(GOLDEN)
        out = str(tmp_path / "resaved.xfa.npz")
        snap.save(out, compress=False)
        with open(GOLDEN, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read(), \
                "snapshot v1 byte layout changed — bump SCHEMA_VERSION " \
                "and regenerate the golden if this was intentional"

    def test_fresh_build_matches_golden_bytes(self, tmp_path):
        """Rebuilding the reference table from source produces the exact
        checked-in bytes (writer determinism, not just reader identity)."""
        out = write_golden(str(tmp_path / "rebuilt.xfa.npz"))
        with open(GOLDEN, "rb") as a, open(out, "rb") as b:
            assert a.read() == b.read()

    def test_compressed_save_is_deterministic(self, tmp_path):
        """Same content -> same bytes for the default compressed writer
        (fixed zip timestamps); lets shard refreshes be content-compared."""
        snap = ProfileSnapshot.load(GOLDEN)
        p1 = str(tmp_path / "a.xfa.npz")
        p2 = str(tmp_path / "b.xfa.npz")
        snap.save(p1)
        snap.save(p2)
        with open(p1, "rb") as a, open(p2, "rb") as b:
            assert a.read() == b.read()

    def test_golden_loads_via_np_load_contract(self):
        """The file stays a plain npz (np.load-readable) — external tooling
        reads snapshots without repro installed."""
        import numpy as np
        with np.load(GOLDEN) as z:
            assert "__header__" in z and "count" in z
            assert z["count"].dtype == np.int64
            assert z["kind"].dtype == np.int8
            assert z["metric_values"].dtype == np.float64


if __name__ == "__main__":   # regenerate the golden after a DELIBERATE bump
    print("wrote", write_golden())