"""tinyllama-1.1b — llama2-arch small, GQA kv=4 [arXiv:2401.02385].
Also the backbone of the end-to-end training example (examples/train_lm.py)."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab=32000, head_dim=64,
).validate()


def smoke():
    return reduced(CONFIG)
