"""Profile diff — run-over-run comparison with per-edge regression flags.

Compares two profiles (baseline vs candidate) edge by edge on the
relation-aware key and flags edges whose count / total_ns / self_ns grew
beyond a relative threshold — the persisted-profile analogue of the scaling
-loss detection that per-run performance graphs enable (ScalAna): once every
run leaves a snapshot behind, a regression is one `diff` away.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.folding import EdgeStats, FoldedTable
from ..core.shadow import SlotKey

#: fields a regression can be flagged on; self_ns/mean_ns are derived, and
#: the percentile/jitter fields read the edge's latency histogram (schema
#: v2) — they evaluate to 0.0 on hist-less edges, so gating on p99_ns
#: drift is a no-op over v1 profiles rather than an error.
DIFF_FIELDS = ("count", "total_ns", "self_ns", "mean_ns",
               "p50_ns", "p95_ns", "p99_ns", "jitter_ns")


def _value(e: EdgeStats, fld: str) -> float:
    return float(getattr(e, fld))


@dataclass
class EdgeDelta:
    key: SlotKey
    base: Optional[EdgeStats]
    cand: Optional[EdgeStats]
    #: field -> (base value, candidate value, relative delta); rel is inf
    #: when the baseline value is 0 and the candidate is not.
    deltas: Dict[str, Tuple[float, float, float]] = field(default_factory=dict)
    #: fields whose relative growth exceeded the threshold
    flagged: List[str] = field(default_factory=list)

    @property
    def regressed(self) -> bool:
        return bool(self.flagged)

    def describe(self) -> str:
        caller, comp, api = self.key
        if self.base is None:
            return f"{caller} -> {comp}.{api}: NEW edge"
        if self.cand is None:
            return f"{caller} -> {comp}.{api}: edge DISAPPEARED"
        parts = []
        for fld in self.flagged:
            b, c, rel = self.deltas[fld]
            parts.append(f"{fld} {b:.0f} -> {c:.0f} ({rel:+.1%})")
        out = f"{caller} -> {comp}.{api}: " + ", ".join(parts)
        # confidence marker: when the overhead governor subsampled either
        # side, time columns are scaled estimates — counts stay exact
        rates = [r for r in (self.base.sample_rate, self.cand.sample_rate)
                 if r is not None]
        if rates:
            out += (f"  [subsampled: rate {min(rates):.3f} — "
                    f"time deltas are scaled estimates]")
        return out


@dataclass
class ProfileDiff:
    threshold: float
    fields: Tuple[str, ...]
    regressions: List[EdgeDelta]
    improvements: List[EdgeDelta]
    added: List[EdgeDelta]
    removed: List[EdgeDelta]
    unchanged: int
    #: whether significant NEW edges count as regressions (a rename/refactor
    #: can shift a hot edge's time into an added key — without this, such a
    #: slowdown would slip past the exit-code gate)
    flag_added: bool = True
    #: True when per-edge calibrated noise bands decided the flags (the
    #: global `threshold` then only covers uncalibrated edges)
    calibrated: bool = False

    @property
    def has_regressions(self) -> bool:
        return bool(self.regressions) or (self.flag_added
                                          and bool(self.added))

    def render(self, max_rows: int = 30) -> str:
        how = f"calibrated bands, fallback {self.threshold:.0%}" \
            if self.calibrated else f"threshold {self.threshold:.0%}"
        lines = [f"profile diff ({how} on "
                 f"{'/'.join(self.fields)}): "
                 f"{len(self.regressions)} regressed, "
                 f"{len(self.improvements)} improved, "
                 f"{len(self.added)} new, {len(self.removed)} gone, "
                 f"{self.unchanged} unchanged"]
        if self.regressions:
            lines.append("regressions:")
            for d in self.regressions[:max_rows]:
                lines.append(f"  REG  {d.describe()}")
            if len(self.regressions) > max_rows:
                lines.append(f"  ... ({len(self.regressions)-max_rows} more)")
        for title, rows in (("new edges:", self.added),
                            ("disappeared edges:", self.removed)):
            if rows:
                lines.append(title)
                for d in rows[:10]:
                    lines.append(f"       {d.describe()}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "threshold": self.threshold,
            "calibrated": self.calibrated,
            "fields": list(self.fields),
            "unchanged": self.unchanged,
            "regressions": [
                {"caller": d.key[0], "component": d.key[1], "api": d.key[2],
                 "flagged": {f: {"base": d.deltas[f][0],
                                 "cand": d.deltas[f][1],
                                 "rel": d.deltas[f][2]} for f in d.flagged}}
                for d in self.regressions
            ],
            "added": [list(d.key) for d in self.added],
            "removed": [list(d.key) for d in self.removed],
        }


def diff_profiles(base: FoldedTable, cand: FoldedTable,
                  threshold: float = 0.25,
                  fields: Sequence[str] = ("total_ns", "self_ns", "count"),
                  min_count: int = 1,
                  min_total_ns: int = 0,
                  flag_added: bool = True,
                  thresholds=None) -> ProfileDiff:
    """Per-edge comparison; an edge regresses when any requested field grew
    by more than its threshold relative to baseline.  Edges below
    `min_count` / `min_total_ns` in BOTH profiles are ignored (noise
    floor).  With `flag_added` (default), significant new edges also fail
    the gate — raise `min_total_ns` to tolerate small new edges.

    `thresholds` (repro.analysis.Thresholds, from `calibrate`) switches
    the gate to MEASURED variance: each calibrated edge tolerates
    k_sigma standard deviations of its own band instead of the global
    `threshold`, which stays the fallback for never-calibrated edges."""
    for fld in fields:
        if fld not in DIFF_FIELDS:
            raise ValueError(f"unknown diff field {fld!r}; "
                             f"choose from {DIFF_FIELDS}")
    regressions: List[EdgeDelta] = []
    improvements: List[EdgeDelta] = []
    added: List[EdgeDelta] = []
    removed: List[EdgeDelta] = []
    unchanged = 0

    def significant(e: Optional[EdgeStats]) -> bool:
        return e is not None and e.count >= min_count \
            and e.total_ns >= min_total_ns

    for key in sorted(base.edges.keys() | cand.edges.keys()):
        b = base.edges.get(key)
        c = cand.edges.get(key)
        if not (significant(b) or significant(c)):
            continue
        if b is None:
            added.append(EdgeDelta(key, None, c))
            continue
        if c is None:
            removed.append(EdgeDelta(key, b, None))
            continue
        d = EdgeDelta(key, b, c)
        improved = False
        for fld in fields:
            thr = threshold if thresholds is None \
                else thresholds.rel_threshold(key, fld, threshold)
            bv, cv = _value(b, fld), _value(c, fld)
            if bv == 0.0:
                rel = float("inf") if cv > 0 else 0.0
            else:
                rel = (cv - bv) / bv
            d.deltas[fld] = (bv, cv, rel)
            if rel > thr:
                d.flagged.append(fld)
            elif rel < -thr:
                improved = True
        if d.flagged:
            regressions.append(d)
        elif improved:
            improvements.append(d)
        else:
            unchanged += 1
    regressions.sort(
        key=lambda d: -max(d.deltas[f][2] for f in d.flagged))
    return ProfileDiff(threshold=threshold, fields=tuple(fields),
                       regressions=regressions, improvements=improvements,
                       added=added, removed=removed, unchanged=unchanged,
                       flag_added=flag_added,
                       calibrated=thresholds is not None)
