"""XFA in anger: inject the paper's canneal-style bug into the data path,
find it from the component/API views (not the code!), fix it, compare.

    PYTHONPATH=src python examples/diagnose_bug.py
"""
from benchmarks.effectiveness import ckptbug, databug


def main():
    for scenario in (databug, ckptbug):
        r = scenario()
        verdict = "DETECTED" if r["detected"] else "missed"
        print(f"{r['bug']:10s} {verdict:9s} via {r['signal']}; "
              f"fix improved step time by {r['speedup_pct']:.0f}%")


if __name__ == "__main__":
    main()
