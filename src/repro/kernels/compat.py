"""Version-compatibility shims for Pallas TPU across jax releases.

jax renamed `pltpu.TPUCompilerParams` to `pltpu.CompilerParams` (and will
eventually drop the old name).  jax==0.4.37 — the pinned CI version — only
has `TPUCompilerParams`; newer nightlies only have `CompilerParams`.  Every
kernel in this package goes through `tpu_compiler_params` so the kernels
themselves stay version-agnostic.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# Prefer the new name when both exist so deprecation warnings stay silent.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build the pallas_call `compiler_params` object for this jax version."""
    return _COMPILER_PARAMS_CLS(**kwargs)
