"""Profile store — a directory of per-process snapshot shards + the reducer.

The paper persists one file per *thread* at thread exit and merges offline;
a ProfileStore is the per-*process* analogue for fleets: every process (one
trainer rank, one serving replica, one host of a mesh) owns a single shard
file named after (label, host, pid) that it atomically overwrites on each
periodic snapshot — folds are cumulative, so the newest write supersedes
the previous one and a crash loses at most one interval.  The reducer merges
whatever shards exist into one profile through the vectorized column merge,
preserving the relation-aware (caller, callee, api) keys.
"""

from __future__ import annotations

import glob
import os
import socket
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

from ..core.folding import FoldedTable
from .snapshot import SNAPSHOT_SUFFIX, ProfileSnapshot


def tracer_folded(tracer=None) -> FoldedTable:
    """Merge every per-thread shadow table of `tracer` (default: the process
    tracer) into one raw FoldedTable — the process's current host-layer fold."""
    if tracer is None:
        from ..core import tracer as xfa
        tracer = xfa.TRACER
    return FoldedTable.merge_all(FoldedTable.from_set(tracer.tables))


class ProfileStore:
    """Shard directory: each process writes one shard; anyone can reduce."""

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    # -- writer side --------------------------------------------------------
    def shard_path(self, label: str = "shard") -> str:
        host = socket.gethostname().split(".")[0]
        return os.path.join(self.root,
                            f"{label}-{host}-{os.getpid()}{SNAPSHOT_SUFFIX}")

    def write_shard(self, folded: FoldedTable, label: str = "shard",
                    meta: Optional[Dict[str, Any]] = None) -> str:
        shard_meta: Dict[str, Any] = {
            "label": label,
            "host": socket.gethostname(),
            "pid": os.getpid(),
            "written_at": time.time(),
        }
        shard_meta.update(meta or {})
        snap = ProfileSnapshot.from_folded(folded, meta=shard_meta)
        return snap.save(self.shard_path(label))

    # -- reader side ----------------------------------------------------------
    def shard_paths(self) -> List[str]:
        return sorted(glob.glob(os.path.join(self.root,
                                             f"*{SNAPSHOT_SUFFIX}")))

    def load_shards(self) -> List[ProfileSnapshot]:
        """Load shard snapshots, EXCLUDING merged outputs: `merge -o` into
        the shard dir must not make the next reduce count everything twice."""
        shards = []
        skipped = []
        for p in self.shard_paths():
            snap = ProfileSnapshot.load(p)
            if "merged_from" in snap.meta:
                skipped.append(os.path.basename(p))
            else:
                shards.append(snap)
        if skipped:
            warnings.warn(
                f"profile dir {self.root!r}: ignoring already-merged "
                f"snapshot(s) {skipped} when reducing shards", stacklevel=2)
        return shards

    def reduce(self, meta: Optional[Dict[str, Any]] = None) -> ProfileSnapshot:
        shards = self.load_shards()
        if not shards:
            raise FileNotFoundError(f"no profile shards under {self.root!r}")
        # two shards with the same (label, host) but different pids are
        # either a stale shard from a previous run (double-counts every
        # edge) or replicas sharing a label — either way worth surfacing
        by_writer: Dict[Tuple[str, str], int] = {}
        for s in shards:
            k = (str(s.meta.get("label", "?")), str(s.meta.get("host", "?")))
            by_writer[k] = by_writer.get(k, 0) + 1
        dups = [k for k, n in by_writer.items() if n > 1]
        if dups:
            warnings.warn(
                f"profile dir {self.root!r} holds multiple shards with the "
                f"same (label, host) {dups}; the reduce SUMS them. If these "
                "are stale shards from a previous run, use a fresh "
                "--profile-dir per run; if they are concurrent replicas, "
                "give each a distinct label (e.g. --profile-label serve-0)",
                stacklevel=2)
        if len(shards) == 1 and not meta:
            return shards[0]
        return ProfileSnapshot.merge(shards, meta=meta)

    def __len__(self) -> int:
        return len(self.shard_paths())


def load_profile(path: str) -> ProfileSnapshot:
    """Load a profile from a snapshot file, a shard directory (reduced), or
    a legacy FoldedTable json dump."""
    if os.path.isdir(path):
        return ProfileStore(path).reduce()
    if path.endswith(".json"):
        return ProfileSnapshot.from_folded(FoldedTable.load(path),
                                           meta={"label": path})
    return ProfileSnapshot.load(path)
