"""Static cross-flow analysis over compiled HLO — the TPU 'binary'.

Paper mapping: Scaler's interceptor patches linkage tables found by reading
the ELF binary — *selective* instrumentation of linkage boundaries only.  On
TPU the compiled HLO module is the binary, and the inter-island links are the
ICI/DCI collectives.  This module reads `compiled.as_text()` (post-SPMD
optimized HLO, per-device view) and attributes every

    all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute

to the model component that issued it, via the `op_name` metadata that
jax.named_scope threads through lowering.  Zero runtime overhead: the program
is never touched, exactly like reading `.rela.plt` never executes the binary.

Outputs feed three consumers:
  * the component×component *collective flow matrix* (views.py),
  * the roofline collective term (wire bytes / link bandwidth),
  * redundancy detection for the perf loop (same tensor gathered twice).

Wire-byte model (ring algorithm over a group of n):
  all-gather       (n-1)/n × output_bytes   per participating device
  reduce-scatter   (n-1)/n × input_bytes
  all-reduce       2(n-1)/n × input_bytes   (reduce-scatter + all-gather)
  all-to-all       (n-1)/n × input_bytes
  collective-permute  input_bytes           (point-to-point)
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(?:\[[0-9,]+\])+(T\(([0-9,]+)\))?")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(dtype: str, dims_str: str) -> int:
    n = 1
    if dims_str.strip():
        for d in dims_str.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _parse_shapes(text: str) -> List[int]:
    """All tensor byte-sizes appearing in `text` (a fragment of an HLO line)."""
    return [_shape_bytes(m.group(1), m.group(2))
            for m in _SHAPE_RE.finditer(text)]


@dataclass
class CollectiveFlow:
    """One collective op in the compiled module (per-device view)."""

    kind: str
    hlo_name: str
    input_bytes: int        # per-device operand bytes
    output_bytes: int       # per-device result bytes
    group_size: int         # participants per replica group
    group_stride: int       # device-id stride inside a group (1 = innermost)
    op_name: str            # full op_name metadata path
    component: str          # resolved component (via known-component match)
    axis: str               # best-effort mesh-axis name

    @property
    def wire_bytes(self) -> float:
        """Bytes each participant puts on the interconnect (ring model)."""
        n = max(self.group_size, 1)
        if n == 1:
            return 0.0
        f = (n - 1) / n
        if self.kind == "all-gather":
            return f * self.output_bytes
        if self.kind == "reduce-scatter":
            return f * self.input_bytes
        if self.kind == "all-reduce":
            return 2.0 * f * self.input_bytes
        if self.kind == "all-to-all":
            return f * self.input_bytes
        if self.kind == "collective-permute":
            return float(self.input_bytes)
        return float(self.input_bytes)


def _resolve_component(op_name: str, known: Sequence[str]) -> str:
    """Innermost known component mentioned in the op_name scope path."""
    segments = re.split(r"[/()]", op_name)
    for seg in reversed(segments):
        seg = seg.strip()
        for comp in known:
            if seg == comp or seg.startswith(comp + ".") or seg.startswith(comp + "["):
                return comp
    # fall back: substring match, innermost first
    for seg in reversed(segments):
        for comp in known:
            if comp in seg:
                return comp
    return "app"


def _resolve_axis(group_size: int, group_stride: int,
                  mesh_axes: Dict[str, int]) -> str:
    """Best-effort mesh-axis attribution from (size, stride).

    With mesh (pod, data, model) laid out row-major, device id =
    ((pod*D)+data)*M + model.  A group over `model` has stride 1; over
    `data` stride M; over `pod` stride D*M.  Size breaks ties first, stride
    second; combined-axis groups report 'axis0+axis1'.
    """
    names = list(mesh_axes.keys())
    sizes = list(mesh_axes.values())
    # stride of each axis in row-major device numbering
    strides = {}
    acc = 1
    for name in reversed(names):
        strides[name] = acc
        acc *= mesh_axes[name]
    total = acc
    candidates = [n for n in names if mesh_axes[n] == group_size]
    if len(candidates) == 1:
        return candidates[0]
    for n in candidates:
        if strides[n] == group_stride:
            return n
    # combined axes (e.g. pod+data gradient reduction)
    for i in range(len(names)):
        for j in range(i + 1, len(names) + 1):
            size = 1
            for n in names[i:j]:
                size *= mesh_axes[n]
            if size == group_size and (j == len(names) or
                                       strides[names[j - 1]] == group_stride):
                return "+".join(names[i:j])
    if group_size == total:
        return "+".join(names)
    return candidates[0] if candidates else f"size{group_size}"


def parse_collective_flows(hlo_text: str,
                           known_components: Sequence[str] = (),
                           mesh_axes: Optional[Dict[str, int]] = None,
                           ) -> List[CollectiveFlow]:
    """Scan optimized HLO text and extract every collective op."""
    flows: List[CollectiveFlow] = []
    mesh_axes = mesh_axes or {}
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line or "=" not in line:
            continue
        kind = None
        for k in COLLECTIVE_KINDS:
            # match op name (e.g. ' = bf16[..] all-gather(' or 'all-gather-start(')
            if re.search(rf"[\s)]({k})(-start)?\(", line):
                kind = k
                break
        if kind is None:
            continue
        if re.search(rf"{kind}-done", line.split("=")[1][:120]):
            continue  # async completion — counted at -start
        lhs, rhs = line.split("=", 1)
        hlo_name = lhs.strip().lstrip("%")
        # result shapes before the op name; operand shapes inside parens
        opn = re.search(rf"({kind})(-start)?\(", rhs)
        result_part = rhs[: opn.start()]
        rest = rhs[opn.end():]
        paren_depth = 1
        i = 0
        while i < len(rest) and paren_depth:
            if rest[i] == "(":
                paren_depth += 1
            elif rest[i] == ")":
                paren_depth -= 1
            i += 1
        operand_part = rest[: i - 1]
        attr_part = rest[i:]

        out_bytes = sum(_parse_shapes(result_part))
        in_bytes = sum(_parse_shapes(operand_part))
        if kind == "all-gather" and "-start" in rhs[: opn.end()]:
            # all-gather-start result is a tuple (operand, result) — keep result
            shapes = _parse_shapes(result_part)
            if len(shapes) >= 2:
                out_bytes = shapes[-1]

        group_size, group_stride = 1, 1
        m = _GROUPS_IOTA_RE.search(attr_part) or _GROUPS_IOTA_RE.search(rhs)
        if m:
            n_groups, g_size = int(m.group(1)), int(m.group(2))
            group_size = g_size
            # no transpose => contiguous ids => stride 1; transposed => outer
            if m.group(3):
                group_stride = n_groups
            else:
                group_stride = 1
        else:
            m2 = _GROUPS_EXPLICIT_RE.search(attr_part) or _GROUPS_EXPLICIT_RE.search(rhs)
            if m2:
                ids = [int(x) for x in m2.group(1).replace(" ", "").split(",") if x]
                group_size = len(ids)
                group_stride = (ids[1] - ids[0]) if len(ids) > 1 else 1
        if kind == "collective-permute":
            group_size = 2  # point-to-point; wire bytes = full operand

        opname_m = _OPNAME_RE.search(raw)
        op_name = opname_m.group(1) if opname_m else ""
        component = _resolve_component(op_name, known_components)
        axis = _resolve_axis(group_size, group_stride, mesh_axes) \
            if mesh_axes else f"size{group_size}"
        flows.append(CollectiveFlow(
            kind=kind, hlo_name=hlo_name, input_bytes=in_bytes,
            output_bytes=out_bytes, group_size=group_size,
            group_stride=group_stride, op_name=op_name,
            component=component, axis=axis))
    return flows


@dataclass
class CollectiveSummary:
    """Aggregated collective flows: per component, per kind, per axis."""

    flows: List[CollectiveFlow]
    by_component: Dict[str, float] = field(default_factory=dict)
    by_kind: Dict[str, float] = field(default_factory=dict)
    by_axis: Dict[str, float] = field(default_factory=dict)
    total_wire_bytes: float = 0.0

    @staticmethod
    def build(flows: List[CollectiveFlow]) -> "CollectiveSummary":
        s = CollectiveSummary(flows)
        for f in flows:
            wb = f.wire_bytes
            s.by_component[f.component] = s.by_component.get(f.component, 0.0) + wb
            s.by_kind[f.kind] = s.by_kind.get(f.kind, 0.0) + wb
            s.by_axis[f.axis] = s.by_axis.get(f.axis, 0.0) + wb
            s.total_wire_bytes += wb
        return s

    def schedule(self) -> List[Tuple[str, str, str, float]]:
        """(kind, component, axis, wire_bytes) in program order — the
        'collective schedule' recorded in EXPERIMENTS.md §Dry-run."""
        return [(f.kind, f.component, f.axis, f.wire_bytes) for f in self.flows]


def find_redundant_gathers(flows: List[CollectiveFlow]) -> List[Tuple[str, int]]:
    """Perf-loop helper: identical (kind, bytes, component, axis) collectives
    appearing more than once may indicate a re-gathered tensor (the paper's
    'same API invoked extensively' smell, XFA'd at the HLO level)."""
    seen: Dict[Tuple[str, int, str, str], int] = {}
    for f in flows:
        key = (f.kind, f.input_bytes, f.component, f.axis)
        seen[key] = seen.get(key, 0) + 1
    return [(f"{k[0]} {k[1]}B {k[2]}@{k[3]}", n)
            for k, n in sorted(seen.items()) if n > 1 and k[1] > 0]
