"""Component view / API view / flow matrix — the paper's two reports.

Paper mapping (Scaler §2.2, §3.5, Figure 1):

 * component view: for one component, the share of its time spent on itself
   ('Self'), on every other component it calls into, and on 'Wait'.
 * API view: inside one component, the time distribution over its APIs.
 * (ours, implied by XFA) flow matrix: component × component totals — the
   cross-flow picture at a glance; on TPU it additionally exists for
   collective wire bytes (hlo_flows.CollectiveSummary).

All views are computed from FoldedTables — the online fold already did the
heavy lifting, which is why the paper's offline visualizer runs in 0.43 s vs
perf's 33 s (§4.3.2); benchmarks/offline.py reproduces that comparison.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .folding import EdgeStats, FoldedTable
from .shadow import KIND_WAIT, edge_label


@dataclass
class ViewRow:
    label: str
    time_ns: float
    pct: float
    count: int = 0
    metrics: Dict[str, float] = field(default_factory=dict)


@dataclass
class View:
    title: str
    rows: List[ViewRow]
    total_ns: float

    def render(self, max_rows: int = 30) -> str:
        lines = [self.title, f"{'-'*len(self.title)}"]
        lines.append(f"{'entry':<42}{'time_ms':>12}{'%':>8}{'count':>12}")
        for r in self.rows[:max_rows]:
            lines.append(f"{r.label:<42}{r.time_ns/1e6:>12.3f}"
                         f"{r.pct:>7.1f}%{r.count:>12}")
        if len(self.rows) > max_rows:
            lines.append(f"... ({len(self.rows)-max_rows} more)")
        return "\n".join(lines)

    def top(self) -> Optional[ViewRow]:
        return self.rows[0] if self.rows else None

    def find(self, label: str) -> Optional[ViewRow]:
        for r in self.rows:
            if r.label == label:
                return r
        return None


def component_view(folded: FoldedTable, component: str,
                   total_ns: Optional[float] = None) -> View:
    """Time `component` spends on itself vs on each callee component.

    Self = sum over edges INTO `component` of self_ns (its own body time),
    callee rows = sum over edges FROM `component` of total time into each
    target, Wait separated.  If the component has no inbound edges (it is the
    app/root), `total_ns` supplies the denominator (wall time).
    """
    spent_on: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    wait_ns = 0.0
    wait_count = 0
    for (caller, callee, api), e in folded.edges.items():
        if caller != component:
            continue
        if e.kind == KIND_WAIT:
            wait_ns += e.total_ns
            wait_count += e.count
        else:
            spent_on[callee] = spent_on.get(callee, 0.0) + e.total_ns
            counts[callee] = counts.get(callee, 0) + e.count

    inbound_total = sum(e.total_ns for (c, t, a), e in folded.edges.items()
                        if t == component)
    inbound_child = sum(e.child_ns for (c, t, a), e in folded.edges.items()
                        if t == component)
    self_ns = max(inbound_total - inbound_child, 0.0)
    outbound = sum(spent_on.values()) + wait_ns
    if total_ns is None:
        total = max(inbound_total, outbound + self_ns)
    else:
        # components can legitimately exceed the observed wall (e.g. compile
        # happened outside the observed steps) — keep pct <= 100
        total = max(total_ns, outbound)
        self_ns = max(total - outbound, 0.0)
    if total == 0:
        total = 1.0

    rows = [ViewRow("Self", self_ns, 100.0 * self_ns / total)]
    if wait_ns:
        rows.append(ViewRow("Wait", wait_ns, 100.0 * wait_ns / total,
                            wait_count))
    for callee, t in spent_on.items():
        rows.append(ViewRow(callee, t, 100.0 * t / total, counts[callee]))
    rows.sort(key=lambda r: -r.time_ns)
    return View(f"Component view: {component}", rows, total)


def api_view(folded: FoldedTable, component: str) -> View:
    """Runtime distribution over APIs inside `component` (all callers merged,
    but available per-caller via api_view_by_caller — relation preserved)."""
    per_api: Dict[str, EdgeStats] = {}
    for (caller, callee, api), e in folded.edges.items():
        if callee != component:
            continue
        cur = per_api.get(api)
        per_api[api] = e if cur is None else cur.merge(e)
    total = sum(e.total_ns for e in per_api.values()) or 1.0
    rows = [ViewRow(api, e.total_ns, 100.0 * e.total_ns / total, e.count,
                    dict(e.metrics))
            for api, e in per_api.items()]
    rows.sort(key=lambda r: -r.time_ns)
    return View(f"API view: {component}", rows, total)


def api_view_by_caller(folded: FoldedTable, component: str) -> View:
    """API view keyed by (caller -> api): the relation-aware drill-down."""
    total = sum(e.total_ns for (c, t, a), e in folded.edges.items()
                if t == component) or 1.0
    rows = [ViewRow(f"{caller} -> {api}", e.total_ns,
                    100.0 * e.total_ns / total, e.count, dict(e.metrics))
            for (caller, callee, api), e in folded.edges.items()
            if callee == component]
    rows.sort(key=lambda r: -r.time_ns)
    return View(f"API view (by caller): {component}", rows, total)


def flow_matrix(folded: FoldedTable) -> Tuple[List[str], List[List[float]]]:
    """Dense component×component matrix of total_ns (caller rows)."""
    comps = folded.components()
    idx = {c: i for i, c in enumerate(comps)}
    mat = [[0.0] * len(comps) for _ in comps]
    for (caller, callee, _api), e in folded.edges.items():
        mat[idx[caller]][idx[callee]] += e.total_ns
    return comps, mat


def render_flow_matrix(folded: FoldedTable, unit: float = 1e6,
                       unit_name: str = "ms") -> str:
    comps, mat = flow_matrix(folded)
    w = max(10, max((len(c) for c in comps), default=10) + 1)
    head = " " * w + "".join(f"{c:>{w}}" for c in comps)
    lines = [f"Flow matrix ({unit_name}, rows=caller)", head]
    for i, c in enumerate(comps):
        lines.append(f"{c:>{w}}" + "".join(
            f"{mat[i][j]/unit:>{w}.2f}" for j in range(len(comps))))
    return "\n".join(lines)


def render_percentiles(folded: FoldedTable, max_rows: int = 30) -> str:
    """Latency-percentile table over the edges that carry histograms
    (schema v2); empty string when none do, so report output is unchanged
    for v1 profiles.  Jitter is the p99 - p50 percentile delta."""
    rows = [(edge_label(k), e) for k, e in folded.edges.items()
            if e.hist is not None]
    if not rows:
        return ""
    rows.sort(key=lambda r: -r[1].p99_ns)
    title = "Latency percentiles (ms, log-bucket histograms)"
    lines = [title, "-" * len(title),
             f"{'edge':<42}{'count':>8}{'p50':>10}{'p95':>10}"
             f"{'p99':>10}{'jitter':>10}"]
    for label, e in rows[:max_rows]:
        n = int(e.hist.sum())
        lines.append(f"{label:<42}{n:>8}{e.p50_ns/1e6:>10.3f}"
                     f"{e.p95_ns/1e6:>10.3f}{e.p99_ns/1e6:>10.3f}"
                     f"{e.jitter_ns/1e6:>10.3f}")
    if len(rows) > max_rows:
        lines.append(f"... ({len(rows)-max_rows} more)")
    return "\n".join(lines)


def render_sampling(folded: FoldedTable, max_rows: int = 30) -> str:
    """Sampling-confidence table over the edges the overhead governor
    subsampled (schema v3); empty string when none were, so report
    output is unchanged for fully-sampled profiles.  Counts are always
    exact; the time columns of listed edges are unbiased 1-in-k
    scale-ups at the shown effective rate."""
    rows = [(edge_label(k), e) for k, e in folded.edges.items()
            if e.sample_rate is not None]
    if not rows:
        return ""
    rows.sort(key=lambda r: r[1].sample_rate)
    title = "Sampling back-off (governor; counts exact, times scaled)"
    lines = [title, "-" * len(title),
             f"{'edge':<42}{'count':>10}{'rate':>10}{'~1-in-k':>10}"]
    for label, e in rows[:max_rows]:
        k = round(1.0 / e.sample_rate) if e.sample_rate > 0 else 0
        lines.append(f"{label:<42}{e.count:>10}{e.sample_rate:>10.4f}"
                     f"{k:>10}")
    if len(rows) > max_rows:
        lines.append(f"... ({len(rows)-max_rows} more)")
    return "\n".join(lines)


def metric_view(folded: FoldedTable, metric: str) -> View:
    """Rank edges by a folded device/static metric (flops, wire_bytes, ...)."""
    rows = []
    total = sum(e.metrics.get(metric, 0.0) for e in folded.edges.values()) or 1.0
    for (caller, callee, api), e in folded.edges.items():
        v = e.metrics.get(metric, 0.0)
        if v:
            rows.append(ViewRow(f"{caller} -> {callee}.{api}", v,
                                100.0 * v / total, e.count, dict(e.metrics)))
    rows.sort(key=lambda r: -r.time_ns)
    return View(f"Metric view: {metric}", rows, total)
