"""Context parallelism for long-context decode: distributed split-K.

For long_500k (one query token vs a 524k KV cache, batch 1) neither batch
nor (often) heads can absorb the mesh — the cache SEQUENCE is the shardable
dim. The flash-decode split-K pattern maps onto the mesh:

  1. each rank runs decode attention over its LOCAL KV range, returning the
     unnormalized-softmax residuals (o_local, m_local, l_local) — the Pallas
     kernel (kernels/decode_attention.py) and the oracle both support
     return_residuals=True;
  2. one SMALL all-gather of the partials over the context axis
     ([shards, B, H(, D)] — KB not GB);
  3. the numerically-stable merge (kernels/ref.combine_decode_partials).

Wire cost: shards x (B·H·(D+2)) floats instead of gathering the cache
(B·H·S·D) — for zamba2 long_500k that is ~100 KB vs ~2.7 GB per shared-attn
invocation. Used via shard_map; tested for exactness against the unsharded
oracle in tests/test_context_parallel.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops, ref
from repro.parallel.compat import shard_map


def _local_split_k(q, k_loc, v_loc, pos, *, axis: str, seq_shards: int,
                   impl: str):
    """Per-shard body: local residuals + gather + merge (runs in shard_map).

    q: [B, Hq_loc, D] (replicated over the context axis);
    k_loc/v_loc: [B, Hkv_loc, S/shards, D]; pos: [] global decode position.
    """
    B, Hq, D = q.shape
    s_loc = k_loc.shape[2]
    idx = jax.lax.axis_index(axis)
    start = idx * s_loc
    # local valid length: clamp (pos+1 - start) into [0, s_loc]
    kv_len = jnp.clip(pos + 1 - start, 0, s_loc)
    kv_len = jnp.broadcast_to(kv_len, (B,)).astype(jnp.int32)
    o, (m, l) = ops.decode_attention(q, k_loc, v_loc, kv_len=kv_len,
                                     impl=impl, return_residuals=True)
    # fully-masked shards contribute l=0 partials; combine handles them via
    # m=-inf weighting (exp(-inf)=0)
    m = jnp.where(kv_len[:, None] > 0, m, -1e30)
    with jax.named_scope("decode_splitk_gather"):
        o_all = jax.lax.all_gather(o, axis)          # [shards, B, Hq, D]
        m_all = jax.lax.all_gather(m, axis)
        l_all = jax.lax.all_gather(l, axis)
    return ref.combine_decode_partials(o_all, m_all, l_all)


def context_parallel_decode(q, k, v, pos, mesh: Mesh, *,
                            context_axis: str = "data",
                            head_axis: Optional[str] = "model",
                            impl: str = "auto"):
    """Decode attention with the KV cache sharded over `context_axis`.

    q: [B, Hq, D]; k, v: [B, Hkv, S, D] with S sharded over context_axis and
    heads (optionally) over head_axis. Returns [B, Hq, D] replicated over
    the context axis (sharded over the head axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shards = sizes.get(context_axis, 1)
    Hkv = k.shape[1]
    h_ax = head_axis if (head_axis and Hkv % sizes.get(head_axis, 1) == 0) \
        else None
    g = q.shape[1] // Hkv
    qspec = P(None, h_ax, None)
    kvspec = P(None, h_ax, context_axis, None)

    body = functools.partial(_local_split_k, axis=context_axis,
                             seq_shards=shards, impl=impl)
    fn = shard_map(body, mesh=mesh,
                       in_specs=(qspec, kvspec, kvspec, P()),
                       out_specs=qspec, check_vma=False)
    return fn(q, k, v, pos)
