"""Serving engine: iteration-level continuous batching behind a client API.

Layering of this package:

    scheduler.py  admission + prefill planning — FCFS queue -> free slots
                  and continuation chunks under a per-tick prefill budget
    sampling.py   per-request sampling params as per-slot vectors, ONE
                  jitted pooled sampler (greedy/temperature/top-k/top-p)
    engine.py     the slot pool + compiled positioned-chunk forward, the
                  background serving thread, and the client handles

EVERY model step is one `forward_chunk` — a T-token chunk written at
per-slot cache offsets: admission bulk prefill, mid-prompt continuation
chunks and the pooled decode tick are the same operation at different
widths (the model layer's rope angles, row-range cache scatters and
offset-causal masks are all per-row).  Prefill is batched ACROSS slots:
each tick's selected chunks (continuations + admissions) group by
compiled width (scheduler.batched_prefill_plan) and every group runs as
ONE multi-row forward_chunk — the participating slots' batch=1 cache
stashes gather into a [B]-row cache, advance at per-row `pos` with
per-row `valid`, and scatter back (rows whose prompt completes scatter
into the pool and sample their first token from that chunk's last-valid
logits).  Concurrent admissions therefore share the accelerator instead
of serializing batch=1 calls; `prefill_batch=1` reproduces the per-slot
path through the same code.  Decode then runs ONE compiled width-1
chunk over the whole pool at per-slot positions: true iteration-level
batching with zero recompilation as requests come and go.  Chunk widths
AND group batch dims round up to power-of-two buckets (pad masked
in-model via `valid`), so the set of compiled prefill programs is
O(log prefill_batch x log max_seq_len), not one per distinct prompt
length or admission pattern.

Paged KV-cache pool (ServeConfig.max_cache_pages > 0, transformer/MLA
families): the contiguous [max_batch, max_seq_len] cache becomes a fixed
arena of pages plus per-slot block tables (paging.PageAllocator owns the
accounting).  Admission is gated by FREE PAGES — the scheduler's page
gate reserves a request's worst-case pages (prompt + max_new - 1 rows)
or back-pressures the FCFS queue — and pages are granted lazily as a
slot's `pos` crosses page boundaries, recycled at finish.  Prefill
groups and the decode tick write straight into the shared arena through
the tables (no batch=1 stashes, no scatter); pages-in-use /
high-water-mark / capacity fold as `serve.cache_pages_*` gauges, the
saturation resource the cache-pressure detector reads.  Recurrent
families (mamba/xlstm/encdec), whose state is O(1) in sequence length,
keep the dense layout behind the same API.

Client API: `submit()` returns a Request handle immediately; tokens
stream through an optional `on_token` callback and `handle.result()`
blocks until completion.  `start()` runs the engine on a background
thread (open-loop serving); without it, `run_until_drained()` drives the
same loop synchronously (closed-loop benchmarks, tests).

XFA instrumentation ('serve'): prefill_request and decode_tick are
traced boundaries, every batched chunk step folds a `prefill_chunk`
duration, and every batched call folds a `prefill_batch_occupancy`
gauge (percent of compiled rows that were real slots, not bucket pad) —
the flow graph separates prefill cost from decode cost per tick and
shows whether cross-slot batching engages;
queue_wait (Wait kind), ttft, decode_token and e2e latency phases fold
via tracer.record_duration (which also folds the bounded latency
histograms behind the p50/p95/p99 read-out); truncated_prompt is a count
event.  Requests carrying a deadline (submit(deadline_ms=...) or
ServeConfig.deadline_ms) additionally fold one deadline_met or
deadline_miss count event at finish — the signal the slo-violation
detector reads.  Shards land in the profile store exactly like trainer
shards — `repro.profile query --kind serve`, report/diff/timeline all
apply to serving runs natively.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.core import tracer as xfa
from repro.core.shadow import KIND_WAIT
from repro.models.api import Model

from .sampling import GREEDY, PooledSampler, SamplingParams
from .scheduler import Scheduler


@dataclasses.dataclass
class Request:
    """Client handle for one generation request.

    Returned by ServingEngine.submit; safe to read from other threads.
    `result()` blocks until the request finishes; `on_token` (if given)
    is invoked from the engine thread for every generated token."""
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 32
    sampling: SamplingParams = GREEDY
    submitted_at: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False            # prompt cut to fit the cache row
    #: e2e latency contract in ms (None: untracked); at finish the engine
    #: folds deadline_met/deadline_miss and sets `deadline_missed`
    deadline_ms: Optional[float] = None
    deadline_missed: Optional[bool] = None
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    on_token: Optional[Callable[["Request", int], None]] = None
    error: Optional[BaseException] = None      # engine failure, if any
    _done_event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    def result(self, timeout: Optional[float] = None) -> "Request":
        """Block until the request completes; raises TimeoutError, or
        RuntimeError if the engine failed while this request was live."""
        if not self._done_event.wait(timeout):
            raise TimeoutError(f"request {self.uid} not done in {timeout}s")
        if self.error is not None:
            raise RuntimeError(
                f"serving engine failed while request {self.uid} was "
                f"in flight") from self.error
        return self

    # -- latency accessors (None until the phase happened) ------------------
    @property
    def queue_wait_s(self) -> Optional[float]:
        return None if self.admitted_at is None \
            else self.admitted_at - self.submitted_at

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.first_token_at is None \
            else self.first_token_at - self.submitted_at

    @property
    def e2e_s(self) -> Optional[float]:
        return None if self.finished_at is None \
            else self.finished_at - self.submitted_at


def _scatter_slot(pool, one, slot_idx: int):
    """Write a batch=1 cache pytree into row `slot_idx` of the pool cache.

    The batch axis differs per family/leaf ([L,B,...] KV rows, xlstm's
    [n_super,n_m,B,...] states, ...) — it is inferred per leaf as the
    first axis where the batch=1 tree has extent 1 and the pool differs.
    (The previous engine hardcoded axis 1, which silently aliased every
    xlstm request onto batch row 0.)"""
    def leaf(p, o):
        if p.shape == o.shape:         # max_batch == 1: full replace
            return o.astype(p.dtype)
        ax = next(d for d, (a, b) in enumerate(zip(p.shape, o.shape))
                  if b == 1 and a != b)
        idx = [0] * p.ndim
        idx[ax] = slot_idx
        return jax.lax.dynamic_update_slice(p, o.astype(p.dtype), tuple(idx))
    return jax.tree.map(leaf, pool, one)


class ServingEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig) -> None:
        self.model = model
        self.params = params
        self.scfg = scfg
        if scfg.xfa_overhead_budget > 0:
            # adaptive overhead governor: per-tick boundaries back off to
            # 1-in-k timing under load, counting stays exact (core.sampler)
            xfa.TRACER.set_overhead_budget(scfg.xfa_overhead_budget)
        self.scheduler = Scheduler(scfg)
        self.sampler = PooledSampler(scfg.max_batch)
        self.table = model.table()
        # paged pool: swap the contiguous [max_batch, max_seq_len] cache
        # for a page arena + per-slot block tables, admission gated by
        # free pages.  Families without a paged entry point (recurrent
        # state is O(1) in sequence length) keep the dense layout even
        # when max_cache_pages is set — same engine API either way.
        self.paged = bool(scfg.max_cache_pages > 0
                          and model.forward_chunk_paged is not None)
        self.allocator = None
        if self.paged:
            from .paging import PageAllocator
            self.allocator = PageAllocator(scfg.max_cache_pages,
                                           scfg.page_size)
            # virtual pages per slot: covers a full max_seq_len row (the
            # block table is the slot's whole address space; unassigned
            # entries point at scratch page 0)
            self._n_blocks = -(-scfg.max_seq_len // scfg.page_size)
            self.block_tables = np.zeros(
                (scfg.max_batch, self._n_blocks), np.int32)
            self.cache = model.init_paged_cache(scfg.max_cache_pages,
                                                scfg.page_size)
            self._decode = jax.jit(model.decode_step_paged,
                                   donate_argnums=(3,))
            self._chunk = jax.jit(model.forward_chunk_paged,
                                  donate_argnums=(3,))
            self.scheduler.page_gate = self._page_gate
        else:
            self.cache = model.init_cache(scfg.max_batch, scfg.max_seq_len)
            self._decode = jax.jit(model.decode_step, donate_argnums=(3,))
            self._chunk = jax.jit(model.forward_chunk, donate_argnums=(3,))
        # one compiled program per (BATCH BUCKET, CHUNK WIDTH) pair (both
        # bucketed powers of two); _chunk_programs tracks the scheduled
        # set — tests assert it stays bounded regardless of how many
        # distinct prompt lengths or admission patterns arrive
        self._chunk_programs: set = set()
        # per-leaf batch axes of the cache pytree (-1: unbatched leaf),
        # inferred once from shapes — the batch axis differs per
        # family/leaf ([L,B,...] KV rows, xlstm's [n_super,n_m,B,...]
        # states, ...) and the batched-prefill gather/scatter needs it
        s1 = jax.eval_shape(lambda: model.init_cache(1, scfg.max_seq_len))
        s2 = jax.eval_shape(lambda: model.init_cache(2, scfg.max_seq_len))
        self._batch_axes = jax.tree.map(
            lambda a, b: next(
                (d for d, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y), -1), s1, s2)
        self._pad_stashes: dict = {}
        self._uid = 0
        self.completed: List[Request] = []
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        self._error: Optional[BaseException] = None   # terminal loop failure
        self._profile_store = None
        self._publisher = None
        self._ticks = 0
        if scfg.profile_dir:
            from repro.profile import (ProfileStore, RetentionPolicy,
                                       register_run)
            self._profile_store = ProfileStore(
                scfg.profile_dir,
                retention=RetentionPolicy(
                    keep_last=scfg.profile_keep_last,
                    max_age_s=scfg.profile_max_age_s,
                    max_bytes=scfg.profile_max_bytes))
            # index this replica in the run registry so fleets of serving
            # runs are queryable (`repro.profile query --kind serve ...`)
            from repro.parallel.axes import get_runtime_mesh
            mesh = get_runtime_mesh()
            register_run(
                scfg.profile_dir,
                config=model.cfg.name, arch=model.cfg.family,
                mesh_shape=tuple(mesh.devices.shape)
                if mesh is not None else None,
                mesh_axes=tuple(mesh.axis_names)
                if mesh is not None else None,
                label=scfg.profile_label, kind="serve",
                meta={"max_batch": scfg.max_batch,
                      "max_seq_len": scfg.max_seq_len,
                      **({"page_size": scfg.page_size,
                          "max_cache_pages": scfg.max_cache_pages}
                         if self.paged else {}),
                      **dict(scfg.profile_meta)})
            if scfg.xfa_collector:
                from repro.profile import FleetPublisher
                self._publisher = FleetPublisher(scfg.xfa_collector,
                                                 scfg.profile_dir)

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               sampling: Optional[SamplingParams] = None,
               on_token: Optional[Callable[[Request, int], None]] = None,
               deadline_ms: Optional[float] = None) -> Request:
        """Enqueue a request; returns its handle immediately.

        `deadline_ms` sets this request's e2e latency contract (falls
        back to ServeConfig.deadline_ms when that is > 0): at finish the
        engine folds a deadline_met/deadline_miss count event and flags
        the handle, feeding the slo-violation detector.  The deadline is
        observational — a late request still completes."""
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the engine "
                             "always samples at least the first token)")
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            # reject per-request: a malformed prompt failing inside
            # _admit would kill the engine loop and every other client
            raise ValueError(f"prompt must be a non-empty 1-D token "
                             f"array, got shape {prompt.shape}")
        if sampling is None:
            sampling = SamplingParams(
                temperature=self.scfg.temperature, top_k=self.scfg.top_k,
                top_p=self.scfg.top_p, seed=self.scfg.sample_seed)
        if deadline_ms is None and self.scfg.deadline_ms > 0:
            deadline_ms = self.scfg.deadline_ms
        # fit the request to the cache row AT SUBMIT, not mid-prefill:
        # the client sees the truncation on the handle it got back, and
        # the paged admission gate prices the rows that will really be
        # used.  Keep at least one prompt token even when max_new_tokens
        # alone (nearly) fills the row — matches Scheduler.admit_cost.
        truncated = False
        limit = max(1, self.scfg.max_seq_len - max_new_tokens - 1)
        if prompt.size > limit:
            # visible truncation: flagged on the handle AND folded as a
            # count event so fleets can alarm on it
            prompt = prompt[:limit]
            truncated = True
            xfa.count_event("serve", "truncated_prompt")
        cap = self.scfg.max_seq_len - prompt.size
        if max_new_tokens > cap:
            # generation budget clamped so the slot's pos can never run
            # off the end of its cache row (oversized max_new_tokens)
            max_new_tokens = cap
            truncated = True
            xfa.count_event("serve", "clamped_max_new")
        if self.paged:
            # a request whose worst case exceeds the whole pool could
            # never pass the page gate: structured rejection here instead
            # of a silent deadlock at the head of the FCFS queue
            rows = int(prompt.size) + max_new_tokens - 1
            need = self.allocator.pages_needed(rows)
            if need > self.allocator.usable:
                raise ValueError(
                    f"request needs {need} cache pages ({rows} rows at "
                    f"page_size={self.scfg.page_size}) but the pool has "
                    f"only {self.allocator.usable} usable pages "
                    f"(max_cache_pages={self.scfg.max_cache_pages}, "
                    f"page 0 reserved)")
        # timestamp BEFORE taking the lock: a tick in progress holds it,
        # and that wait is queueing delay the client really experienced
        submitted_at = time.monotonic()
        with self._work:
            if self._error is not None:
                # a dead engine must reject, not enqueue into a void where
                # result() would block forever
                raise RuntimeError("serving engine has failed; no further "
                                   "requests accepted") from self._error
            self._uid += 1
            req = Request(self._uid, prompt,
                          max_new_tokens, sampling=sampling,
                          submitted_at=submitted_at, on_token=on_token,
                          deadline_ms=deadline_ms, truncated=truncated)
            self.scheduler.add(req)
            self._work.notify_all()
        return req

    def start(self) -> "ServingEngine":
        """Run the engine loop on a background daemon thread.  After a
        timed-out stop() this blocks until the old loop finishes its tick
        and is reaped — there is never a second loop over the same pool,
        and start() returning means the engine IS serving."""
        while True:
            with self._lock:
                if self._error is not None:
                    raise RuntimeError("serving engine has failed; it "
                                       "cannot be restarted") from self._error
                t = self._thread
                if t is None:
                    self._stop = False
                    self._thread = threading.Thread(
                        target=self._serve_loop, name="serve-engine",
                        daemon=True)
                    self._thread.start()
                    return self
                if t.is_alive() and not self._stop:
                    return self            # genuinely running
            # finished, or stopping after a timed-out stop(): reap OUTSIDE
            # the lock (the loop's current tick needs it to complete)
            t.join()
            with self._lock:
                if self._thread is t:
                    self._thread = None

    def stop(self, timeout: float = 30.0) -> bool:
        """Stop the background thread (in-flight requests stay in place).
        Returns False if the loop is still finishing its current tick —
        the thread stays owned so a later start() can never spawn a
        second loop over the same pool; call stop() again to reap it."""
        with self._work:
            if self._thread is None:
                return True
            self._stop = True
            self._work.notify_all()
            t = self._thread
        t.join(timeout)
        if t.is_alive():
            return False
        with self._lock:
            if self._thread is t:
                self._thread = None
        if self._publisher is not None:
            self._publisher.close()
        return True

    # -- engine internals ---------------------------------------------------
    def chunk_buckets(self) -> list:
        """Every chunk width this engine schedules under bucketing — the
        warmup surface for benchmarks (compile these outside any timed
        window).  End-of-row chunks may additionally bucket DOWN to
        smaller powers of two; all widths stay powers of two, so the
        compiled-program count is O(log) regardless of prompt lengths."""
        scfg = self.scfg
        if not scfg.bucket_chunks:
            return []                  # unbounded: one program per length
        out, w = [], max(scfg.min_chunk_bucket, 1)
        top = max(scfg.prefill_chunk or 1, scfg.tail_chunk or 1)
        while w < top:
            out.append(w)
            w *= 2
        out.append(w)
        return out

    def batch_buckets(self) -> list:
        """Every compiled batch dimension batched prefill can schedule
        (powers of two up to the effective prefill_batch cap) — with
        chunk_buckets(), the warmup surface for benchmarks (one compiled
        program per (batch bucket, width) pair)."""
        if not self.scfg.bucket_chunks:
            return []                  # unbounded: one program per group size
        out, b = [], 1
        while b < self.scheduler.prefill_batch:
            out.append(b)
            b *= 2
        out.append(b)
        return out

    def warm_chunk_programs(self) -> None:
        """Compile every (batch bucket, width) prefill program this
        engine can schedule, on scratch caches — call it outside any
        timed window so a benchmark's first batched tick measures the
        batching, not XLA compilation.  Warmed programs do NOT count
        toward chunk_programs: that set reports what the workload
        actually scheduled (the recompile-hazard bound)."""
        for w in self.chunk_buckets() or [self.scfg.prefill_chunk or 1]:
            for b in self.batch_buckets() or [1]:
                if self.paged:
                    # the arena shape is part of the compiled program, so
                    # warm against a scratch arena of the SAME size; an
                    # all-zero block table routes every write to the
                    # scratch page
                    cache = self.model.init_paged_cache(
                        self.scfg.max_cache_pages, self.scfg.page_size)
                    logits, _, self.table = self._chunk(
                        self.params, jnp.zeros((b, w), jnp.int32),
                        self.table, cache, jnp.zeros((b,), jnp.int32),
                        jnp.zeros((b, self._n_blocks), jnp.int32),
                        jnp.ones((b,), jnp.int32))
                else:
                    cache = self.model.init_cache(b, self.scfg.max_seq_len)
                    logits, _, self.table = self._chunk(
                        self.params, jnp.zeros((b, w), jnp.int32),
                        self.table, cache, jnp.zeros((b,), jnp.int32),
                        jnp.ones((b,), jnp.int32))
                jax.block_until_ready(logits)

    @property
    def chunk_widths(self) -> frozenset:
        """Chunk widths compiled so far (the width projection of
        chunk_programs; stays bounded no matter how many distinct prompt
        lengths arrive)."""
        return frozenset(w for _, w in self._chunk_programs)

    @property
    def chunk_programs(self) -> frozenset:
        """(batch_bucket, width) pairs scheduled so far — tests assert
        this stays O(log prefill_batch x log max_seq_len) no matter how
        many distinct prompt lengths or admission patterns arrive."""
        return frozenset(self._chunk_programs)

    # -- batched cross-slot prefill -----------------------------------------
    def _pad_stash(self, rows: int):
        """Zero cache rows padding a group up to its batch bucket (valid
        masks them in-model).  Cached per size: the gather CONCATENATES
        it (a copy) and only the copy is donated to the compiled call,
        so the cached rows stay live across ticks."""
        if rows not in self._pad_stashes:
            self._pad_stashes[rows] = self.model.init_cache(
                rows, self.scfg.max_seq_len)
        return self._pad_stashes[rows]

    def _gather_stashes(self, stashes: list, pad: int):
        """Concatenate B batch=1 stashes (+ `pad` zero rows) into one
        [B+pad]-row cache along each leaf's batch axis — _scatter_slot's
        machinery in reverse.  A single stash with no pad passes through
        untouched: prefill_batch=1 IS the legacy per-slot path, same
        buffers, same numerics."""
        if len(stashes) == 1 and pad == 0:
            return stashes[0]
        parts = stashes + ([self._pad_stash(pad)] if pad else [])

        def leaf(ax, *ls):
            return ls[0] if ax < 0 else jnp.concatenate(ls, axis=ax)
        return jax.tree.map(leaf, self._batch_axes, *parts)

    def _take_row(self, gathered, row: int):
        """Slice row `row` of a gathered stash back out as a batch=1
        cache pytree (a copy, so the donated gathered buffer is never
        aliased by a live slot stash)."""
        def leaf(ax, l):
            return l if ax < 0 else jax.lax.slice_in_dim(
                l, row, row + 1, axis=ax)
        return jax.tree.map(leaf, self._batch_axes, gathered)

    # -- paged pool ---------------------------------------------------------
    def _page_gate(self, req: Request) -> bool:
        """Scheduler admission gate: reserve the request's WORST-CASE
        pages (truncated prompt + clamped max_new - 1 rows — submit
        already fitted both to the row) or report back-pressure.  A True
        return has committed pages: _admit's slot consumes them via
        lazy grants, rollback paths cancel them."""
        rows = len(req.prompt) + req.max_new_tokens - 1
        return self.allocator.try_reserve(
            req.uid, self.allocator.pages_needed(rows))

    def _grant_rows(self, slot_idx: int, rows: int) -> None:
        """Ensure slot `slot_idx` owns pages covering its first `rows`
        cache rows, drawing lazily from the allocator as the frontier
        crosses page boundaries (granted page ids append to the slot's
        block table; page 0 is never granted, so count_nonzero IS the
        pages-held count)."""
        have = int(np.count_nonzero(self.block_tables[slot_idx]))
        need = self.allocator.pages_needed(rows) - have
        if need > 0:
            uid = self.scheduler.slots[slot_idx].request.uid
            pages = self.allocator.grant(uid, need)
            self.block_tables[slot_idx, have:have + need] = pages

    def _release_pages(self, slot_idx: int, req: Request) -> None:
        """Recycle a finished/failed slot's pages and clear its table."""
        if self.paged:
            self.allocator.release(req.uid)
            self.block_tables[slot_idx, :] = 0

    def _prefill_group(self, idxs: list, ns: list, width: int) -> None:
        """One batched prefill chunk: advance the B slots in `idxs` by
        their next ns[r] tokens through a SINGLE forward_chunk at
        per-row cache offsets (width bucket-padded in T, group padded to
        the batch bucket in B, both masked via `valid`).  Rows whose
        prompt completes scatter into the pool and sample their FIRST
        token from this chunk's last-valid logits — the TTFT win over
        the old one-token-per-tick tail feed, now at multi-slot
        throughput."""
        slots = self.scheduler.slots
        B = len(idxs)
        Bb = self.scheduler.batch_bucket(B)
        tokens = np.zeros((Bb, width), np.int32)
        pos = np.zeros((Bb,), np.int32)
        valid = np.zeros((Bb,), np.int32)
        for r, (i, n) in enumerate(zip(idxs, ns)):
            slot = slots[i]
            tokens[r, :n] = [slot.pending.popleft() for _ in range(n)]
            pos[r] = slot.pos
            valid[r] = n
        if self.paged:
            # grant the pages this chunk's frontier will cross, then run
            # the group straight against the shared arena — no stashes,
            # no scatter: the block table IS the slot's cache row.  Pad
            # rows carry an all-zero table (writes land on scratch).
            for i, n in zip(idxs, ns):
                self._grant_rows(i, slots[i].pos + n)
            bt = np.zeros((Bb, self._n_blocks), np.int32)
            bt[:B] = self.block_tables[idxs]
            gathered = None
            t0 = time.perf_counter_ns()
            logits, self.cache, self.table = self._chunk(
                self.params, jnp.asarray(tokens), self.table, self.cache,
                jnp.asarray(pos), jnp.asarray(bt), jnp.asarray(valid))
        else:
            gathered = self._gather_stashes([slots[i].stash for i in idxs],
                                            Bb - B)
            t0 = time.perf_counter_ns()
            logits, gathered, self.table = self._chunk(
                self.params, jnp.asarray(tokens), self.table, gathered,
                jnp.asarray(pos), jnp.asarray(valid))
        # sync before the end timestamp: jitted calls return unready
        # arrays, and mid-prompt chunks have no downstream host read to
        # block on — without this the fold times dispatch, not compute
        jax.block_until_ready(logits)
        # its own flow-graph edge: diagnose separates prefill interference
        # from decode cost per tick (wait-dominance / hot-edge detectors)
        xfa.record_duration("serve", "prefill_chunk",
                            time.perf_counter_ns() - t0)
        # batching efficiency as a gauge (percent of compiled rows that
        # were real slots): the flow-graph evidence that cross-slot
        # batching engages — 100 when groups fill their bucket, lower
        # when pad rows dominate (mean over calls via the gauge fold)
        xfa.record_gauge("serve", "prefill_batch_occupancy",
                         100.0 * B / Bb)
        self._chunk_programs.add((Bb, width))
        for r, (i, n) in enumerate(zip(idxs, ns)):
            slot = slots[i]
            slot.pos += n
            if not self.paged:
                row = gathered if B == 1 and Bb == 1 \
                    else self._take_row(gathered, r)
                if slot.pending:
                    slot.stash = row
                    continue
                self.cache = _scatter_slot(self.cache, row, i)
                slot.stash = None
            elif slot.pending:
                continue               # arena already holds the chunk
            # the first token is EOS-checked — a first-token EOS finishes
            # without any decode ticks instead of burning max_new - 1
            tok = self.sampler.sample_one(
                np.asarray(logits[r]), slot.request.sampling, step=slot.pos)
            self._emit(i, tok, time.monotonic())

    @xfa.api("serve", "prefill_request")
    def _admit(self, slot_idx: int, req: Request) -> int:
        """Bind `req` to slot `slot_idx` (truncation accounting, fresh
        batch=1 stash, sampler row) and return its first prefill chunk's
        token count — the chunk itself runs in this tick's batched
        prefill groups, alongside other admissions and continuations of
        the same compiled width."""
        model, scfg = self.model, self.scfg
        now = time.monotonic()
        req.admitted_at = now
        xfa.record_duration("serve", "queue_wait",
                            (now - req.submitted_at) * 1e9, kind=KIND_WAIT)
        # safety-net truncation for requests bound without going through
        # submit() (which already fitted prompt and max_new to the row —
        # these branches are then no-ops, so the count events fire once)
        limit = max(1, scfg.max_seq_len - req.max_new_tokens - 1)
        prompt = req.prompt
        if len(prompt) > limit:
            # visible truncation: flagged on the handle AND folded as a
            # count event so fleets can alarm on it
            prompt = prompt[:limit]
            req.truncated = True
            xfa.count_event("serve", "truncated_prompt")
        cap = scfg.max_seq_len - len(prompt)
        if req.max_new_tokens > cap:
            # generation budget clamped so the slot's pos can never run
            # off the end of its cache row (oversized max_new_tokens)
            req.max_new_tokens = cap
            req.truncated = True
            xfa.count_event("serve", "clamped_max_new")
        # paged pool: the slot writes straight into the shared arena
        # through its block table — no batch=1 stash to fill or scatter
        self.scheduler.bind(slot_idx, req, pos=0, pending=prompt,
                            stash=None if self.paged
                            else model.init_cache(1, scfg.max_seq_len))
        self.sampler.bind(slot_idx, req.sampling)
        return self.scheduler.admit_cost(req)

    @xfa.api("serve", "decode_tick")
    def _tick(self) -> int:
        """One pooled width-1 forward_chunk at per-slot positions over the
        slots past prefill; returns #decoding."""
        slots = self.scheduler.slots
        active = self.scheduler.decoding()
        if not active:
            return 0
        tokens = np.zeros((self.scfg.max_batch,), np.int32)
        pos = self.scheduler.pos_vector()
        for i in active:
            tokens[i] = slots[i].request.output[-1]
        if self.paged:
            # the write frontier (row `pos`) may cross into a new page
            for i in active:
                self._grant_rows(i, slots[i].pos + 1)
        t0 = time.perf_counter_ns()
        if self.paged:
            logits, self.cache, self.table = self._decode(
                self.params, jnp.asarray(tokens), self.table, self.cache,
                jnp.asarray(pos), jnp.asarray(self.block_tables))
        else:
            logits, self.cache, self.table = self._decode(
                self.params, jnp.asarray(tokens), self.table, self.cache,
                jnp.asarray(pos))
        nxt = self.sampler(logits, step=pos + 1)
        tick_ns = time.perf_counter_ns() - t0
        now = time.monotonic()
        for i in active:
            slots[i].pos += 1
            self._emit(i, int(nxt[i]), now)
        if active:
            xfa.record_duration("serve", "decode_token",
                                tick_ns / len(active), n=len(active))
        return len(active)

    def _emit(self, slot_idx: int, tok: int, now: float) -> None:
        """Accept one generated token for the request in `slot_idx`."""
        req = self.scheduler.slots[slot_idx].request
        first = not req.output
        req.output.append(tok)
        if first:
            req.first_token_at = now
            xfa.record_duration("serve", "ttft",
                                (now - req.submitted_at) * 1e9)
        if req.on_token is not None:
            try:
                req.on_token(req, tok)
            except Exception:
                xfa.count_event("serve", "callback_error")
        if tok == self.scfg.eos_token or len(req.output) >= req.max_new_tokens:
            self._finish(slot_idx, now)

    def _finish(self, slot_idx: int, now: float) -> None:
        req = self.scheduler.slots[slot_idx].request
        req.done = True
        req.finished_at = now
        e2e_ns = (now - req.submitted_at) * 1e9
        xfa.record_duration("serve", "e2e", e2e_ns)
        if req.deadline_ms is not None:
            req.deadline_missed = e2e_ns > req.deadline_ms * 1e6
            xfa.count_event("serve", "deadline_miss" if req.deadline_missed
                            else "deadline_met")
        self.completed.append(req)
        self._release_pages(slot_idx, req)
        self.scheduler.release(slot_idx)
        self.sampler.release(slot_idx)
        req._done_event.set()

    def step(self) -> int:
        """One engine iteration: continuation prefill chunks for
        mid-prompt slots (oldest first), admissions under the leftover
        budget, then one pooled decode tick.  Returns the number of
        slots still active afterwards.

        Failure handling lives HERE, not in the background loop, so the
        synchronous (closed-loop) driver gets the same guarantee: an
        error marks the engine dead and wakes every waiter before the
        exception propagates to whoever drove the step."""
        with self._lock:
            try:
                # queue depth at tick start, folded as a gauge: its
                # per-interval mean across the snapshot ring is the
                # saturation signal `diagnose` reads (a growing mean says
                # admission is structurally behind the arrival rate)
                xfa.record_gauge("serve", "queue_depth",
                                 len(self.scheduler.waiting))
                if self.paged:
                    # pages are the admission resource: fold occupancy,
                    # high-water mark and capacity as gauges so cache
                    # pressure is a flow-graph edge (what the
                    # cache-pressure detector and the fleet plane read)
                    xfa.record_gauge("serve", "cache_pages_in_use",
                                     self.allocator.in_use)
                    xfa.record_gauge("serve", "cache_page_hwm",
                                     self.allocator.hwm)
                    xfa.record_gauge("serve", "cache_pages_capacity",
                                     self.allocator.usable)
                cont, deferred = self.scheduler.continuation_plan()
                # strict FCFS: if any mid-prefill slot (older than every
                # waiting request) was deferred by the budget, nothing
                # younger may spend the leftover this tick
                picked = [] if deferred else self.scheduler.schedule(
                    spent=sum(n for _, n in cont))
                items = list(cont)
                for k, (idx, req) in enumerate(picked):
                    try:
                        items.append((idx, self._admit(idx, req)))
                    except Exception as e:
                        # every request in `picked` was already popped
                        # from the queue — none may vanish without waking
                        # waiters: the failing one errors out, later ones
                        # go back to the queue head (FCFS preserved) for
                        # _fail_outstanding to find
                        req.error = e
                        req._done_event.set()
                        self._release_pages(idx, req)
                        self.scheduler.release(idx)
                        for _, later in reversed(picked[k + 1:]):
                            if self.paged:
                                # the page gate reserved for them; back in
                                # the queue they must not hold pages (they
                                # re-reserve at their next gate pass)
                                self.allocator.cancel(later.uid)
                            self.scheduler.waiting.appendleft(later)
                        raise
                # continuations AND admissions batch together: one
                # forward_chunk per same-width group of selected chunks
                for idxs, ns, width in \
                        self.scheduler.batched_prefill_plan(items):
                    self._prefill_group(idxs, ns, width)
                # pad stashes are per-TICK scratch: groups in this tick
                # shared them by size, but holding them across ticks pins
                # dead full-context rows for the engine's lifetime
                self._pad_stashes.clear()
                self._tick()
                self._ticks += 1
                interval = self.scfg.profile_interval_ticks
                if self._profile_store is not None and interval \
                        and self._ticks % interval == 0:
                    self.write_profile_shard()
                return len(self.scheduler.active())
            except Exception as e:      # noqa: BLE001 — fail loud AND clean
                self._fail_outstanding(e)
                raise

    def _serve_loop(self) -> None:
        xfa.set_thread_group("serve")
        while True:
            with self._work:
                while not self._stop and not self.scheduler.has_work():
                    self._work.wait(0.05)
                if self._stop:
                    break
            try:
                self.step()
            except Exception:               # noqa: BLE001 — must not die mute
                break                       # step() already failed waiters
        self.write_profile_shard()

    def _fail_outstanding(self, exc: BaseException) -> None:
        """A serve-loop error must not strand clients on result(): mark
        every live request failed and wake its waiters."""
        xfa.count_event("serve", "engine_error")
        with self._lock:
            self._error = exc
            live = [s.request for s in self.scheduler.slots
                    if s.request is not None]
            live += list(self.scheduler.waiting)
            self.scheduler.waiting.clear()
            if self.paged:
                # recycle every page and reservation so a post-mortem
                # reading the allocator sees the true terminal state
                for req in live:
                    self.allocator.release(req.uid)
                self.block_tables[:] = 0
            for i in self.scheduler.active():
                self.scheduler.release(i)
            for req in live:
                req.error = exc
                req._done_event.set()
            self._stop = True

    # -- profiling ----------------------------------------------------------
    def write_profile_shard(self) -> None:
        """Refresh this replica's profile shard (host tracer folds)."""
        if self._profile_store is None:
            return
        from repro.profile import tracer_folded
        self._profile_store.write_shard(
            tracer_folded(), label=self.scfg.profile_label,
            meta={"ticks": self._ticks, "completed": len(self.completed)})
        if self._publisher is not None:
            # local ring first, then the delta stream; publish() never
            # raises — a dead collector degrades to local-only profiling
            with xfa.scope("serve", "profile_publish"):
                self._publisher.publish()

    # -- synchronous driver -------------------------------------------------
    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Serve until queue and pool are empty.  With a background thread
        running this just waits for quiescence; otherwise it drives the
        loop inline (closed-loop mode)."""
        t = self._thread
        if t is not None and t.is_alive():
            deadline = time.monotonic() + max_ticks * 0.1
            while True:
                # observe under the engine lock: step() holds it across
                # pop -> bind -> tick, so a request mid-admission can
                # never look like "neither waiting nor active" from here
                with self._lock:
                    if not self.scheduler.has_work():
                        break
                if time.monotonic() > deadline:
                    break
                time.sleep(0.002)
            return self.completed
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and not self.scheduler.has_waiting():
                break
        self.write_profile_shard()
        return self.completed
