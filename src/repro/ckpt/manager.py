"""Checkpoint manager: atomic, async-capable, restart-friendly.

Layout per checkpoint:  <dir>/step_<k>/
    manifest.json   step, leaf paths, shapes, dtypes, config fingerprint
    <leaf-idx>.npy  one file per pytree leaf (numpy, host-fetched)
Written to step_<k>.tmp then os.rename'd — a crash mid-save never corrupts
the latest checkpoint (fault-tolerance requirement). `keep_last` old
checkpoints are pruned after a successful save.

Async mode hands the (already host-fetched) arrays to a writer thread so the
train loop only pays the device->host fetch, not the fsync. The save/restore
boundaries are XFA-instrumented ('ckpt') — the dedup-3 analogue benchmark
(checkpoint-every-step misconfiguration) reads exactly these edges.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import tracer as xfa


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        out.append((name, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 async_save: bool = False) -> None:
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._writer: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    @xfa.api("ckpt", "save")
    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> str:
        flat, _ = _flatten(tree)
        host = [(name, np.asarray(leaf)) for name, leaf in flat]
        if self.async_save:
            self.wait()  # one in-flight save at a time
            self._writer = threading.Thread(
                target=self._write, args=(step, host, extra or {}),
                daemon=True, name=f"ckpt-writer-{step}")
            self._writer.start()
            return self._path(step)
        self._write(step, host, extra or {})
        return self._path(step)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, host, extra) -> None:
        try:
            xfa.set_thread_group("ckpt_writers")
            final = self._path(step)
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": [], "extra": extra}
            for i, (name, arr) in enumerate(host):
                np.save(os.path.join(tmp, f"{i}.npy"), arr)
                manifest["leaves"].append(
                    {"name": name, "file": f"{i}.npy",
                     "shape": list(arr.shape), "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._prune()
        except BaseException as e:  # surfaced on next wait()
            self._last_error = e

    @xfa.wait("ckpt", "wait_async")
    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def _prune(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    @xfa.api("ckpt", "restore")
    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of `tree_like`; device_put with
        `shardings` when given (elastic re-mesh restores reshard here)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = _flatten(tree_like)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        leaves = []
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        for (name, like), sh in zip(flat, shard_flat):
            entry = by_name.get(name)
            if entry is None:
                raise KeyError(f"checkpoint {step} missing leaf {name}")
            arr = np.load(os.path.join(path, entry["file"]))
            if list(arr.shape) != list(like.shape):
                raise ValueError(f"{name}: ckpt shape {arr.shape} != "
                                 f"{like.shape}")
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest.get("extra", {})
