"""Runtime attribution — serial/parallel phases, wait separation, imbalance.

Paper mapping (Scaler §3.4–§3.5):

 * An API invoked in a serial phase costs its full duration; in a parallel
   phase its end-to-end impact is duration / #active-threads.  Scaler divides
   at recording time; we divide at fold time (the fold keeps raw durations, so
   the division is reversible and testable).
 * Waiting time (condvar/barrier/lock) is separated into a 'Wait' pseudo
   category — time where the program does no useful work.
 * Thread groups with significantly different wait/exec ratios indicate load
   imbalance (learned from SyncPerf; the paper's ferret/dedup-2 case studies).

TPU adaptation: "threads" generalize to parallel lanes of the system —
host threads (pipeline stages, data workers) and device shards (DP replicas,
pipeline stages).  `attribute_parallel` divides a fold by its lane count;
`imbalance_report` compares groups; both run on folded tables, never on logs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .folding import EdgeStats, FoldedTable
from .shadow import KIND_WAIT


@dataclass
class PhaseAttribution:
    """A fold re-weighted for end-to-end impact."""

    folded: FoldedTable
    n_lanes: int
    phase: str  # 'serial' | 'parallel'


def attribute_serial(folded: FoldedTable) -> PhaseAttribution:
    return PhaseAttribution(folded, 1, "serial")


def attribute_parallel(folded: FoldedTable, n_lanes: int) -> PhaseAttribution:
    """Divide durations by the number of active lanes (paper §3.4)."""
    if n_lanes <= 0:
        raise ValueError("n_lanes must be positive")
    return PhaseAttribution(folded.scale_time(1.0 / n_lanes), n_lanes, "parallel")


def combine_phases(phases: Sequence[PhaseAttribution]) -> FoldedTable:
    out = FoldedTable()
    for p in phases:
        out = out.merge(p.folded)
    return out


def wait_split(folded: FoldedTable) -> Tuple[FoldedTable, FoldedTable]:
    """Split a fold into (useful, wait) sub-folds (paper's Wait category)."""
    useful = {k: v for k, v in folded.edges.items() if v.kind != KIND_WAIT}
    wait = {k: v for k, v in folded.edges.items() if v.kind == KIND_WAIT}
    return (FoldedTable(useful, folded.group), FoldedTable(wait, folded.group))


@dataclass
class GroupStats:
    group: str
    n_tables: int
    exec_ns: int
    wait_ns: int

    @property
    def wait_frac(self) -> float:
        tot = self.exec_ns + self.wait_ns
        return self.wait_ns / tot if tot else 0.0


@dataclass
class ImbalanceReport:
    groups: List[GroupStats]
    max_exec_ratio: float   # max(exec)/min(exec) across groups
    imbalanced: bool
    threshold: float

    def render(self) -> str:
        lines = [f"{'group':<16}{'tables':>7}{'exec_ms':>12}{'wait_ms':>12}"
                 f"{'wait%':>8}"]
        for g in self.groups:
            lines.append(f"{g.group:<16}{g.n_tables:>7}"
                         f"{g.exec_ns/1e6:>12.2f}{g.wait_ns/1e6:>12.2f}"
                         f"{100*g.wait_frac:>7.1f}%")
        verdict = ("IMBALANCED" if self.imbalanced else "balanced")
        lines.append(f"exec max/min ratio: {self.max_exec_ratio:.2f}x -> {verdict}"
                     f" (threshold {self.threshold:.1f}x)")
        return "\n".join(lines)


def imbalance_report(per_group_folds: Dict[str, List[FoldedTable]],
                     threshold: float = 4.0) -> ImbalanceReport:
    """Compare effective exec time across thread/lane groups.

    The paper flags ferret when rank threads' effective exec is ~16x seg's;
    we flag when max/min exec across groups exceeds `threshold`.
    """
    groups: List[GroupStats] = []
    for name, folds in sorted(per_group_folds.items()):
        exec_ns = 0
        wait_ns = 0
        for f in folds:
            useful, wait = wait_split(f)
            exec_ns += sum(e.self_ns for e in useful.edges.values())
            wait_ns += sum(e.total_ns for e in wait.edges.values())
        groups.append(GroupStats(name, len(folds), exec_ns, wait_ns))
    execs = [g.exec_ns for g in groups if g.exec_ns > 0]
    ratio = (max(execs) / min(execs)) if len(execs) >= 2 else 1.0
    return ImbalanceReport(groups, ratio, ratio > threshold, threshold)


def expert_imbalance(loads: Sequence[float], threshold: float = 4.0
                     ) -> Tuple[bool, float]:
    """Device-fold analogue of thread imbalance: MoE expert loads.

    Returns (imbalanced?, max/mean ratio).  Mirrors the ferret diagnosis —
    'different thread groups have very different effective execution time' —
    with experts as the lanes and routed token counts as the work."""
    loads = [float(x) for x in loads]
    if not loads or sum(loads) == 0:
        return (False, 1.0)
    mean = sum(loads) / len(loads)
    ratio = max(loads) / mean if mean else 1.0
    return (ratio > threshold, ratio)
