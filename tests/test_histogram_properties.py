"""Property-based tests (hypothesis) on the histogram merge algebra.

Separate from test_histograms.py so environments without hypothesis
(CI installs it, see requirements.txt) skip only the property tests,
not the unit coverage."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.folding import EdgeColumns, EdgeStats, FoldedTable, \
    merge_columns
from repro.core.histogram import hist_of, percentile_ns

#: includes durations past the 2^40 ns range bound — clamped, not lost
durations = st.lists(st.integers(1, 1 << 41), max_size=200)


@settings(max_examples=60, deadline=None)
@given(durations, st.integers(0, 200))
def test_hist_merge_is_split_invariant(ds, cut):
    """hist(whole stream) == hist(part) + hist(rest) for ANY split — the
    bucket-wise add that merge_columns/EdgeStats.merge performs."""
    cut = min(cut, len(ds))
    whole = hist_of(ds)
    parts = hist_of(ds[:cut]) + hist_of(ds[cut:])
    assert np.array_equal(whole, parts)
    assert int(whole.sum()) == len(ds)


@settings(max_examples=40, deadline=None)
@given(durations, durations, durations)
def test_hist_merge_order_independent(d1, d2, d3):
    """Shard merge order never changes a bucket (so never a percentile)."""
    h1, h2, h3 = hist_of(d1), hist_of(d2), hist_of(d3)
    left = (h1 + h2) + h3
    right = h1 + (h2 + h3)
    assert np.array_equal(left, right)
    assert np.array_equal(h1 + h2, h2 + h1)
    for q in (0.5, 0.95, 0.99):
        assert percentile_ns(left, q) == percentile_ns(right, q)


@settings(max_examples=40, deadline=None)
@given(durations, st.randoms(use_true_random=False))
def test_merge_columns_exact_on_hists(ds, rnd):
    """End-to-end: splitting a duration stream across two shards and
    merging the columnar forms reproduces the single-shard histogram."""
    a, b = [], []
    for d in ds:
        (a if rnd.random() < 0.5 else b).append(d)

    def shard(samples):
        t = FoldedTable()
        if samples:
            t.edges[("app", "serve", "e2e")] = EdgeStats(
                count=len(samples), total_ns=sum(samples),
                min_ns=min(samples), max_ns=max(samples),
                hist=hist_of(samples))
        return EdgeColumns.from_folded(t)

    merged = merge_columns([shard(a), shard(b)]).to_folded()
    if not ds:
        assert len(merged) == 0
        return
    e = merged.edges[("app", "serve", "e2e")]
    assert np.array_equal(e.hist, hist_of(ds))
