"""Relation-Aware Data Folding — the fold/merge algebra over shadow tables.

Paper mapping (Scaler §3.4 "Online Data Folder"): events are never appended
to a log; they are folded online into per-(caller → callee API) accumulators.
Memory is O(#edges), not O(#events).  The fold keeps the *relation* — the same
API invoked from two components stays two edges — so per-component accuracy
survives the folding.

This module provides the pure-data half: `EdgeStats` (one folded edge),
`FoldedTable` (edge → stats mapping with a commutative, associative merge),
and constructors from per-thread ShadowTables and from device fold vectors.
The merge algebra is property-tested (tests/test_xfa_properties.py):

    merge(a, merge(b, c)) == merge(merge(a, b), c)      (associativity)
    merge(a, b) == merge(b, a)                          (commutativity)
    merge(a, empty) == a                                (identity)
    total_ns / count conservation under arbitrary splits of an event stream
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from .shadow import (KIND_CALL, KIND_NAMES, KIND_WAIT, ShadowTable,
                     ShadowTableSet, SlotInfo, SlotKey)

_I64_MAX = np.iinfo(np.int64).max


@dataclass
class EdgeStats:
    """Folded statistics of one cross-flow edge (caller → component.api)."""

    count: int = 0
    total_ns: int = 0
    child_ns: int = 0
    min_ns: int = _I64_MAX
    max_ns: int = 0
    kind: int = KIND_CALL
    # extra folded metrics from the device layer (flops, bytes, tokens, ...)
    metrics: Dict[str, float] = field(default_factory=dict)

    @property
    def self_ns(self) -> int:
        """Time in the callee itself, excluding its own callees (paper 'Self')."""
        return self.total_ns - self.child_ns

    @property
    def mean_ns(self) -> float:
        return self.total_ns / self.count if self.count else 0.0

    def merge(self, other: "EdgeStats") -> "EdgeStats":
        metrics = dict(self.metrics)
        for k, v in other.metrics.items():
            metrics[k] = metrics.get(k, 0.0) + v
        return EdgeStats(
            count=self.count + other.count,
            total_ns=self.total_ns + other.total_ns,
            child_ns=self.child_ns + other.child_ns,
            min_ns=min(self.min_ns, other.min_ns),
            max_ns=max(self.max_ns, other.max_ns),
            kind=self.kind if self.count else other.kind,
            metrics=metrics,
        )

    def to_json(self) -> dict:
        return {
            "count": int(self.count),
            "total_ns": int(self.total_ns),
            "child_ns": int(self.child_ns),
            "min_ns": int(self.min_ns) if self.count else None,
            "max_ns": int(self.max_ns),
            "kind": KIND_NAMES[self.kind],
            "metrics": self.metrics,
        }

    @staticmethod
    def from_json(d: dict) -> "EdgeStats":
        kind = KIND_WAIT if d.get("kind") == "wait" else KIND_CALL
        return EdgeStats(
            count=d["count"],
            total_ns=d["total_ns"],
            child_ns=d["child_ns"],
            min_ns=d["min_ns"] if d.get("min_ns") is not None else _I64_MAX,
            max_ns=d["max_ns"],
            kind=kind,
            metrics=dict(d.get("metrics", {})),
        )


class FoldedTable:
    """Edge → EdgeStats mapping; the offline-mergeable form of a shadow table.

    `group` tags which thread-group / host / device shard the fold came from —
    kept so attribution (serial vs parallel, imbalance) can run *before* the
    final cross-group merge, exactly like the paper merges per-thread files in
    the offline visualizer.
    """

    def __init__(self, edges: Optional[Dict[SlotKey, EdgeStats]] = None,
                 group: str = "main") -> None:
        self.edges: Dict[SlotKey, EdgeStats] = edges or {}
        self.group = group

    # -- constructors -------------------------------------------------------
    @staticmethod
    def from_shadow(table: ShadowTable, infos: Iterable[SlotInfo]) -> "FoldedTable":
        edges: Dict[SlotKey, EdgeStats] = {}
        for info in infos:
            s = info.slot
            if s >= table.capacity or table.count[s] == 0:
                continue
            edges[info.key] = EdgeStats(
                count=int(table.count[s]),
                total_ns=int(table.total_ns[s]),
                child_ns=int(table.child_ns[s]),
                min_ns=int(table.min_ns[s]),
                max_ns=int(table.max_ns[s]),
                kind=info.kind,
            )
        return FoldedTable(edges, group=table.group)

    @staticmethod
    def from_set(tables: ShadowTableSet) -> List["FoldedTable"]:
        infos = tables.registry.infos()
        return [FoldedTable.from_shadow(t, infos) for t in tables.tables()]

    # -- algebra --------------------------------------------------------------
    def merge(self, other: "FoldedTable") -> "FoldedTable":
        edges = {k: v for k, v in self.edges.items()}
        for k, v in other.edges.items():
            edges[k] = edges[k].merge(v) if k in edges else v
        group = self.group if self.group == other.group else "merged"
        return FoldedTable(edges, group=group)

    @staticmethod
    def merge_all(tables: Iterable["FoldedTable"]) -> "FoldedTable":
        out = FoldedTable()
        for t in tables:
            out = out.merge(t)
        return out

    # -- queries --------------------------------------------------------------
    def components(self) -> List[str]:
        names = set()
        for (caller, component, _api) in self.edges:
            names.add(caller)
            names.add(component)
        return sorted(names)

    def edges_from(self, caller: str) -> Dict[SlotKey, EdgeStats]:
        return {k: v for k, v in self.edges.items() if k[0] == caller}

    def edges_into(self, component: str) -> Dict[SlotKey, EdgeStats]:
        return {k: v for k, v in self.edges.items() if k[1] == component}

    def total_ns(self) -> int:
        return sum(e.total_ns for e in self.edges.values())

    def scale_time(self, factor: float) -> "FoldedTable":
        """Scale all times (serial/parallel attribution divides by #threads)."""
        edges = {
            k: EdgeStats(
                count=v.count,
                total_ns=int(v.total_ns * factor),
                child_ns=int(v.child_ns * factor),
                min_ns=int(v.min_ns * factor) if v.count else v.min_ns,
                max_ns=int(v.max_ns * factor),
                kind=v.kind,
                metrics=dict(v.metrics),
            )
            for k, v in self.edges.items()
        }
        return FoldedTable(edges, group=self.group)

    # -- persistence ------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "group": self.group,
            "edges": [
                {"caller": k[0], "component": k[1], "api": k[2], **v.to_json()}
                for k, v in sorted(self.edges.items())
            ],
        }

    @staticmethod
    def from_json(d: dict) -> "FoldedTable":
        edges = {
            (e["caller"], e["component"], e["api"]): EdgeStats.from_json(e)
            for e in d["edges"]
        }
        return FoldedTable(edges, group=d.get("group", "main"))

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @staticmethod
    def load(path: str) -> "FoldedTable":
        with open(path) as f:
            return FoldedTable.from_json(json.load(f))

    def __len__(self) -> int:
        return len(self.edges)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FoldedTable(group={self.group!r}, edges={len(self.edges)})"


def fold_event_log(events: Iterable[Tuple[str, str, str, int]],
                   kinds: Optional[Mapping[SlotKey, int]] = None) -> FoldedTable:
    """Fold an append-style event log [(caller, component, api, dur_ns), ...].

    Exists for the paper's comparison (Table 5 / §4.3.2): benchmarks build the
    same table from a raw log and from the online fold and assert equality,
    then compare memory/time.  Not used on any hot path.
    """
    edges: Dict[SlotKey, EdgeStats] = {}
    for caller, component, api, dur in events:
        key = (caller, component, api)
        e = edges.get(key)
        if e is None:
            kind = (kinds or {}).get(key, KIND_CALL)
            e = edges[key] = EdgeStats(kind=kind)
        e.count += 1
        e.total_ns += dur
        e.min_ns = min(e.min_ns, dur)
        e.max_ns = max(e.max_ns, dur)
    return FoldedTable(edges)
