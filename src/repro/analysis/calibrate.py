"""Noise-band calibration — measured per-edge variance instead of a
hand-picked global threshold.

The profile-diff CI gate (and any cross-run comparison) needs to know how
much an edge's count/total/self wobbles between *healthy* runs before a
growth can be called a regression.  ScALPEL's argument applies directly:
diagnostics must adapt their sensitivity to the measured behaviour, not to
one magic constant.  This module fits per-(edge, field) bands from either

  * a set of BASELINE RUNS (each profile one sample — e.g. the synthetic
    CI workload at several seeds, or last week's nightly runs), or
  * one run's snapshot RING (each per-interval delta one sample — in-run
    variance, for drift detectors).

and serializes them as a thresholds JSON that both `diff --thresholds`
and `diagnose --thresholds` consume: the allowed relative growth of an
edge becomes max(floor, k_sigma * std / mean) of ITS OWN band, falling
back to the global `--threshold` for edges never seen in calibration.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence

from ..core.folding import FoldedTable
from ..core.shadow import SlotKey
from .graph import edge_label

#: fields a band can be fitted on (self_ns/mean_ns derive per sample; the
#: percentile/jitter fields read schema-v2 latency histograms and fit 0.0
#: bands over hist-less edges, matching diff's 0.0-valued percentiles).
CALIBRATE_FIELDS = ("count", "total_ns", "self_ns", "mean_ns",
                    "p50_ns", "p95_ns", "p99_ns", "jitter_ns")

THRESHOLDS_SCHEMA = 1


@dataclass(frozen=True)
class EdgeBand:
    """Summary statistics of one (edge, field) across calibration samples."""

    n: int
    mean: float
    std: float
    p95: float
    lo: float
    hi: float

    @staticmethod
    def fit(values: Sequence[float]) -> "EdgeBand":
        # pure python on purpose: samples are a handful of floats per
        # edge, and numpy's percentile/std dispatch overhead dominated a
        # fleet-sized calibration (10k+ edges) by >2x
        vals = sorted(float(v) for v in values)
        n = len(vals)
        if n == 0:
            raise ValueError("EdgeBand.fit needs at least one sample")
        mean = sum(vals) / n
        std = (sum((v - mean) ** 2 for v in vals) / n) ** 0.5
        h = 0.95 * (n - 1)                 # numpy's 'linear' interpolation
        i = int(h)
        p95 = vals[i] + (vals[min(i + 1, n - 1)] - vals[i]) * (h - i)
        return EdgeBand(n=n, mean=mean, std=std, p95=p95,
                        lo=vals[0], hi=vals[-1])

    def to_json(self) -> dict:
        return {"n": self.n, "mean": self.mean, "std": self.std,
                "p95": self.p95, "lo": self.lo, "hi": self.hi}

    @staticmethod
    def from_json(d: dict) -> "EdgeBand":
        return EdgeBand(n=int(d["n"]), mean=float(d["mean"]),
                        std=float(d["std"]), p95=float(d["p95"]),
                        lo=float(d["lo"]), hi=float(d["hi"]))


@dataclass
class Thresholds:
    """Per-edge noise bands + the rule turning them into rel thresholds."""

    bands: Dict[str, Dict[str, EdgeBand]] = field(default_factory=dict)
    k_sigma: float = 3.0
    floor: float = 0.05
    fields: tuple = CALIBRATE_FIELDS
    meta: Dict[str, Any] = field(default_factory=dict)
    schema: int = THRESHOLDS_SCHEMA

    def band(self, key: SlotKey, fld: str) -> Optional[EdgeBand]:
        return self.bands.get(edge_label(key), {}).get(fld)

    def rel_threshold(self, key: SlotKey, fld: str,
                      default: float) -> float:
        """Allowed relative growth for (edge, field): k_sigma standard
        deviations of its own band, floored so a zero-variance edge (e.g.
        a deterministic count) still tolerates rounding-level change.
        Edges without a band keep the caller's `default`."""
        b = self.band(key, fld)
        if b is None or b.mean <= 0:
            return default
        return max(self.floor, self.k_sigma * b.std / b.mean)

    def noise_ns(self, key: SlotKey, fld: str = "total_ns") -> float:
        """Absolute per-sample noise scale (k_sigma * std); 0 when unknown.
        Drift detectors use it as an evidence floor."""
        b = self.band(key, fld)
        return self.k_sigma * b.std if b is not None else 0.0

    def __len__(self) -> int:
        return len(self.bands)

    # -- persistence --------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "schema": self.schema,
            "k_sigma": self.k_sigma,
            "floor": self.floor,
            "fields": list(self.fields),
            "meta": self.meta,
            "edges": {label: {fld: b.to_json() for fld, b in sorted(
                per.items())} for label, per in sorted(self.bands.items())},
        }

    @staticmethod
    def from_json(d: dict) -> "Thresholds":
        schema = int(d.get("schema", -1))
        if schema > THRESHOLDS_SCHEMA or schema < 1:
            raise ValueError(f"thresholds schema {schema} not supported "
                             f"(supports <= {THRESHOLDS_SCHEMA})")
        return Thresholds(
            bands={label: {fld: EdgeBand.from_json(b)
                           for fld, b in per.items()}
                   for label, per in d.get("edges", {}).items()},
            k_sigma=float(d.get("k_sigma", 3.0)),
            floor=float(d.get("floor", 0.05)),
            fields=tuple(d.get("fields", CALIBRATE_FIELDS)),
            meta=dict(d.get("meta", {})), schema=schema)

    def save(self, path: str) -> str:
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    @staticmethod
    def load(path: str) -> "Thresholds":
        with open(path) as f:
            return Thresholds.from_json(json.load(f))


def _edge_value(e, fld: str) -> float:
    return float(getattr(e, fld))


def calibrate_runs(tables: Iterable[FoldedTable], *,
                   fields: Sequence[str] = CALIBRATE_FIELDS,
                   k_sigma: float = 3.0, floor: float = 0.05,
                   meta: Optional[Dict[str, Any]] = None) -> Thresholds:
    """Fit bands treating each profile as one independent sample of the
    same workload.  An edge absent from a run contributes 0.0 — presence
    variance IS variance (a sometimes-there edge gets a wide band)."""
    tables = list(tables)
    if not tables:
        raise ValueError("calibrate_runs needs at least one profile")
    for fld in fields:
        if fld not in CALIBRATE_FIELDS:
            raise ValueError(f"unknown calibration field {fld!r}; "
                             f"choose from {CALIBRATE_FIELDS}")
    keys = sorted({k for t in tables for k in t.edges})
    bands: Dict[str, Dict[str, EdgeBand]] = {}
    for key in keys:
        per: Dict[str, EdgeBand] = {}
        for fld in fields:
            vals = [(_edge_value(t.edges[key], fld)
                     if key in t.edges else 0.0) for t in tables]
            per[fld] = EdgeBand.fit(vals)
        bands[edge_label(key)] = per
    m = {"mode": "runs", "n_samples": len(tables)}
    m.update(meta or {})
    return Thresholds(bands=bands, k_sigma=k_sigma, floor=floor,
                      fields=tuple(fields), meta=m)


def calibrate_ring(timelines, *, fields: Sequence[str] = CALIBRATE_FIELDS,
                   k_sigma: float = 3.0, floor: float = 0.05,
                   meta: Optional[Dict[str, Any]] = None) -> Thresholds:
    """Fit bands from one (or more) shard rings: every per-interval delta
    of an edge is one sample of its steady-state activity.  Negative
    deltas (writer restarts) are excluded — a restart is not noise."""
    timelines = list(timelines)
    for fld in fields:
        if fld not in CALIBRATE_FIELDS:
            raise ValueError(f"unknown calibration field {fld!r}; "
                             f"choose from {CALIBRATE_FIELDS}")

    def diffs(s: List[float]) -> List[float]:
        return [s[0]] + [b - a for a, b in zip(s, s[1:])]

    samples: Dict[SlotKey, Dict[str, List[float]]] = {}
    n_intervals = 0
    for tl in timelines:
        n_intervals += max(len(tl) - 1, 0)
        # a retention-trimmed ring's first snapshot is a CUMULATIVE fold
        # of everything before it, not one interval — sampling it would
        # inflate every band (and silently blind the gate).  Only a ring
        # that still holds seq 1 contributes its first value as a sample.
        start = 0 if (tl.seqs and tl.seqs[0] == 1) else 1
        for key in tl.edges():
            # one pass per edge: every field's per-interval deltas derive
            # from the three base cumulative series (a fleet-sized ring
            # has 10k+ edges; re-walking the ring per field dominated)
            counts = tl.series(key, "count")
            totals = tl.series(key, "total_ns")
            childs = tl.series(key, "child_ns")
            dc, dt = diffs(counts), diffs(totals)
            derived = {
                "count": dc,
                "total_ns": dt,
                "self_ns": diffs([t - c for t, c in zip(totals, childs)]),
                # per-interval TRUE mean, matching ShardTimeline.deltas
                "mean_ns": [t / c if c > 0 else (-1.0 if c < 0 else 0.0)
                            for t, c in zip(dt, dc)],
            }
            for fld in fields:
                if fld not in derived:
                    # percentile/jitter: per-interval quantiles off the
                    # differenced histograms (ShardTimeline handles the
                    # hist algebra; restarts come back as -1.0 and are
                    # dropped by the v >= 0 filter below)
                    derived[fld] = tl.deltas(key, fld)
            per = samples.setdefault(key, {f: [] for f in fields})
            for fld in fields:
                per[fld].extend(v for v in derived[fld][start:] if v >= 0)
    if not samples:
        raise ValueError("calibrate_ring: no ring intervals to sample")
    bands = {edge_label(k): {fld: EdgeBand.fit(vs)
                             for fld, vs in per.items() if vs}
             for k, per in sorted(samples.items())}
    m = {"mode": "ring", "n_shards": len(timelines),
         "n_intervals": n_intervals}
    m.update(meta or {})
    return Thresholds(bands=bands, k_sigma=k_sigma, floor=floor,
                      fields=tuple(fields), meta=m)
