"""Shared model building blocks — pure-functional JAX, params as dicts.

Conventions:
  * init_* (key, cfg) -> param dict; leaf names match parallel/sharding.RULES.
  * apply functions are pure; dtype policy: params in cfg.param_dtype,
    compute in cfg.compute_dtype, reductions/softmax in f32.
  * every block wraps itself in jax.named_scope(<component>) — that is the
    XFA L3 hook: compiled-HLO collectives inherit the scope via op_name.
  * kernel hot-spots route through repro.kernels.ops (Pallas on TPU, oracle
    on CPU), which also registers analytic FLOPs with the XFA static layer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.device_fold import annotate_cost
from repro.kernels import ops
from repro.parallel.axes import axis_size, shard

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Per-call runtime knobs threaded alongside the config."""
    cfg: ModelConfig
    impl: str = "auto"            # kernel impl: auto | ref | pallas
    fold_spec: Any = None         # DeviceFoldSpec or None
    decode: bool = False

    @property
    def cdtype(self):
        return jnp.dtype(self.cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ------------------------------------------------------------------ misc ----
def linear(p: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.einsum("...i,io->...o", x, p.astype(x.dtype))


@jax.custom_vjp
def _bf16_grad_barrier(x):
    """Identity whose COTANGENT is forced to bf16.

    f32 casts inside blocks (rope, silu, softmax) leak f32 cotangents back
    to the TP dx all-reduces (measured: every [B,S,d] backward all-reduce in
    the train HLO was f32 — EXPERIMENTS.md §Perf). Placing this barrier on
    block outputs halves that wire traffic; bf16 gradient reduction is
    standard practice at scale."""
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, ct):
    return (ct.astype(jnp.bfloat16).astype(ct.dtype)
            if ct.dtype == jnp.float32 else ct,)


_bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


def grad_barrier(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if getattr(cfg, "bf16_grad_reduce", False):
        return _bf16_grad_barrier(x)
    return x


def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    return {"scale": jnp.ones((d or cfg.d_model,), pdtype(cfg))}


def norm(p: Params, x: jax.Array, rt: Runtime) -> jax.Array:
    with jax.named_scope("norm"):
        return ops.rmsnorm(x, p["scale"], eps=rt.cfg.norm_eps, impl=rt.impl)


# ------------------------------------------------------------------ rope ----
def rope_tables(cfg: ModelConfig, positions: jax.Array, dim: int
                ) -> Tuple[jax.Array, jax.Array]:
    """positions [S] (or [B,S]) -> cos/sin [..., S, dim//2], f32."""
    half = dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, D]; cos/sin broadcastable to [..., S, D//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ------------------------------------------------------------- attention ----
def init_attention(key, cfg: ModelConfig) -> Params:
    d, h = cfg.d_model, cfg.head_dim_
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    if cfg.mla:
        qd = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = {
            "wq": _init(ks[0], (d, cfg.n_heads * qd), dt),
            "wkv_a": _init(ks[1], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), dt),
            "wkv_b": _init(ks[2], (cfg.kv_lora_rank,
                                   cfg.n_heads * (cfg.qk_nope_dim
                                                  + cfg.v_head_dim)), dt),
            "wo": _init(ks[3], (cfg.n_heads * cfg.v_head_dim, d), dt),
        }
        return {"attn": p}
    p = {
        "wq": _init(ks[0], (d, cfg.n_heads * h), dt),
        "wk": _init(ks[1], (d, cfg.n_kv_heads * h), dt),
        "wv": _init(ks[2], (d, cfg.n_kv_heads * h), dt),
        "wo": _init(ks[3], (cfg.n_heads * h, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((h,), dt)
        p["k_norm"] = jnp.ones((h,), dt)
    return {"attn": p}


def update_cache_rows(dst: jax.Array, src: jax.Array, pos: jax.Array,
                      seq_axis: int = 2) -> jax.Array:
    """Vmapped ROW-RANGE cache scatter at arbitrary per-row offsets: row b
    of `src` (length T along `seq_axis`, T >= 1) lands at indices
    [pos[b], pos[b]+T) of `dst`'s seq_axis.  dst: [B, ...]; src: [B, ...];
    pos: [B].

    The vmap'd dynamic_update_slice is what lets every slot of a serving
    pool advance its cache row independently (continuous batching: slots
    decode at different depths in the same compiled step), and — with
    T > 1 — what lets a positioned CHUNK of prompt tokens land mid-row
    (in-model chunked prefill).  Callers must keep pos[b] + T within the
    row: dynamic_update_slice clamps the start index, so an overrun would
    silently shift the write onto earlier valid entries."""
    def one(d, s, p):
        idx = [jnp.int32(0)] * d.ndim
        idx[seq_axis - 1] = p        # batch dim vmapped away
        return jax.lax.dynamic_update_slice(d, s, tuple(idx))
    return jax.vmap(one)(dst, src.astype(dst.dtype), pos)


def update_cache_pages(arena: jax.Array, src: jax.Array, pos: jax.Array,
                       block_table: jax.Array,
                       seq_axis: int = 2) -> jax.Array:
    """Paged cache scatter: the PAGE-ARENA twin of update_cache_rows.

    arena: [P, ..., page_size, ...] page pool (page id replaces the batch
    dim; `seq_axis` is the row-within-page axis); src: [B, ..., T, ...]
    fresh rows; pos: [B] per-row virtual offsets; block_table: [B, NB]
    int32 page ids mapping virtual page `v` of row b to arena page
    block_table[b, v].

    Virtual row pos[b]+t of batch row b lands at
    (block_table[b, (pos[b]+t) // page_size], (pos[b]+t) % page_size).
    Page 0 is the engine's reserved scratch page: bucket-pad rows and
    past-frontier writes of a padded chunk resolve there (their table
    entries are 0) and are overwritten or masked before any read — the
    same discard contract dense pads have, made page-granular."""
    ps = arena.shape[seq_axis]
    NB = block_table.shape[1]
    B = src.shape[0]
    T = src.shape[seq_axis]
    abs_pos = jnp.asarray(pos, jnp.int32)[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    blk = jnp.clip(abs_pos // ps, 0, NB - 1)
    pg = jnp.take_along_axis(jnp.asarray(block_table, jnp.int32), blk, axis=1)
    row = abs_pos % ps
    # [B, ..., T, ...] -> [B*T, ...rest] matching the advanced-index
    # selection shape (page and row indices broadcast to the front)
    srcf = jnp.moveaxis(src, seq_axis, 1).reshape(
        (B * T,) + src.shape[1:seq_axis] + src.shape[seq_axis + 1:])
    index = [slice(None)] * arena.ndim
    index[0] = pg.reshape(-1)
    index[seq_axis] = row.reshape(-1)
    return arena.at[tuple(index)].set(srcf.astype(arena.dtype))


def last_valid(x: jax.Array, valid: Optional[jax.Array]) -> jax.Array:
    """x: [B, T, d] -> [B, 1, d] at each row's last VALID position.  A
    bucket-padded chunk carries valid: [B] real-token counts; the logits a
    caller samples from must come from the last real token, not the pad."""
    if valid is None:
        return x[:, -1:]
    last = jnp.clip(jnp.asarray(valid, jnp.int32) - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, last[:, None, None], axis=1)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int, dtype) -> Params:
    """Stacked (scan-compatible) KV cache for n_layers layers."""
    h = cfg.head_dim_
    if cfg.mla:
        return {
            "ckv": jnp.zeros((n_layers, batch, max_len, cfg.kv_lora_rank),
                             dtype),
            "krope": jnp.zeros((n_layers, batch, max_len, cfg.qk_rope_dim),
                               dtype),
        }
    return {
        "k": jnp.zeros((n_layers, batch, cfg.n_kv_heads, max_len, h), dtype),
        "v": jnp.zeros((n_layers, batch, cfg.n_kv_heads, max_len, h), dtype),
    }


def attention(p: Params, x: jax.Array, rt: Runtime, positions: jax.Array,
              cache: Optional[Params] = None, pos: Optional[jax.Array] = None,
              kv: Optional[jax.Array] = None, causal: bool = True,
              return_kv: bool = False,
              block_table: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Optional[Params]]:
    """GQA/MQA (optionally qk-norm) attention.

    x: [B, S, d]; kv: cross-attention source [B, Sk, d] (None = self-attn);
    cache+pos: single-layer KV cache in positioned-chunk mode — pos is [B]
    int32, each batch row's own cache depth (a scalar broadcasts): the S
    fresh K/V rows are scattered at [pos, pos+S) of each row's cache and
    queries attend offset-causally against the row's full prefix.  S == 1
    is the pooled decode step, S > 1 an in-model prefill chunk — the same
    operation at different widths;
    block_table: [B, NB] int32 page ids — when given, `cache` is a PAGE
    ARENA ([P, Hkv, page_size, h] per layer) rather than per-row storage:
    writes scatter and reads gather through the table, so a row only
    touches the pages it was granted;
    positions: [S] shared rope positions, or [B, S] per-row (chunk/decode);
    return_kv: return this call's post-rope K/V (prefill cache building).
    Returns (y [B, S, d], cache-or-kv).
    """
    if rt.cfg.mla:
        return mla_attention(p, x, rt, positions, cache, pos,
                             return_kv=return_kv, block_table=block_table)
    cfg = rt.cfg
    ap = p["attn"]
    B, S, d = x.shape
    h = cfg.head_dim_
    with jax.named_scope("attention"):
        q = linear(ap["wq"], x).reshape(B, S, cfg.n_heads, h)
        src = x if kv is None else kv
        Sk = src.shape[1]
        k = linear(ap["wk"], src).reshape(B, Sk, cfg.n_kv_heads, h)
        v = linear(ap["wv"], src).reshape(B, Sk, cfg.n_kv_heads, h)
        annotate_cost("attention", "attention", "qkv_proj",
                      flops=2.0 * B * S * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * h)
        if cfg.qk_norm:
            q = ops.rmsnorm(q, ap["q_norm"], eps=cfg.norm_eps, impl=rt.impl)
            k = ops.rmsnorm(k, ap["k_norm"], eps=cfg.norm_eps, impl=rt.impl)
        if kv is None:  # RoPE on self-attention only
            with jax.named_scope("rope"):
                cos, sin = rope_tables(cfg, positions, h)
                if cos.ndim == 3:            # per-row positions [B, S]
                    cos, sin = cos[:, None], sin[:, None]
                q = apply_rope(q.swapaxes(1, 2), cos, sin)       # [B,H,S,h]
                k = apply_rope(k.swapaxes(1, 2), cos, sin)
        else:
            q = q.swapaxes(1, 2)
            k = k.swapaxes(1, 2)
        v = v.swapaxes(1, 2)
        q = shard(q, "batch", "model", None, None)
        k = shard(k, "batch", "model" if cfg.n_kv_heads > 1 else None,
                  None, None)

        if cache is not None and block_table is not None:
            # paged positioned chunk: scatter the S fresh rows through the
            # block table into the shared page arena, read back the row's
            # visible prefix through the same indirection
            pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
            ck = update_cache_pages(cache["k"], k, pos, block_table,
                                    seq_axis=2)
            cv = update_cache_pages(cache["v"], v, pos, block_table,
                                    seq_axis=2)
            if S == 1:                 # decode width: paged flash-decode
                o = ops.decode_attention_paged(
                    q[:, :, 0], ck, cv, block_table=block_table,
                    kv_len=pos + 1, impl=rt.impl)
                o = o.reshape(B, 1, cfg.n_heads, h)
            else:                      # prefill chunk at per-row offsets
                o = ops.chunk_attention_paged(
                    q, ck, cv, block_table=block_table, pos=pos,
                    impl=rt.impl)
                o = o.swapaxes(1, 2)                   # [B,S,Hq,h]
            new_cache = {"k": ck, "v": cv}
        elif cache is not None:
            # positioned chunk: append each row's S fresh k/v rows at its
            # own `pos`, attend to the row's own prefix (offset-causal)
            pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
            ck = update_cache_rows(cache["k"], k, pos, seq_axis=2)
            cv = update_cache_rows(cache["v"], v, pos, seq_axis=2)
            if S == 1:                 # decode width: flash-decode kernel
                kv_len = pos + 1
                o = ops.decode_attention(q[:, :, 0], ck, cv, kv_len=kv_len,
                                         impl=rt.impl)
                o = o[:, None] if o.ndim == 3 else o   # [B,1,Hq,h] fmt below
                o = o.reshape(B, 1, cfg.n_heads, h)
            else:                      # prefill chunk at per-row offsets
                o = ops.chunk_attention(q, ck, cv, pos=pos, impl=rt.impl)
                o = o.swapaxes(1, 2)                   # [B,S,Hq,h]
            new_cache = {"k": ck, "v": cv}
        else:
            o = ops.attention(q, k, v, causal=causal and kv is None,
                              impl=rt.impl)
            o = o.swapaxes(1, 2)                                 # [B,S,Hq,h]
            new_cache = {"k": k, "v": v} if return_kv else None
        y = linear(ap["wo"], o.reshape(B, S, cfg.n_heads * h))
        annotate_cost("attention", "attention", "o_proj",
                      flops=2.0 * B * S * cfg.n_heads * h * d)
        return shard(y, "batch", "seq", None), new_cache


def mla_attention(p: Params, x: jax.Array, rt: Runtime, positions: jax.Array,
                  cache: Optional[Params] = None,
                  pos: Optional[jax.Array] = None,
                  return_kv: bool = False,
                  block_table: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[Params]]:
    """Multi-head Latent Attention (DeepSeek-V2).

    Prefill/train: expand the latent into full per-head K/V.
    Decode: matrix-absorbed latent attention — the cache stores ONLY
    (c_kv [B,S,r], k_rope [B,S,dr]); queries are projected into the latent
    space, and the decode kernel runs with a single latent 'kv head'.
    With block_table the latent cache is a page arena ([P, page_size, r] /
    [P, page_size, dr]) addressed exactly like the GQA one — the latent
    rows page the same way full K/V rows do."""
    cfg = rt.cfg
    ap = p["attn"]
    B, S, d = x.shape
    nh, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    with jax.named_scope("attention"):
        q = linear(ap["wq"], x).reshape(B, S, nh, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        kv_a = linear(ap["wkv_a"], x)                      # [B,S,r+dr]
        c_kv, k_rope = kv_a[..., :r], kv_a[..., r:]
        with jax.named_scope("rope"):
            cos, sin = rope_tables(cfg, positions, dr)
            if cos.ndim == 3:                # per-row positions [B, S]
                cos, sin = cos[:, None], sin[:, None]
            q_rope = apply_rope(q_rope.swapaxes(1, 2), cos, sin)  # [B,nh,S,dr]
            k_rope = apply_rope(k_rope[:, None], cos, sin)        # [B,1,S,dr]
        annotate_cost("attention", "attention", "mla_proj",
                      flops=2.0 * B * S * d * (nh * (dn + dr) + r + dr))

        wkv_b = ap["wkv_b"].reshape(r, nh, dn + dv)
        wk_b, wv_b = wkv_b[..., :dn], wkv_b[..., dn:]      # [r,nh,dn],[r,nh,dv]

        if cache is not None:
            # positioned chunk in LATENT space: scatter this chunk's S
            # latent rows at per-row offsets, matrix-absorb the queries,
            # run the decode kernel (S == 1) or the offset-causal chunk
            # kernel (S > 1) over the single latent 'kv head'
            pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
            if block_table is not None:
                cc = update_cache_pages(cache["ckv"], c_kv, pos,
                                        block_table, seq_axis=1)
                cr = update_cache_pages(cache["krope"], k_rope[:, 0], pos,
                                        block_table, seq_axis=1)
            else:
                cc = update_cache_rows(cache["ckv"], c_kv, pos, seq_axis=1)
                cr = update_cache_rows(cache["krope"], k_rope[:, 0], pos,
                                       seq_axis=1)
            # absorb: q_latent = q_nope @ wk_b^T  -> [B,nh,S,r]
            q_lat = jnp.einsum("bhtd,rhd->bhtr",
                               q_nope.swapaxes(1, 2).astype(jnp.float32),
                               wk_b.astype(jnp.float32)).astype(x.dtype)
            q_full = jnp.concatenate([q_lat, q_rope], -1)   # [B,nh,S,r+dr]
            # [B,1,Smax,r+dr] dense; [P,1,page,r+dr] paged arena — the
            # added axis is the single latent 'kv head' either way
            k_full = jnp.concatenate([cc, cr], -1)[:, None]
            # v = c_kv (latent); pad to r+dr so k/v share a kernel shape
            v_lat = jnp.pad(cc, ((0, 0), (0, 0), (0, dr)))[:, None]
            scale = (dn + dr) ** -0.5
            if block_table is not None:
                if S == 1:
                    o_lat = ops.decode_attention_paged(
                        q_full[:, :, 0], k_full, v_lat,
                        block_table=block_table, kv_len=pos + 1,
                        sm_scale=scale, impl=rt.impl)[:, None]
                else:
                    o_lat = ops.chunk_attention_paged(
                        q_full, k_full, v_lat, block_table=block_table,
                        pos=pos, sm_scale=scale,
                        impl=rt.impl).swapaxes(1, 2)
            elif S == 1:
                kv_len = pos + 1
                o_lat = ops.decode_attention(
                    q_full[:, :, 0], k_full, v_lat, kv_len=kv_len,
                    sm_scale=scale, impl=rt.impl)[:, None]   # [B,1,nh,r+dr]
            else:
                o_lat = ops.chunk_attention(
                    q_full, k_full, v_lat, pos=pos, sm_scale=scale,
                    impl=rt.impl).swapaxes(1, 2)             # [B,S,nh,r+dr]
            o_lat = o_lat[..., :r]
            o = jnp.einsum("bthr,rhd->bthd", o_lat.astype(jnp.float32),
                           wv_b.astype(jnp.float32)).astype(x.dtype)
            new_cache = {"ckv": cc, "krope": cr}
        else:
            from repro.parallel.axes import shard_dims
            _ch = lambda t: shard_dims(t, {0: "batch", 1: "model"})
            # expand the latent in COMPUTE dtype with heads pinned to the TP
            # axis: the f32-staged version produced a 2.1 GB f32 all-gather
            # per layer (220 GB/step on deepseek train_4k — EXPERIMENTS.md
            # §Perf deepseek iteration 2)
            k_nope = _ch(jnp.einsum("bsr,rhd->bhsd", c_kv,
                                    wk_b.astype(c_kv.dtype)))
            v = _ch(jnp.einsum("bsr,rhd->bhsd", c_kv,
                               wv_b.astype(c_kv.dtype)))
            k = _ch(jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope, (B, nh, S, dr))], -1))
            qq = _ch(jnp.concatenate([q_nope.swapaxes(1, 2), q_rope], -1))
            # pad v (dv) up to qk dim so the flash kernel sees equal D
            dq = dn + dr
            v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dq - dv)))
            o = ops.attention(qq, k, v_p, causal=True, sm_scale=dq ** -0.5,
                              impl=rt.impl)[..., :dv]
            o = o.swapaxes(1, 2)                                    # [B,S,nh,dv]
            new_cache = ({"ckv": c_kv, "krope": k_rope[:, 0]}
                         if return_kv else None)
        y = linear(ap["wo"], o.reshape(B, S, nh * dv))
        return shard(y, "batch", "seq", None), new_cache


# ------------------------------------------------------------------- mlp ----
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = pdtype(cfg)
    p = {"w_up": _init(ks[1], (d, f), dt), "w_down": _init(ks[2], (f, d), dt)}
    if cfg.mlp_gated:
        p["w_gate"] = _init(ks[0], (d, f), dt)
    return {"mlp": p}


def mlp(p: Params, x: jax.Array, rt: Runtime) -> jax.Array:
    mp = p["mlp"]
    cfg = rt.cfg
    with jax.named_scope("mlp"):
        if getattr(cfg, "manual_tp", False):
            from repro.parallel.tp import col_row_mlp, manual_tp_available
            f = mp["w_up"].shape[1]
            if manual_tp_available(f):
                nmat = 3 if cfg.mlp_gated else 2
                annotate_cost("mlp", "mlp", "ffn",
                              flops=2.0 * x.shape[0] * x.shape[1]
                              * cfg.d_model * f * nmat)
                y = col_row_mlp(x, mp["w_up"], mp["w_down"],
                                mp.get("w_gate"), cfg.mlp_gated)
                return shard(y, "batch", "seq", None)
        up = linear(mp["w_up"], x)
        if cfg.mlp_gated:
            act = jax.nn.silu(linear(mp["w_gate"], x).astype(jnp.float32))
            hidden = (act * up.astype(jnp.float32)).astype(x.dtype)
        else:
            hidden = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
        hidden = shard(hidden, "batch", "seq", "model")
        y = linear(mp["w_down"], hidden)
        f = mp["w_up"].shape[1]
        nmat = 3 if cfg.mlp_gated else 2
        annotate_cost("mlp", "mlp", "ffn",
                      flops=2.0 * x.shape[0] * x.shape[1] * cfg.d_model * f * nmat)
        return shard(y, "batch", "seq", None)


# ----------------------------------------------------------------- embed ----
def init_embed(key, cfg: ModelConfig) -> Params:
    return {"embed": {"table": _init(key, (cfg.vocab, cfg.d_model),
                                     pdtype(cfg), scale=1.0)}}


def embed(p: Params, tokens: jax.Array, rt: Runtime) -> jax.Array:
    with jax.named_scope("embed"):
        x = jnp.take(p["embed"]["table"], tokens, axis=0).astype(rt.cdtype)
        annotate_cost("embed", "embed", "lookup", bytes=float(x.size * 2))
        return shard(x, "batch", "seq", None)


def init_lm_head(key, cfg: ModelConfig) -> Params:
    if cfg.tie_embeddings:
        return {}
    return {"lm_head": {"w": _init(key, (cfg.d_model, cfg.vocab), pdtype(cfg))}}


def lm_head(p: Params, x: jax.Array, rt: Runtime) -> jax.Array:
    with jax.named_scope("lm_head"):
        w = (p["embed"]["table"].T if rt.cfg.tie_embeddings
             else p["lm_head"]["w"])
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
        annotate_cost("lm_head", "lm_head", "proj",
                      flops=2.0 * x.shape[0] * x.shape[1] * rt.cfg.d_model
                      * rt.cfg.vocab)
        return shard(logits, "batch", "seq", "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL in f32; mask: [B, S] 1=count.

    Vocab-sharding safe: the gold logit is extracted by a one-hot
    CONTRACTION over the vocab dim (fuses to iota+select+reduce and keeps
    the vocab dim sharded under SPMD), never a take_along_axis gather that
    would force an all-gather of [B, S, V] logits."""
    with jax.named_scope("loss"):
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
        gold = jnp.einsum("bsv,bsv->bs", lf, onehot)
        nll = lse - gold
        if mask is None:
            return jnp.mean(nll)
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
