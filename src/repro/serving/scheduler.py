"""Slot admission + chunked-prefill budgeting for the serving engine.

The scheduler owns the WAITING side of continuous batching: the FCFS
queue of submitted requests, the fixed slot pool's occupancy bookkeeping
(which request holds which cache row, at what depth, with how much
prompt left to feed), and the per-tick prefill plan.

Admission is iteration-level (vLLM-style): any tick with free slots may
admit, bounded by a chunked-prefill token budget so a burst of long
prompts cannot stall slots that are already decoding (Sarathi-style
prefill/decode interference control).  Prefill is IN-MODEL chunked: the
admission chunk and every continuation chunk of a longer prompt's tail
run through the same positioned `forward_chunk` step at the slot's cache
offset, up to `prefill_chunk` (continuations: `tail_chunk`) tokens per
step — one code path from first prompt token to pooled decode.

Fairness: strict FCFS.  Continuation chunks belong to requests admitted
BEFORE anything still waiting, so each tick plans continuations first
(oldest admission first), then admissions with whatever budget remains.
The budget never reorders the queue, and the first prefill step of a
tick always fits, so one huge prompt is delayed (by the budget) but
never starved — and neither is a long tail mid-prefill.

Batched prefill plan: once a tick's chunks are SELECTED (continuations
then admissions, under the budget), `batched_prefill_plan` groups them
by compiled chunk width into at most `prefill_batch`-row groups — each
group one multi-row `forward_chunk` call in the engine.  Grouping only
changes HOW the selected chunks execute, never WHO was selected, so the
FCFS/budget guarantees above are untouched by batching.  The scheduler
also owns the compiled-shape discipline: chunk widths and group batch
dims both round to power-of-two buckets (`chunk_width`, `batch_bucket`),
keeping the engine's program set O(log batch x log seq_len).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, List, Optional, Tuple

import numpy as np

from repro.configs.base import ServeConfig


@dataclasses.dataclass
class Slot:
    """One row of the batched cache pool."""
    request: Optional[object] = None   # serving.engine.Request (duck-typed)
    pos: int = 0                       # next cache position to write
    pending: Deque[int] = dataclasses.field(default_factory=deque)
    seq: int = 0                       # admission order (continuation FCFS)
    stash: Any = None                  # batch=1 cache pytree while prefilling

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def prefilling(self) -> bool:
        """Still owed prompt chunks (not yet in the pooled decode)."""
        return self.request is not None and bool(self.pending)


class Scheduler:
    """Iteration-level admission + chunk planning over a fixed slot pool."""

    def __init__(self, scfg: ServeConfig) -> None:
        self.scfg = scfg
        self.waiting: Deque = deque()
        self.slots: List[Slot] = [Slot() for _ in range(scfg.max_batch)]
        self._admit_seq = 0
        # paged-cache admission gate: callable(req) -> bool, set by the
        # engine when the pool is paged.  True = the pool RESERVED the
        # request's worst-case pages (the gate has side effects — the
        # engine must consume or cancel the reservation); False = not
        # enough free pages, and because admission is strict FCFS the
        # whole queue waits behind its head rather than letting a short
        # request jump a long one (no out-of-order admission, no
        # starvation).  None = slot count is the only admission resource.
        self.page_gate = None

    # -- queue side ---------------------------------------------------------
    def add(self, req) -> None:
        self.waiting.append(req)

    def has_waiting(self) -> bool:
        return bool(self.waiting)

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.active())

    # -- pool side ----------------------------------------------------------
    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    def decoding(self) -> List[int]:
        """Slots past prefill: they join the pooled decode tick."""
        return [i for i, s in enumerate(self.slots)
                if s.request is not None and not s.pending]

    def prefilling_slots(self) -> List[int]:
        """Slots owed continuation chunks, oldest admission first."""
        out = [i for i, s in enumerate(self.slots) if s.prefilling]
        return sorted(out, key=lambda i: self.slots[i].seq)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def admit_cost(self, req) -> int:
        """Prefill tokens the ADMISSION chunk will actually consume —
        after the engine's truncation to fit the cache row (charging the
        raw prompt length would overbill truncated requests and block
        cheap neighbours for no real work)."""
        limit = self.scfg.max_seq_len \
            - getattr(req, "max_new_tokens", 0) - 1
        plen = min(len(req.prompt), max(limit, 1))
        chunk = self.scfg.prefill_chunk or plen
        return max(1, min(plen, chunk))

    @property
    def tail_chunk(self) -> int:
        """Continuation chunk width (tokens per forward_chunk step)."""
        return self.scfg.tail_chunk or self.scfg.prefill_chunk or 1

    # -- compiled-shape discipline ------------------------------------------
    def chunk_width(self, n: int, pos: int) -> int:
        """Compiled width for a chunk of <= n tokens starting at cache
        offset `pos`: the next power-of-two bucket (>= min_chunk_bucket),
        bucketed DOWN while a padded write would run past the row end (a
        clamped scatter would shift garbage onto valid entries).  May
        return less than n — the caller then consumes fewer tokens and
        leaves the rest pending, keeping every width a power of two: the
        compiled-program set stays O(log) even for non-power-of-two
        max_seq_len rows."""
        scfg = self.scfg
        if not scfg.bucket_chunks:
            return n
        w = max(scfg.min_chunk_bucket, 1)
        while w < n:
            w *= 2
        room = scfg.max_seq_len - pos          # >= n: the engine clamps
        while w > room and w > 1:
            w //= 2
        return w

    def batch_bucket(self, rows: int) -> int:
        """Compiled batch dimension for a `rows`-row prefill group: the
        next power of two (pad rows masked via `valid`) under bucketing,
        exact otherwise — with widths also bucketed, group shapes come
        from an O(log prefill_batch x log max_seq_len) set."""
        if not self.scfg.bucket_chunks:
            return rows
        b = 1
        while b < rows:
            b *= 2
        return b

    @property
    def prefill_batch(self) -> int:
        """Effective rows-per-group cap (never more than the pool)."""
        return max(1, min(self.scfg.prefill_batch, self.scfg.max_batch))

    def batched_prefill_plan(self, items: List[Tuple[int, int]]
                             ) -> List[Tuple[List[int], List[int], int]]:
        """Group this tick's SELECTED prefill chunks [(slot_idx, n)] —
        continuations first, then admissions, exactly as the budget
        picked them — into (slot_indices, n_tokens, width) groups of at
        most `prefill_batch` same-width rows: each group is ONE
        multi-row forward_chunk call.  Selection already enforced FCFS
        and the token budget; grouping only changes how the chunks run,
        never who runs, so an older mid-prefill slot can never be
        displaced by a batch of younger admissions.  An item's width may
        bucket DOWN near its row end (it then consumes min(n, width)
        tokens); items group by that final width."""
        cap = self.prefill_batch
        groups: List[Tuple[List[int], List[int], int]] = []
        open_group = {}                # width -> index of its open group
        for idx, n in items:
            slot = self.slots[idx]
            w = self.chunk_width(min(n, len(slot.pending)), slot.pos)
            n = min(n, w)
            g = open_group.get(w)
            if g is None or len(groups[g][0]) >= cap:
                open_group[w] = len(groups)
                groups.append(([idx], [n], w))
            else:
                groups[g][0].append(idx)
                groups[g][1].append(n)
        return groups

    def continuation_plan(self) -> Tuple[List[Tuple[int, int]], bool]:
        """((slot_idx, n_tokens) continuation chunks for this tick,
        deferred?): every mid-prefill slot advances by up to `tail_chunk`
        tokens, oldest admission first, under the per-tick prefill token
        budget.  The first chunk of the tick always fits (a long tail can
        be slowed by the budget, never starved); an oversized chunk is
        skipped, not a barrier, so smaller chunks of LATER-admitted
        (but still older-than-any-waiting) slots may consume the
        leftover.  `deferred` reports whether any mid-prefill slot got
        nothing — admissions must then wait a tick (every mid-prefill
        request predates everything in the waiting queue)."""
        budget = self.scfg.prefill_budget_tokens
        out: List[Tuple[int, int]] = []
        spent = 0
        deferred = False
        for idx in self.prefilling_slots():
            n = min(len(self.slots[idx].pending), self.tail_chunk)
            if out and budget and spent + n > budget:
                deferred = True
                continue
            out.append((idx, n))
            spent += n
        return out, deferred

    def schedule(self, spent: int = 0) -> List[Tuple[int, object]]:
        """Admissions for this tick: FCFS into free slots under the
        prefill token budget.  `spent` is what this tick's continuation
        chunks already consumed — waiting requests arrived after every
        mid-prefill request, so they only see the leftover budget.  The
        first prefill step of a tick (spent == 0, nothing admitted yet)
        always fits regardless of cost (no starvation of long prompts)."""
        budget = self.scfg.prefill_budget_tokens
        out: List[Tuple[int, object]] = []
        free = self.free_slots()
        while free and self.waiting:
            cost = self.admit_cost(self.waiting[0])
            if (out or spent) and budget and spent + cost > budget:
                break
            if self.page_gate is not None \
                    and not self.page_gate(self.waiting[0]):
                break                      # page back-pressure: FCFS waits
            out.append((free.pop(0), self.waiting.popleft()))
            spent += cost
        return out

    def bind(self, idx: int, req, pos: int, pending, stash: Any = None
             ) -> None:
        """Occupy slot `idx`: cache holds `pos` tokens, `pending` is the
        not-yet-prefilled prompt remainder (fed through forward_chunk
        steps), `stash` the batch=1 cache being filled until the prompt
        completes and scatters into the pool."""
        self._admit_seq += 1
        self.slots[idx] = Slot(request=req, pos=pos, pending=deque(pending),
                               seq=self._admit_seq, stash=stash)

    def release(self, idx: int) -> None:
        self.slots[idx] = Slot()

    def pos_vector(self) -> np.ndarray:
        """[max_batch] int32 per-slot cache depths (free slots at 0)."""
        return np.asarray([s.pos for s in self.slots], np.int32)
