"""seamless-m4t-large-v2 — encoder-decoder multimodal translation backbone
[arXiv:2308.11596]. Speech frontend is a STUB (precomputed frame embeddings
via input_specs); backbone = 24L encoder + 24L decoder w/ cross-attention."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    enc_layers=24, dec_layers=24, cross_attn=True,
    src_frontend="audio_frames", frontend_dim=1024,
    mlp_gated=False,
).validate()


def smoke():
    return reduced(CONFIG, enc_layers=2, dec_layers=2)
