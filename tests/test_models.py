"""Per-architecture smoke tests (reduced configs) + serving consistency.

Every assigned arch: one forward/train step on CPU asserting output shapes
and no NaNs (assignment requirement), plus prefill->decode == full-forward
consistency for one arch per family (the strongest end-to-end invariant a
serving stack has)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_archs
from repro.models import build_model
from repro.models.layers import Runtime, lm_head


def make_batch(cfg, B=2, S=64, seed=1):
    key = jax.random.key(seed)
    text_s = S - (16 if cfg.family == "vlm" else 0)
    tok = jax.random.randint(key, (B, text_s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok,
             "mask": jnp.ones_like(tok, jnp.float32)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.frontend_dim))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    table = model.table()
    loss, (metrics, table) = model.loss_fn(params, batch, table)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert float(loss) > 0
    # gradients flow and are finite
    g = jax.grad(lambda p: model.loss_fn(p, batch, model.table())[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    cache = model.init_cache(B, 96, **({"src_len": S} if cfg.family == "audio"
                                       else {}))
    table = model.table()
    prompt = {k: (v[:, :32] if k == "tokens" else v) for k, v in batch.items()}
    logits, cache, table = model.prefill(params, prompt, table, cache)
    assert logits.shape == (B, cfg.vocab)
    lg, cache, table = model.decode_step(
        params, batch["tokens"][:, 0], table, cache, jnp.int32(32))
    assert lg.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg))), f"{arch}: NaN decode logits"


FAMILY_REPS = ["tinyllama_1_1b", "deepseek_v2_lite_16b", "zamba2_2_7b",
               "xlstm_1_3b", "qwen3_14b"]


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) then decode(token) must equal the full forward pass —
    the cache path and the training path are the same function.

    MoE capacity drops depend on batch composition (a 34-token forward and a
    32-token prefill can drop different tokens), so the consistency check
    runs drop-free (high capacity factor)."""
    cfg = dataclasses.replace(get_smoke(arch), capacity_factor=8.0)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (2, 40), 0, cfg.vocab)
    table = model.table()
    cache = model.init_cache(2, 64)
    logits_p, cache, table = model.prefill(
        params, {"tokens": tok[:, :32]}, table, cache)
    logits_d, cache, table = model.decode_step(
        params, tok[:, 32], table, cache, jnp.int32(32))

    # ground truth from the training-path forward
    from repro.models import encdec, mamba, transformer, xlstm
    mod = {"dense": transformer, "moe": transformer, "vlm": transformer,
           "hybrid": mamba, "ssm": xlstm}[cfg.family]
    rt = model.rt
    x, _, _ = mod.forward(params, tok[:, :34], rt, model.table())
    full = lm_head(params, x, rt)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, 31]), atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full[:, 32]), atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ["seamless_m4t_large_v2", "internvl2_1b"])
def test_multimodal_chunked_prefill_matches_bulk(arch):
    """forward_chunk continuation == bulk prefill for the families the
    token-prompt engine can't serve: the multimodal prefix (audio frames /
    vlm patches) rides the pos=0 chunk via the prefill wrapper, later
    chunks continue token-only at the cache offset — including a
    bucket-padded chunk whose pad is masked via `valid`."""
    cfg = get_smoke(arch)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    tok = batch["tokens"][:, :24]
    src = {"src_len": S} if cfg.family == "audio" else {}
    prefix = cfg.n_patches if cfg.family == "vlm" else 0

    bulk = dict(batch)
    bulk["tokens"] = tok
    logits_bulk, _, _ = model.prefill(params, bulk, model.table(),
                                      model.init_cache(B, 96, **src))

    head = dict(batch)
    head["tokens"] = tok[:, :10]
    cache = model.init_cache(B, 96, **src)
    _, cache, table = model.prefill(params, head, model.table(), cache)
    # 14-token continuation bucket-padded to 16, valid = 14
    padded = jnp.zeros((B, 16), jnp.int32).at[:, :14].set(tok[:, 10:24])
    logits_chunk, _, _ = model.forward_chunk(
        params, padded, table, cache,
        jnp.full((B,), prefix + 10, jnp.int32), jnp.full((B,), 14,
                                                         jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_chunk),
                               np.asarray(logits_bulk),
                               atol=2e-3, rtol=1e-3)


def test_decode_is_causal_wrt_future():
    """Changing tokens after position p must not change decode at p."""
    cfg = get_smoke("tinyllama_1_1b")
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab)
    out = []
    for variant in (tok, tok.at[:, 20:].set(0)):
        cache = model.init_cache(1, 64)
        lg, _, _ = model.prefill(params, {"tokens": variant[:, :16]},
                                 model.table(), cache)
        out.append(np.asarray(lg))
    np.testing.assert_allclose(out[0], out[1])


def test_moe_emits_fold_metrics():
    cfg = get_smoke("phi3_5_moe_42b")
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    table = model.table()
    loss, (_, table) = model.loss_fn(params, batch, table)
    folded = model.fold_spec.fold(np.asarray(table))
    edge = folded.edges[("decoder", "moe", "dispatch")]
    loads = [v for k, v in edge.metrics.items() if k.startswith("expert_load")]
    # every token routed top_k times across all moe layers
    T = batch["tokens"].size
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    assert sum(loads) == pytest.approx(T * cfg.top_k * n_moe_layers)


def test_moe_capacity_drops_counted():
    cfg = dataclasses.replace(get_smoke("phi3_5_moe_42b"),
                              capacity_factor=0.05)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    table = model.table()
    _, (_, table) = model.loss_fn(params, batch, table)
    folded = model.fold_spec.fold(np.asarray(table))
    dropped = folded.edges[("decoder", "moe", "dispatch")].metrics[
        "dropped_tokens"]
    assert dropped > 0


def test_mlstm_chunked_matches_sequential():
    from repro.models import xlstm as xl
    rng = np.random.default_rng(3)
    B, H, L, ph = 2, 2, 96, 8
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q, k, v = mk(B, H, L, ph), mk(B, H, L, ph), mk(B, H, L, ph)
    logf = jax.nn.log_sigmoid(mk(B, H, L) * 2)
    logi = mk(B, H, L) * 2
    y1, (C1, n1, m1) = xl._mlstm_cell_seq(q, k, v, logf, logi)
    y2, (C2, n2, m2) = xl._mlstm_cell_chunked(q, k, v, logf, logi, chunk=16)
    np.testing.assert_allclose(y1, y2, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(C1, C2, atol=5e-5, rtol=5e-4)


def test_analytic_param_count_close():
    """cfg.n_params() (used for 6ND roofline) tracks the real param count."""
    for arch in list_archs():
        cfg = get_smoke(arch)
        model = build_model(cfg, impl="ref")
        n_real = sum(np.prod(x.shape) for x in
                     jax.tree.leaves(jax.eval_shape(model.init,
                                                    jax.random.key(0))))
        n_est = cfg.n_params()
        assert abs(n_est - n_real) / n_real < 0.35, \
            f"{arch}: analytic {n_est} vs real {n_real}"
