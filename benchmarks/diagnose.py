"""Diagnosis subsystem cost: graph construction + detector sweep +
calibration on a fleet-sized profile.

Diagnosis runs offline, but "offline" still has a budget: an operator
pointing `diagnose` at a registry of nightly runs should get findings in
seconds, and the ScALPEL argument (diagnostics must stay lightweight)
deserves a number.  This benchmark builds a 10k-edge profile spread over
8 shards with 6-deep rings — the shape a day of fleet runs leaves behind
— and times each layer:

  diagnose.graph_ms        FlowGraph.from_columns on the merged profile
  diagnose.shards_ms       per-shard graph projection (8 subgraphs)
  diagnose.detect_ms       full built-in detector sweep over the context
  diagnose.calibrate_ms    ring-mode noise-band fit over every interval
  diagnose.e2e_ms          store -> context -> findings, end to end
  diagnose.findings        finding count (sanity: the injected pathologies
                           are found, a healthy fleet stays quiet)
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.analysis import (FlowGraph, build_context, builtin_detectors,
                            calibrate_ring, run_detectors)
from repro.core.folding import EdgeStats, FoldedTable
from repro.profile import ProfileStore, build_timelines

N_EDGES = 10_000
N_SHARDS = 8
RING_LEN = 6


def _fleet_table(seed: int, scale: float = 1.0,
                 n_edges: int = N_EDGES) -> FoldedTable:
    rng = np.random.default_rng(seed)
    durs = rng.integers(1_000, 1_000_000, size=n_edges)
    counts = rng.integers(1, 100, size=n_edges)
    edges = {}
    for j in range(n_edges):
        key = (f"comp{j % 37}", f"lib{j % 101}", f"api{j}")
        d = int(durs[j] * scale)
        edges[key] = EdgeStats(
            count=int(counts[j]), total_ns=d * int(counts[j]),
            child_ns=d // 2, min_ns=d // 2, max_ns=d * 2,
            kind=1 if j % 29 == 0 else 0)
    # one injected pathology so the sweep has something to find: a
    # wait-dominated component
    edges[("app", "hotspot", "sync")] = EdgeStats(
        count=100, total_ns=900_000_000, min_ns=1, max_ns=9_000_000, kind=1)
    edges[("app", "hotspot", "work")] = EdgeStats(
        count=100, total_ns=100_000_000, min_ns=1, max_ns=2_000_000)
    return FoldedTable(edges)


def _build_run(root: str) -> str:
    store = ProfileStore(root)
    for s in range(N_SHARDS):
        for i in range(1, RING_LEN + 1):
            # cumulative folds: interval activity is one _fleet_table
            t = FoldedTable.merge_all([_fleet_table(s, scale=1.0)
                                       for _ in range(i)])
            store.write_shard(t, label=f"rank-{s}")
    return root


def _best_of(fn, repeats: int = 3):
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3, out


def run():
    with tempfile.TemporaryDirectory() as d:
        run_dir = _build_run(os.path.join(d, "run"))
        store = ProfileStore(run_dir)
        cols = store.reduce().columns

        graph_ms, graph = _best_of(lambda: FlowGraph.from_columns(cols))
        from repro.analysis import shard_graphs
        shards_ms, shards = _best_of(lambda: shard_graphs(run_dir))
        ctx = build_context(run_dir)
        dets = builtin_detectors()
        detect_ms, findings = _best_of(lambda: run_detectors(ctx, dets))
        tls = build_timelines(run_dir)
        calibrate_ms, thr = _best_of(lambda: calibrate_ring(tls))

        def e2e():
            from repro.analysis import diagnose
            return diagnose(run_dir)
        e2e_ms, diag = _best_of(e2e, repeats=1)

        assert len(graph) == len(cols)
        assert len(shards) == N_SHARDS
        assert any(f.detector == "wait-dominance" for f in findings), \
            "injected pathology not found"
        assert len(thr) >= N_EDGES

    note = f"{N_SHARDS} shards x {N_EDGES} edges x {RING_LEN} ring"
    yield "diagnose.graph_ms", graph_ms, note
    yield "diagnose.shards_ms", shards_ms, note
    yield "diagnose.detect_ms", detect_ms, f"{len(dets)} detectors"
    yield "diagnose.calibrate_ms", calibrate_ms, \
        f"{len(thr)} bands from {RING_LEN - 1} intervals"
    yield "diagnose.e2e_ms", e2e_ms, "store -> findings"
    yield "diagnose.findings", float(len(diag.findings)), "count"


if __name__ == "__main__":
    print("name,value,note")
    for name, val, note in run():
        print(f"{name},{val:.3f},{note}")
