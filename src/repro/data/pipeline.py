"""Deterministic synthetic LM data pipeline — host-sharded, prefetching.

Production posture without a corpus: tokens are a splittable counter-based
hash (Philox-like mix of (seed, step, position, shard)), so every host
generates exactly its own shard with no coordination, any step is
reproducible in O(1) (restart-friendly: resume at step k without replaying),
and the stream differs across DP shards.

The pipeline is XFA-instrumented (@xfa.api('data')): per-batch generation
time and the host->device feed boundary both appear in the component view —
the paper's dedup-1 (I/O-bound application) case study is reproduced against
exactly these edges in benchmarks/effectiveness.py.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import tracer as xfa


def _mix(x: np.ndarray) -> np.ndarray:
    """64-bit splitmix-style stateless mix."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    return x ^ (x >> np.uint64(31))


class SyntheticLMData:
    """Iterator of host-local training batches."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, shard: int = 0, n_shards: int = 1,
                 prefetch: int = 2) -> None:
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.shard = shard
        self.n_shards = n_shards
        self.step = 0
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- synchronous generation (also used directly by tests) ---------------
    @xfa.api("data", "generate_batch")
    def generate(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        text_s = self.seq_len - (cfg.n_patches if cfg.family == "vlm" else 0)
        b, s = self.batch, text_s + 1
        base = (np.uint64(self.seed) << np.uint64(40)) \
            + (np.uint64(step) << np.uint64(20)) \
            + (np.uint64(self.shard) << np.uint64(56))
        idx = np.arange(b * s, dtype=np.uint64) + base
        toks = (_mix(idx) % np.uint64(self.cfg.vocab)).astype(np.int32)
        toks = toks.reshape(b, s)
        batch = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, s - 1), np.float32),
        }
        if cfg.family == "vlm":
            fidx = np.arange(b * cfg.n_patches * cfg.frontend_dim,
                             dtype=np.uint64) + base
            batch["patches"] = (
                (_mix(fidx) % np.uint64(2000)).astype(np.float32) / 1000.0
                - 1.0).reshape(b, cfg.n_patches, cfg.frontend_dim)
        if cfg.family == "audio":
            fidx = np.arange(b * self.seq_len * cfg.frontend_dim,
                             dtype=np.uint64) + base + np.uint64(7)
            batch["frames"] = (
                (_mix(fidx) % np.uint64(2000)).astype(np.float32) / 1000.0
                - 1.0).reshape(b, self.seq_len, cfg.frontend_dim)
        return batch

    # -- prefetching iterator ------------------------------------------------
    def _worker(self):
        xfa.set_thread_group("data_workers")
        step = self.step
        while not self._stop.is_set():
            batch = self.generate(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self, at_step: int = 0) -> "SyntheticLMData":
        self.step = at_step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="data-prefetch")
        self._thread.start()
        return self

    @xfa.wait("data", "next_batch")
    def __next__(self) -> Dict[str, np.ndarray]:
        if self._thread is None:
            batch = self.generate(self.step)
            self.step += 1
            return batch
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None


def make_batch_fn(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """Stateless batch constructor for a (cfg, shape) cell."""
    data = SyntheticLMData(cfg, shape.global_batch, shape.seq_len, seed=seed)
    return data.generate
