"""Pooled per-slot sampling — greedy / temperature / top-k / top-p.

Per-REQUEST sampling params live as per-SLOT vectors so ONE jitted
sampler covers the whole pool every tick regardless of which requests
occupy which slots — the same static-shape discipline as the decode step
itself: params are array *values*, not compile-time constants, so
requests coming and going never retrace.

Determinism: the PRNG key for a token is derived from (request seed,
absolute context length), so a request's sampled continuation is
identical whether it decodes alone, batched with arbitrary neighbours,
or with its prompt chunked differently — the serving-equivalence test
relies on this.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding strategy (all combinable; greedy by default)."""
    temperature: float = 0.0         # 0 -> greedy (argmax)
    top_k: int = 0                   # 0 -> full vocab
    top_p: float = 1.0               # nucleus mass; 1.0 -> no nucleus cut
    seed: int = 0                    # PRNG stream for this request


GREEDY = SamplingParams()


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array,
                  seed: jax.Array, step: jax.Array) -> jax.Array:
    """Sample one token per pool row.  logits: [B, V]; all params [B].

    step is the row's absolute context length at sampling time — it salts
    the per-row PRNG key so token t of a request is a pure function of
    (seed, t), independent of batch composition.  Rows with
    temperature <= 0 take the argmax."""
    B, V = logits.shape
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1)
    lg = lf / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: drop logits below each row's k-th largest (k = 0 keeps all)
    srt = jnp.sort(lg, axis=-1)[:, ::-1]
    k = jnp.where(top_k > 0, top_k, V).astype(jnp.int32)
    kth = jnp.take_along_axis(srt, (k - 1)[:, None], axis=-1)
    lg = jnp.where(lg < kth, NEG_INF, lg)
    # top-p nucleus on the post-top-k distribution; the top token always
    # survives, so top_p -> 0 degenerates to greedy, never empty support
    probs = jax.nn.softmax(lg, axis=-1)
    ps = jnp.sort(probs, axis=-1)[:, ::-1]
    cum = jnp.cumsum(ps, axis=-1)
    keep_sorted = (cum - ps) < top_p[:, None]        # exclusive-cum mass
    keep_sorted = keep_sorted.at[:, 0].set(True)
    thresh = jnp.min(jnp.where(keep_sorted, ps, jnp.inf), axis=-1)
    lg = jnp.where(probs < thresh[:, None], NEG_INF, lg)
    keys = jax.vmap(lambda s, t: jax.random.fold_in(jax.random.key(s), t))(
        seed, step)
    sampled = jax.vmap(jax.random.categorical)(keys, lg)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)


class PooledSampler:
    """Host-side mirror of per-slot sampling state + the jitted kernel.

    bind/release keep [B] param vectors in step with slot occupancy;
    __call__ runs the whole pool through one compiled sample_tokens."""

    def __init__(self, max_batch: int) -> None:
        self.max_batch = max_batch
        self.temperature = np.zeros((max_batch,), np.float32)
        self.top_k = np.zeros((max_batch,), np.int32)
        self.top_p = np.ones((max_batch,), np.float32)
        self.seed = np.zeros((max_batch,), np.uint32)
        self._fn = jax.jit(sample_tokens)

    def bind(self, i: int, sp: SamplingParams) -> None:
        self.temperature[i] = sp.temperature
        self.top_k[i] = sp.top_k
        self.top_p[i] = sp.top_p
        self.seed[i] = np.uint32(sp.seed)

    def release(self, i: int) -> None:
        self.bind(i, GREEDY)

    def __call__(self, logits, step) -> np.ndarray:
        """logits: [B, V]; step: [B] context length per row -> tokens [B]."""
        return np.asarray(self._fn(
            jnp.asarray(logits), jnp.asarray(self.temperature),
            jnp.asarray(self.top_k), jnp.asarray(self.top_p),
            jnp.asarray(self.seed), jnp.asarray(step, jnp.int32)))

    def sample_one(self, logits_row, sp: SamplingParams, step: int) -> int:
        """Single-sequence sampling (prefill's first token) through the
        SAME kernel semantics as the pooled path."""
        out = self._fn(
            jnp.asarray(logits_row)[None],
            jnp.full((1,), sp.temperature, jnp.float32),
            jnp.full((1,), sp.top_k, jnp.int32),
            jnp.full((1,), sp.top_p, jnp.float32),
            jnp.full((1,), np.uint32(sp.seed), jnp.uint32),
            jnp.full((1,), step, jnp.int32))
        return int(out[0])
