"""Device-side shadow table — in-graph Relation-Aware Data Folding.

Inside an XLA program we cannot timestamp (no rdtsc on a systolic array) but
we CAN fold: a fixed-shape f32 vector rides through the jitted step function
and every instrumented site adds its metrics at a *statically resolved*
offset.  This is the Universal Shadow Table transplanted into the dataflow
graph:

  shadow entry            ->  a [width] span of the fold vector at a static
                              offset, resolved at TRACE time (= lazy PLT
                              resolution happening at "link" time)
  assembly in the entry   ->  one fused add per site: O(width) scalar work vs
                              O(1e9) FLOP matmuls — overhead measured in
                              benchmarks/overhead.py
  per-thread tables       ->  the fold vector is part of the step carry; under
                              scan-over-layers it lives in the carry; across
                              devices it is replicated (values are global)
  relation-awareness      ->  the slot key is (caller, component, api, metric)
                              so the same metric emitted from two callers
                              folds separately

What the device layer folds is the *data-dependent* signal that static HLO
analysis cannot see: MoE expert load/overflow, router entropy, token counts,
capacity drops, gradient norms — the signals behind the paper's ferret
(imbalance) and swaptions (misconfiguration) case studies.  Static per-step
costs (FLOPs per scope) are registered at trace time via `annotate_cost` —
they need no runtime representation at all, the trace IS the count.

Counts are folded in f32: exact up to 2**24 per step-segment; DeviceFoldSpec
validates declared maxima and the session accumulates cross-step sums in f64
on the host after fetch.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .folding import EdgeStats, FoldedTable
from .shadow import KIND_CALL, SlotKey

DeviceSlotKey = Tuple[str, str, str, str]  # (caller, component, api, metric)


@dataclass(frozen=True)
class DeviceSlot:
    key: DeviceSlotKey
    offset: int
    width: int


class DeviceFoldSpec:
    """Declared-upfront slot layout for one model family's device fold.

    Model builders declare every metric they will emit (they know E, top_k,
    n_stages... from the config), the spec freezes, and `init_table` returns
    the zeroed vector.  Declaring after freeze or emitting an undeclared key
    raises — an unresolved shadow entry is a bug, not a fallback.
    """

    def __init__(self) -> None:
        self._slots: Dict[DeviceSlotKey, DeviceSlot] = {}
        self._order: List[DeviceSlot] = []
        self._size = 0
        self._frozen = False
        self._lock = threading.Lock()

    def declare(self, caller: str, component: str, api: str, metric: str,
                width: int = 1) -> DeviceSlot:
        key = (caller, component, api, metric)
        with self._lock:
            if key in self._slots:
                existing = self._slots[key]
                if existing.width != width:
                    raise ValueError(f"slot {key} re-declared with width "
                                     f"{width} != {existing.width}")
                return existing
            if self._frozen:
                raise RuntimeError(f"DeviceFoldSpec frozen; cannot declare {key}")
            slot = DeviceSlot(key, self._size, width)
            self._slots[key] = slot
            self._order.append(slot)
            self._size += width
            return slot

    def freeze(self) -> "DeviceFoldSpec":
        self._frozen = True
        return self

    @property
    def size(self) -> int:
        return max(self._size, 1)

    def slots(self) -> List[DeviceSlot]:
        return list(self._order)

    # -- in-graph ops -------------------------------------------------------
    def init_table(self, dtype=jnp.float32) -> jnp.ndarray:
        return jnp.zeros((self.size,), dtype=dtype)

    def emit(self, table: jnp.ndarray, caller: str, component: str, api: str,
             metric: str, value) -> jnp.ndarray:
        """Fold `value` (scalar or [width] vector) into its slot. Trace-time
        key resolution; runtime is one dynamic_update_slice-add."""
        key = (caller, component, api, metric)
        slot = self._slots.get(key)
        if slot is None:
            raise KeyError(f"device fold slot not declared: {key}")
        v = jnp.asarray(value, dtype=table.dtype).reshape((-1,))
        if v.shape[0] != slot.width:
            raise ValueError(f"slot {key} width {slot.width}, got {v.shape[0]}")
        seg = jax.lax.dynamic_slice(table, (slot.offset,), (slot.width,))
        return jax.lax.dynamic_update_slice(table, seg + v, (slot.offset,))

    def read(self, table: jnp.ndarray, caller: str, component: str, api: str,
             metric: str) -> jnp.ndarray:
        key = (caller, component, api, metric)
        slot = self._slots[key]
        return jax.lax.dynamic_slice(table, (slot.offset,), (slot.width,))

    # -- host-side fold -----------------------------------------------------
    def fold(self, table_np: np.ndarray, group: str = "device") -> FoldedTable:
        """Convert a fetched fold vector into a FoldedTable whose edges carry
        the metrics; vector slots expand to metric[i] entries."""
        table_np = np.asarray(table_np, dtype=np.float64)
        edges: Dict[SlotKey, EdgeStats] = {}
        for slot in self._order:
            caller, component, api, metric = slot.key
            ekey: SlotKey = (caller, component, api)
            e = edges.get(ekey)
            if e is None:
                e = edges[ekey] = EdgeStats(kind=KIND_CALL)
            span = table_np[slot.offset: slot.offset + slot.width]
            if slot.width == 1:
                e.metrics[metric] = e.metrics.get(metric, 0.0) + float(span[0])
            else:
                for i, v in enumerate(span):
                    k = f"{metric}[{i}]"
                    e.metrics[k] = e.metrics.get(k, 0.0) + float(v)
            if metric == "count":
                e.count += int(round(float(span.sum())))
        return FoldedTable(edges, group=group)


# ---------------------------------------------------------------------------
# Static trace-time costs: the zero-overhead fold. Model code calls
# annotate_cost while being traced; the registry accumulates per-step analytic
# FLOPs/bytes per edge. One trace == one step's worth of applications, so the
# multiplicity is exact without any runtime representation.
# ---------------------------------------------------------------------------


@dataclass
class StaticCostRegistry:
    costs: Dict[SlotKey, Dict[str, float]] = field(default_factory=dict)
    #: multiplier stack: inside scan-over-layers the body traces ONCE but
    #: executes `length` times — scopes push the scan length so analytic
    #: costs keep their true per-step multiplicity.
    _mult_stack: List[float] = field(default_factory=lambda: [1.0])

    def push_multiplier(self, m: float) -> None:
        self._mult_stack.append(self._mult_stack[-1] * m)

    def pop_multiplier(self) -> None:
        self._mult_stack.pop()

    @property
    def multiplier(self) -> float:
        return self._mult_stack[-1]

    def annotate(self, caller: str, component: str, api: str,
                 **metrics: float) -> None:
        key = (caller, component, api)
        d = self.costs.setdefault(key, {})
        m = self.multiplier
        for name, v in metrics.items():
            d[name] = d.get(name, 0.0) + float(v) * m
        d["count"] = d.get("count", 0.0) + m

    def reset(self) -> None:
        self.costs.clear()
        self._mult_stack[:] = [1.0]

    def as_folded(self, group: str = "static") -> FoldedTable:
        edges: Dict[SlotKey, EdgeStats] = {}
        for key, metrics in self.costs.items():
            e = EdgeStats(kind=KIND_CALL, metrics=dict(metrics))
            e.count = int(round(metrics.get("count", 0.0)))
            edges[key] = e
        return FoldedTable(edges, group=group)


STATIC_COSTS = StaticCostRegistry()


class scan_multiplier:
    """Context manager: wrap the TRACING of a scanned body so static costs
    registered inside are multiplied by the scan length."""

    def __init__(self, length: float, registry: Optional[StaticCostRegistry] = None):
        self.length = float(length)
        self.registry = registry or STATIC_COSTS

    def __enter__(self):
        self.registry.push_multiplier(self.length)
        return self

    def __exit__(self, *exc):
        self.registry.pop_multiplier()
        return False


def annotate_cost(caller: str, component: str, api: str, **metrics: float) -> None:
    STATIC_COSTS.annotate(caller, component, api, **metrics)
