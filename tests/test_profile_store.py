"""Profile store subsystem: snapshot round-trip, columnar merge laws,
cross-process shard aggregation through the CLI, and diff regression flags."""

import json
import subprocess
import sys

import numpy as np
import pytest

from conftest import assert_tables_equal
from repro.core.folding import (EdgeColumns, EdgeStats, FoldedTable,
                                fold_event_log, merge_columns)
from repro.core.histogram import hist_of
from repro.profile import (ProfileSnapshot, ProfileStore, diff_profiles,
                           load_profile)
from repro.profile.__main__ import main as profile_cli
from repro.profile.snapshot import SCHEMA_VERSION

EVENTS = [
    ("app", "glibc", "read", 18), ("app", "glibc", "write", 35),
    ("app", "alloc", "malloc", 10), ("glibc", "alloc", "malloc", 2),
    ("moe", "glibc", "read", 7), ("app", "glibc", "read", 4),
    ("optimizer", "alloc", "free", 1), ("moe", "pthread", "lock", 900),
]


def rich_table() -> FoldedTable:
    """A table exercising every field: kinds, metrics, count-0 edges,
    the min_ns sentinel, child_ns."""
    t = fold_event_log(EVENTS)
    t.edges[("app", "glibc", "read")].child_ns = 5
    t.edges[("moe", "pthread", "lock")].kind = 1  # KIND_WAIT
    t.edges[("app", "alloc", "malloc")].metrics = {"bytes": 4096.0,
                                                   "load[0]": 1.0}
    # device/static-style edge: metrics only, never timed
    t.edges[("app", "moe", "dispatch")] = EdgeStats(
        metrics={"flops": 1e9, "bytes": 0.0})
    t.group = "proc0"
    return t


# ------------------------------------------------------------- snapshot ----
class TestSnapshot:
    def test_roundtrip_lossless(self, tmp_path):
        t = rich_table()
        p = str(tmp_path / "t.xfa.npz")
        ProfileSnapshot.from_folded(t, meta={"label": "x"}).save(p)
        snap = ProfileSnapshot.load(p)
        assert snap.meta["label"] == "x"
        # hist-less content serializes as the minimal schema (v1 bytes)
        assert snap.schema == 1
        back = snap.to_folded()
        assert back.group == "proc0"
        assert_tables_equal(back, t)
        # metric PRESENCE survives: bytes=0.0 stays recorded, absent metrics
        # stay absent
        e = back.edges[("app", "moe", "dispatch")]
        assert e.metrics == {"flops": 1e9, "bytes": 0.0}
        assert back.edges[("moe", "pthread", "lock")].metrics == {}
        # a histogram column promotes the written schema to v2 (minimal
        # schema that represents the content)...
        t.edges[("app", "glibc", "read")].hist = hist_of([18, 4])
        ProfileSnapshot.from_folded(t, meta={"label": "x"}).save(p)
        snap2 = ProfileSnapshot.load(p)
        assert snap2.schema == 2
        assert_tables_equal(snap2.to_folded(), t)
        # ...and a governor sampling rate promotes it to the current one
        t.edges[("app", "glibc", "read")].sample_rate = 0.25
        ProfileSnapshot.from_folded(t, meta={"label": "x"}).save(p)
        snap3 = ProfileSnapshot.load(p)
        assert snap3.schema == SCHEMA_VERSION
        assert_tables_equal(snap3.to_folded(), t)

    def test_empty_roundtrip(self, tmp_path):
        p = str(tmp_path / "e.xfa.npz")
        ProfileSnapshot.from_folded(FoldedTable()).save(p)
        assert len(ProfileSnapshot.load(p).to_folded()) == 0

    def test_rejects_newer_schema(self, tmp_path):
        t = fold_event_log(EVENTS[:2])
        p = str(tmp_path / "t.xfa.npz")
        ProfileSnapshot.from_folded(t).save(p)
        # the writer derives the schema from content (minimal-schema rule),
        # so forge the header bytes to fake a future version
        with np.load(p, allow_pickle=False) as z:
            members = {k: z[k] for k in z.files}
        header = json.loads(bytes(members["__header__"]).decode("utf-8"))
        header["schema"] = SCHEMA_VERSION + 1
        members["__header__"] = np.frombuffer(
            json.dumps(header).encode("utf-8"), dtype=np.uint8)
        np.savez(p, **members)
        with pytest.raises(ValueError, match="schema"):
            ProfileSnapshot.load(p)

    def test_rejects_non_snapshot(self, tmp_path):
        p = str(tmp_path / "junk.npz")
        np.savez(p, a=np.zeros(3))
        with pytest.raises(ValueError, match="not an XFA profile"):
            ProfileSnapshot.load(p)


# ------------------------------------------------------ columnar merge ----
class TestColumnarMerge:
    def _random_tables(self, n, seed):
        rng = np.random.default_rng(seed)
        tables = []
        for g in range(n):
            evs = [(f"c{rng.integers(3)}", f"m{rng.integers(4)}",
                    f"a{rng.integers(5)}", int(rng.integers(1, 1000)))
                   for _ in range(int(rng.integers(0, 60)))]
            t = fold_event_log(evs)
            t.group = f"p{g}"
            for k in list(t.edges)[::3]:
                t.edges[k].metrics = {"flops": float(rng.integers(1, 100))}
            tables.append(t)
        return tables

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_matches_pairwise_oracle(self, seed):
        tables = self._random_tables(5, seed)
        want = FoldedTable.merge_all(tables)
        got = FoldedTable.merge_all_columnar(tables)
        assert_tables_equal(got, want)

    def test_commutative_and_associative(self):
        a, b, c = (t.to_columns() for t in self._random_tables(3, 7))
        left = merge_columns([merge_columns([a, b]), c]).to_folded()
        right = merge_columns([a, merge_columns([b, c])]).to_folded()
        flipped = merge_columns([c, a, b]).to_folded()
        assert_tables_equal(left, right)
        assert_tables_equal(left, flipped)

    def test_empty_identity(self):
        t = rich_table()
        merged = merge_columns([t.to_columns(),
                                EdgeColumns.empty()]).to_folded()
        assert_tables_equal(merged, t)


# ----------------------------------------------------------------- store ----
class TestStore:
    def test_shard_overwrite_is_cumulative_refresh(self, tmp_path):
        store = ProfileStore(str(tmp_path))
        store.write_shard(fold_event_log(EVENTS[:3]), label="train")
        store.write_shard(fold_event_log(EVENTS), label="train")
        assert len(store) == 1  # same process+label refreshes in place
        assert_tables_equal(store.reduce().to_folded(),
                            fold_event_log(EVENTS))

    def test_reduce_empty_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            ProfileStore(str(tmp_path)).reduce()

    def test_reduce_warns_on_stale_same_writer_shards(self, tmp_path):
        """Two shards with the same (label, host) but different pids are a
        stale previous run — reduce sums them, so it must warn."""
        store = ProfileStore(str(tmp_path))
        snap = ProfileSnapshot.from_folded(
            fold_event_log(EVENTS[:2]),
            meta={"label": "train", "host": "h", "pid": 1})
        snap.save(str(tmp_path / "train-h-1.xfa.npz"))
        snap.meta["pid"] = 2
        snap.save(str(tmp_path / "train-h-2.xfa.npz"))
        with pytest.warns(UserWarning, match="SUMS them"):
            merged = store.reduce()
        assert merged.to_folded().edges[("app", "glibc", "read")].count == 2

    def test_reduce_ignores_merged_snapshot_in_dir(self, tmp_path):
        """`merge RUN_DIR -o RUN_DIR/merged.xfa.npz` must not double-count
        on the next reduce."""
        store = ProfileStore(str(tmp_path))
        store.write_shard(fold_event_log(EVENTS), label="p0")
        assert profile_cli(["merge", str(tmp_path), "-o",
                            str(tmp_path / "merged.xfa.npz")]) == 0
        with pytest.warns(UserWarning, match="already-merged"):
            merged = store.reduce()
        assert_tables_equal(merged.to_folded(), fold_event_log(EVENTS))

    def test_load_profile_json_compat(self, tmp_path):
        t = fold_event_log(EVENTS)
        p = str(tmp_path / "legacy.json")
        t.dump(p)
        assert_tables_equal(load_profile(p).to_folded(), t)


# -------------------------------------------------- cross-process merge ----
WRITER = """
import sys, json
from repro.core.folding import fold_event_log
from repro.profile import ProfileStore

events = [tuple(e) for e in json.loads(sys.argv[1])]
store = ProfileStore(sys.argv[2])
store.write_shard(fold_event_log(events), label=sys.argv[3])
print("wrote", store.shard_paths())
"""


class TestCrossProcess:
    def test_two_process_shards_merge_to_single_process_profile(self, tmp_path):
        """The acceptance path: two separate OS processes each fold half of
        the work and write shards; the CLI merges them into a profile whose
        per-edge stats are identical to one process folding everything."""
        shard_dir = str(tmp_path / "shards")
        half = len(EVENTS) // 2
        for label, chunk in (("p0", EVENTS[:half]), ("p1", EVENTS[half:])):
            proc = subprocess.run(
                [sys.executable, "-c", WRITER, json.dumps(chunk),
                 shard_dir, label],
                capture_output=True, text=True, timeout=120)
            assert proc.returncode == 0, proc.stderr
        assert len(ProfileStore(shard_dir)) == 2

        merged_path = str(tmp_path / "merged.xfa.npz")
        assert profile_cli(["merge", shard_dir, "-o", merged_path]) == 0
        merged = ProfileSnapshot.load(merged_path).to_folded()
        assert_tables_equal(merged, fold_event_log(EVENTS))

    def test_report_renders_merged_views(self, tmp_path, capsys):
        shard_dir = str(tmp_path / "shards")
        store = ProfileStore(shard_dir)
        store.write_shard(fold_event_log(EVENTS), label="r0")
        assert profile_cli(["report", shard_dir]) == 0
        out = capsys.readouterr().out
        assert "Component view: app" in out
        assert "Flow matrix" in out


# ------------------------------------------------------------------ diff ----
class TestDiff:
    def test_flags_injected_slowdown(self, tmp_path):
        base = fold_event_log(EVENTS)
        slow = fold_event_log(EVENTS)
        e = slow.edges[("app", "glibc", "write")]
        e.total_ns *= 3  # injected 3x regression on one edge
        d = diff_profiles(base, slow, threshold=0.5)
        assert d.has_regressions
        assert [r.key for r in d.regressions] == [("app", "glibc", "write")]
        assert "total_ns" in d.regressions[0].flagged
        assert "count" not in d.regressions[0].flagged
        assert "REG" in d.render()

    def test_below_threshold_is_clean(self):
        base = fold_event_log(EVENTS)
        d = diff_profiles(base, base, threshold=0.25)
        assert not d.has_regressions
        assert d.unchanged == len(base)

    def test_added_and_removed_edges(self):
        base = fold_event_log(EVENTS[:4])
        cand = fold_event_log(EVENTS[2:])
        d = diff_profiles(base, cand, threshold=0.25)
        added = {x.key for x in d.added}
        removed = {x.key for x in d.removed}
        assert ("moe", "pthread", "lock") in added
        assert ("app", "glibc", "write") in removed
        # new edges fail the gate by default (a rename could hide a hot
        # edge otherwise) but can be waived
        assert d.has_regressions
        d2 = diff_profiles(base, cand, threshold=0.25, flag_added=False)
        assert not d2.has_regressions

    def test_cli_diff_exit_codes(self, tmp_path, capsys):
        base = fold_event_log(EVENTS)
        slow = fold_event_log(EVENTS)
        slow.edges[("app", "glibc", "write")].total_ns *= 3
        pb = str(tmp_path / "base.xfa.npz")
        pc = str(tmp_path / "cand.xfa.npz")
        ProfileSnapshot.from_folded(base).save(pb)
        ProfileSnapshot.from_folded(slow).save(pc)
        assert profile_cli(["diff", pb, pb, "--threshold", "0.5"]) == 0
        assert profile_cli(["diff", pb, pc, "--threshold", "0.5"]) == 1
        capsys.readouterr()
        assert profile_cli(["diff", pb, pc, "--threshold", "0.5",
                            "--json"]) == 1
        payload = json.loads(capsys.readouterr().out)
        flagged = payload["regressions"][0]
        assert (flagged["caller"], flagged["component"],
                flagged["api"]) == ("app", "glibc", "write")


# --------------------------------------------------------------- session ----
class TestSessionSnapshot:
    def test_session_snapshot_includes_host_folds(self, tmp_path):
        from repro.core.session import XFASession
        from repro.core.tracer import Tracer

        t = Tracer()

        @t.api("data")
        def load():
            return 1

        load()
        load()
        sess = XFASession(tracer=t)
        p = sess.snapshot(str(tmp_path / "s.xfa.npz"), meta={"label": "s"})
        snap = ProfileSnapshot.load(p)
        folded = snap.to_folded()
        assert folded.edges[("app", "data", "load")].count == 2
        assert snap.meta["label"] == "s"
