"""repro.profile — persistence + cross-process aggregation for XFA profiles.

Scaler merges per-thread shadow tables *offline* (§3.3–3.4); this package
lifts that design one level: per-*process* profiles are persisted as columnar
snapshot shards and reduced offline, so profiles survive process exit and can
be aggregated across hosts, serving replicas, and runs.

  snapshot.py   schema-versioned columnar serialization (npz arrays + json
                slot metadata) of a FoldedTable — lossless round-trip
  store.py      a directory of per-process shards + the N-way reducer
  diff.py       run-over-run comparison with per-edge regression flagging
  __main__.py   CLI: python -m repro.profile {report,merge,diff}

The merge itself is the vectorized column algebra in core/folding.py
(merge_columns): registry re-interning + whole-column numpy scatter-adds,
not per-edge EdgeStats dict loops (benchmarks/merge.py measures the gap).
"""

from .snapshot import SCHEMA_VERSION, SNAPSHOT_SUFFIX, ProfileSnapshot
from .store import ProfileStore, load_profile, tracer_folded
from .diff import EdgeDelta, ProfileDiff, diff_profiles

__all__ = [
    "SCHEMA_VERSION", "SNAPSHOT_SUFFIX", "ProfileSnapshot",
    "ProfileStore", "load_profile", "tracer_folded",
    "EdgeDelta", "ProfileDiff", "diff_profiles",
]
