"""Profile store — a run directory of per-process snapshot rings + reducer.

The paper persists one file per *thread* at thread exit and merges offline;
a ProfileStore is the per-*process* analogue for fleets: every process (one
trainer rank, one serving replica, one host of a mesh) owns a shard named
after (label, host, pid).  Since v2 a shard is not a single atomically
-replaced file but a bounded ring of *sequence-numbered* snapshots

    <label>-<host>-<pid>.000001.xfa.npz
    <label>-<host>-<pid>.000002.xfa.npz ...

written on each periodic refresh.  Folds are cumulative, so the NEWEST
snapshot of a shard supersedes the older ones for aggregation (reduce /
report / merge all use only the newest per shard), while the older ring
entries are the shard's *time series* — `python -m repro.profile timeline`
renders per-edge trajectories across them, which is how drift inside one
run becomes visible (ScalAna's per-run performance-graph argument).

The ring is bounded by a RetentionPolicy (keep-last-N per shard, max-age,
max-bytes per run dir) enforced in the writer on every refresh and offline
via `python -m repro.profile gc`.  The newest snapshot of a shard is never
deleted — a live shard always keeps its latest cumulative fold.

Legacy v1 shards (`<label>-<host>-<pid>.xfa.npz`, no sequence number) load
as sequence 0 of their shard, so old run dirs keep reducing.
"""

from __future__ import annotations

import glob
import os
import re
import socket
import time
import warnings
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from ..core.folding import FoldedTable
from .snapshot import SNAPSHOT_SUFFIX, ProfileSnapshot

#: sequence-numbered ring entry: <stem>.<seq:06d>.xfa.npz
_SEQ_RE = re.compile(r"^(?P<stem>.+)\.(?P<seq>\d{6})$")

#: manifest filename (canonically index.MANIFEST_NAME; repeated here as a
#: literal because index imports store — host_label — and a module cycle
#: is worse than one duplicated constant)
_MANIFEST_NAME = "manifest.json"

#: process-wide host identity override (``--xfa-host-label``): shard
#: stems, snapshot/writer metadata and the fleet transport all derive
#: the host from here, so tests and containers with meaningless
#: hostnames can give every publisher a distinct, stable identity.
_HOST_LABEL: Optional[str] = None


def set_host_label(label: Optional[str]) -> None:
    """Override the host identity recorded by every profile writer in
    this process (None restores `socket.gethostname()`)."""
    global _HOST_LABEL
    _HOST_LABEL = label or None


def host_label() -> str:
    """The host identity profile writers record (override or hostname)."""
    return _HOST_LABEL or socket.gethostname()


def split_snapshot_name(path: str) -> Tuple[str, int]:
    """(shard stem, sequence number) of a snapshot path; legacy un-numbered
    snapshots are sequence 0 of their stem."""
    name = os.path.basename(path)
    if name.endswith(SNAPSHOT_SUFFIX):
        name = name[: -len(SNAPSHOT_SUFFIX)]
    m = _SEQ_RE.match(name)
    if m:
        return m.group("stem"), int(m.group("seq"))
    return name, 0


def snapshot_name(stem: str, seq: int) -> str:
    return f"{stem}.{seq:06d}{SNAPSHOT_SUFFIX}"


def ring_entries(root: str) -> List[Tuple[str, int, str]]:
    """Every ring entry under a run dir as (qualified stem, seq, path).

    A run dir is either flat (each writer's ring directly inside) or the
    collector's spool layout with one subdirectory per HOST
    (`<run>/<host>/<shard>.<seq>.xfa.npz`, docs/fleet.md).  Subdir
    entries get host-qualified stems (`<host>/<shard>`) so two hosts'
    same-named rings never alias in reduce/shard_graphs/timeline.  A
    subdirectory carrying its own manifest is its OWN run dir (a nested
    registry), not a host of this one, and is skipped.
    """
    out = []
    for p in glob.glob(os.path.join(root, f"*{SNAPSHOT_SUFFIX}")):
        stem, seq = split_snapshot_name(p)
        out.append((stem, seq, p))
    for p in glob.glob(os.path.join(root, "*", f"*{SNAPSHOT_SUFFIX}")):
        sub = os.path.dirname(p)
        if os.path.exists(os.path.join(sub, _MANIFEST_NAME)):
            continue
        stem, seq = split_snapshot_name(p)
        out.append((f"{os.path.basename(sub)}/{stem}", seq, p))
    out.sort()
    return out


def tracer_folded(tracer=None) -> FoldedTable:
    """Merge every per-thread shadow table of `tracer` (default: the process
    tracer) into one raw FoldedTable — the process's current host-layer fold."""
    if tracer is None:
        from ..core import tracer as xfa
        tracer = xfa.TRACER
    return FoldedTable.merge_all(
        FoldedTable.from_set(tracer.tables, rates=tracer.sample_rates()))


@dataclass(frozen=True)
class RetentionPolicy:
    """Bounded-footprint rules for one run directory.

    keep_last   ring length per shard (0: unbounded)
    max_age_s   delete snapshots older than this (0: unbounded)
    max_bytes   total snapshot bytes per run dir; oldest-first eviction
                across shards until under budget (0: unbounded)

    Whatever the rule, the newest snapshot of every shard survives: a live
    shard's latest cumulative fold is the one file aggregation needs.
    """

    keep_last: int = 8
    max_age_s: float = 0.0
    max_bytes: int = 0

    @property
    def unbounded(self) -> bool:
        return not (self.keep_last or self.max_age_s or self.max_bytes)

    def doomed(self, root: str, now: Optional[float] = None) -> List[str]:
        """Paths under `root` this policy would delete (oldest-first)."""
        if self.unbounded:
            return []
        now = time.time() if now is None else now
        entries = []  # (stem, seq, path, size, mtime); stems are host-
        # qualified in the collector's spool layout, so keep-last applies
        # per (host, shard) ring, exactly as the publishers wrote them
        for stem, seq, p in ring_entries(root):
            try:
                st = os.stat(p)
            except FileNotFoundError:      # concurrent writer GC'd it
                continue
            entries.append((stem, seq, p, st.st_size, st.st_mtime))
        newest = {}  # stem -> max seq
        for stem, seq, *_ in entries:
            newest[stem] = max(newest.get(stem, -1), seq)
        protected = {p for stem, seq, p, *_ in entries
                     if seq == newest[stem]}
        doomed: Dict[str, float] = {}  # path -> mtime (dict keeps order out)
        by_stem: Dict[str, List] = {}
        for e in entries:
            by_stem.setdefault(e[0], []).append(e)
        for stem, es in by_stem.items():
            es.sort(key=lambda e: (-e[1], -e[4]))  # newest first
            if self.keep_last:
                for e in es[self.keep_last:]:
                    if e[2] not in protected:
                        doomed[e[2]] = e[4]
            if self.max_age_s:
                for e in es:
                    if now - e[4] > self.max_age_s and e[2] not in protected:
                        doomed[e[2]] = e[4]
        if self.max_bytes:
            live = [e for e in entries if e[2] not in doomed]
            total = sum(e[3] for e in live)
            # oldest first: (mtime, seq) — never the newest of a stem
            for e in sorted(live, key=lambda e: (e[4], e[1])):
                if total <= self.max_bytes:
                    break
                if e[2] in protected:
                    continue
                doomed[e[2]] = e[4]
                total -= e[3]
        return sorted(doomed, key=doomed.get)

    def enforce(self, root: str, now: Optional[float] = None,
                dry_run: bool = False) -> List[str]:
        """Delete (or with dry_run just report) out-of-policy snapshots."""
        victims = self.doomed(root, now=now)
        if not dry_run:
            for p in victims:
                try:
                    os.unlink(p)
                except FileNotFoundError:  # lost a race with another writer
                    pass
        return victims


class ProfileStore:
    """One run directory: per-process shard rings; anyone can reduce."""

    def __init__(self, root: str,
                 retention: Optional[RetentionPolicy] = None) -> None:
        # NO makedirs here: readers (query -v, timeline, reduce) construct
        # stores too, and a typo'd path must error, not leave empty dirs
        # behind to pollute later registry scans.  write_shard creates it.
        self.root = root
        self.retention = RetentionPolicy() if retention is None else retention

    # -- writer side --------------------------------------------------------
    def shard_stem(self, label: str = "shard") -> str:
        host = host_label().split(".")[0]
        return f"{label}-{host}-{os.getpid()}"

    def next_seq(self, stem: str) -> int:
        seqs = [seq for s, seq, _ in ring_entries(self.root) if s == stem]
        return max(seqs, default=0) + 1

    def write_shard(self, folded: FoldedTable, label: str = "shard",
                    meta: Optional[Dict[str, Any]] = None) -> str:
        """Append the next ring snapshot for this process's shard and
        enforce retention.  Folds are cumulative: each snapshot holds the
        whole run so far, so the newest alone is enough to aggregate and
        consecutive snapshots difference into per-interval activity."""
        os.makedirs(self.root, exist_ok=True)
        stem = self.shard_stem(label)
        seq = self.next_seq(stem)
        shard_meta: Dict[str, Any] = {
            "label": label,
            "host": host_label(),
            "pid": os.getpid(),
            "seq": seq,
            "written_at": time.time(),
        }
        shard_meta.update(meta or {})
        snap = ProfileSnapshot.from_folded(folded, meta=shard_meta)
        path = snap.save(os.path.join(self.root, snapshot_name(stem, seq)))
        self.retention.enforce(self.root)
        return path

    # -- reader side ----------------------------------------------------------
    def snapshot_paths(self) -> List[str]:
        """Every ring entry of every shard in this run dir (including the
        per-host subdirectories of a collector spool run)."""
        return sorted(p for _stem, _seq, p in ring_entries(self.root))

    def shards(self) -> Dict[str, List[Tuple[int, str]]]:
        """stem -> [(seq, path), ...] ascending — each shard's time series.
        Stems of spool-layout entries are host-qualified (`host/shard`)."""
        out: Dict[str, List[Tuple[int, str]]] = {}
        for stem, seq, p in ring_entries(self.root):
            out.setdefault(stem, []).append((seq, p))
        for ring in out.values():
            ring.sort()
        return out

    def shard_paths(self) -> List[str]:
        """The NEWEST snapshot of each shard — what aggregation consumes
        (cumulative folds: the latest ring entry supersedes the others)."""
        return sorted(ring[-1][1] for ring in self.shards().values())

    def load_shards(self) -> List[ProfileSnapshot]:
        """Load newest-per-shard snapshots, EXCLUDING merged outputs:
        `merge -o` into the shard dir must not make the next reduce count
        everything twice."""
        shards = []
        skipped = []
        for p in self.shard_paths():
            snap = ProfileSnapshot.load(p)
            if "merged_from" in snap.meta:
                skipped.append(os.path.basename(p))
            else:
                shards.append(snap)
        if skipped:
            warnings.warn(
                f"profile dir {self.root!r}: ignoring already-merged "
                f"snapshot(s) {skipped} when reducing shards", stacklevel=2)
        return shards

    def reduce(self, meta: Optional[Dict[str, Any]] = None) -> ProfileSnapshot:
        shards = self.load_shards()
        if not shards:
            raise FileNotFoundError(f"no profile shards under {self.root!r}")
        # two shards with the same (label, host) but different pids are
        # either a stale shard from a previous run (double-counts every
        # edge) or replicas sharing a label — either way worth surfacing
        by_writer: Dict[Tuple[str, str], int] = {}
        for s in shards:
            k = (str(s.meta.get("label", "?")), str(s.meta.get("host", "?")))
            by_writer[k] = by_writer.get(k, 0) + 1
        dups = [k for k, n in by_writer.items() if n > 1]
        if dups:
            warnings.warn(
                f"profile dir {self.root!r} holds multiple shards with the "
                f"same (label, host) {dups}; the reduce SUMS them. If these "
                "are stale shards from a previous run, use a fresh "
                "--profile-dir per run; if they are concurrent replicas, "
                "give each a distinct label (e.g. --profile-label serve-0)",
                stacklevel=2)
        if len(shards) == 1 and not meta:
            return shards[0]
        return ProfileSnapshot.merge(shards, meta=meta)

    def __len__(self) -> int:
        return len(self.shard_paths())


def find_run_dirs(root: str) -> List[str]:
    """Directories under `root` (inclusive) holding profile snapshots —
    the unit `gc` applies a RetentionPolicy to.  A directory whose
    PARENT carries a manifest is a per-host subdirectory of a collector
    spool run (docs/fleet.md), not a run of its own: it collapses into
    the parent so retention sees the whole run (host-qualified rings,
    one byte budget) exactly as the reducer does."""
    dirs = set()
    for p in glob.glob(os.path.join(root, "**", f"*{SNAPSHOT_SUFFIX}"),
                       recursive=True):
        d = os.path.dirname(p)
        parent = os.path.dirname(d)
        if not os.path.exists(os.path.join(d, _MANIFEST_NAME)) and \
                os.path.exists(os.path.join(parent, _MANIFEST_NAME)):
            d = parent
        dirs.add(d)
    return sorted(dirs)


def load_profile(path: str) -> ProfileSnapshot:
    """Load a profile from a snapshot file, a shard directory (reduced), or
    a legacy FoldedTable json dump."""
    if os.path.isdir(path):
        return ProfileStore(path).reduce()
    if path.endswith(".json"):
        return ProfileSnapshot.from_folded(FoldedTable.load(path),
                                           meta={"label": path})
    return ProfileSnapshot.load(path)
