#!/usr/bin/env python
"""Fail CI when docs/cli.md falls behind the actual CLI surface.

Enumerates every subcommand and every long option of
`python -m repro.profile` straight from the argparse tree
(repro.profile.__main__.build_parser — no subprocess, no help-text
scraping) and requires each to appear verbatim in docs/cli.md:

  * each subcommand name must appear as an inline-code token,
    e.g. `report` (backticked, so prose mentions don't count);
  * each long flag string (e.g. --thresholds) must appear anywhere
    in the file — flag tables and worked examples both satisfy it.

Exit 0 when the docs cover everything, 1 with a list of the missing
tokens otherwise, 2 when docs/cli.md itself is missing.  Run from the
repo root (CI does); PYTHONPATH=src is set up by the script itself so
`python tools/check_cli_docs.py` works standalone.
"""

from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

DOC = os.path.join(REPO, "docs", "cli.md")

# argparse-generated noise not worth documenting per subcommand
IGNORED_FLAGS = {"--help"}


def cli_surface():
    """(subcommands, {subcommand: sorted long flags}) from the parser."""
    from repro.profile.__main__ import build_parser
    ap = build_parser()
    subs = next(a for a in ap._actions
                if isinstance(a, argparse._SubParsersAction))
    flags = {}
    for name, sp in subs.choices.items():
        longs = set()
        for act in sp._actions:
            longs.update(s for s in act.option_strings
                         if s.startswith("--") and s not in IGNORED_FLAGS)
        flags[name] = sorted(longs)
    return sorted(subs.choices), flags


def main() -> int:
    if not os.path.exists(DOC):
        print(f"check_cli_docs: {DOC} does not exist", file=sys.stderr)
        return 2
    text = open(DOC).read()
    code_tokens = set(re.findall(r"`([^`]+)`", text))
    subcommands, flags = cli_surface()
    missing = []
    for cmd in subcommands:
        # the subcommand must be named as an inline-code token (alone or
        # inside a backticked invocation like `python -m repro.profile gc`)
        if not any(re.search(rf"(^|[\s.]){re.escape(cmd)}($|\s)", tok)
                   for tok in code_tokens):
            missing.append(f"subcommand `{cmd}`")
        for flag in flags[cmd]:
            if flag not in text:
                missing.append(f"{cmd} flag {flag}")
    if missing:
        print(f"check_cli_docs: docs/cli.md is missing {len(missing)} "
              f"item(s):", file=sys.stderr)
        for m in missing:
            print(f"  - {m}", file=sys.stderr)
        return 1
    n_flags = sum(len(v) for v in flags.values())
    print(f"docs/cli.md covers all {len(subcommands)} subcommands "
          f"and {n_flags} flags")
    return 0


if __name__ == "__main__":
    sys.exit(main())
