"""repro.profile — persistence, indexing + cross-process aggregation of XFA
profiles.

Scaler merges per-thread shadow tables *offline* (§3.3–3.4); this package
lifts that design one level: per-*process* profiles are persisted as columnar
snapshot shards and reduced offline, so profiles survive process exit and can
be aggregated across hosts, serving replicas, and runs — and since v2 the
store is a *run registry*: bounded time-series snapshot rings per shard, a
retention/GC policy, and metadata manifests that make whole fleets of runs
queryable ("all runs of arch X on mesh Y").

  snapshot.py   schema-versioned columnar serialization (npz arrays + json
                slot metadata) of a FoldedTable — lossless, byte-stable
  store.py      run dir of per-process snapshot *rings* (sequence-numbered),
                the N-way reducer, and RetentionPolicy (keep-last / max-age
                / max-bytes, enforced in-writer and via `gc`)
  index.py      run manifests + RunRegistry.query (metadata predicates)
  timeline.py   per-edge count/total_ns/self_ns trajectories across a
                shard's ring — the in-run drift view; TimelineDiff aligns
                two runs' rings by sequence index for per-edge
                delta-of-deltas (`timeline RUN_A --diff RUN_B`)
  diff.py       run-over-run comparison with per-edge regression flagging
                (global threshold, or calibrated per-edge noise bands)
  transport.py  framed TCP wire protocol (length-prefixed json header +
                payload) and FleetPublisher — ships snapshot-ring deltas
                to a collector, resuming from its acked (shard, seq)
                state; publish failures degrade to local-only rings
  collector.py  threaded collector daemon + spool layout
                (SPOOL/<run_id>/<host>/<shard>.seq<N>.xfa.npz) behind
                `python -m repro.profile collect`
  __main__.py   CLI: python -m repro.profile
                {report,merge,diff,query,gc,timeline,calibrate,diagnose,
                 collect}

Interpretation of all of this — the typed Cross Flow Graph, the detector
suite behind `diagnose`, and the noise-band calibration behind
`calibrate`/`diff --thresholds` — lives one package over, in
repro.analysis.

The merge itself is the vectorized column algebra in core/folding.py
(merge_columns): registry re-interning + whole-column numpy scatter-adds,
not per-edge EdgeStats dict loops (benchmarks/merge.py measures the gap).
"""

from .snapshot import SCHEMA_VERSION, SNAPSHOT_SUFFIX, ProfileSnapshot
from .store import (ProfileStore, RetentionPolicy, find_run_dirs,
                    host_label, load_profile, ring_entries, set_host_label,
                    split_snapshot_name, tracer_folded)
from .index import (MANIFEST_NAME, RunManifest, RunRegistry, kv_pair,
                    parse_mesh, register_run)
from .timeline import (ShardTimeline, TimelineDiff, build_timelines,
                       pair_timelines, render_timeline, render_timeline_diff)
from .diff import EdgeDelta, ProfileDiff, diff_profiles
from .transport import (PROTO_VERSION, Disconnect, FleetPublisher,
                        FrameError, frame_checksum, parse_addr, recv_frame,
                        send_frame)
from .collector import Collector, collect_main

__all__ = [
    "SCHEMA_VERSION", "SNAPSHOT_SUFFIX", "ProfileSnapshot",
    "ProfileStore", "RetentionPolicy", "find_run_dirs", "host_label",
    "load_profile", "ring_entries", "set_host_label",
    "split_snapshot_name", "tracer_folded",
    "MANIFEST_NAME", "RunManifest", "RunRegistry", "kv_pair", "parse_mesh",
    "register_run",
    "ShardTimeline", "TimelineDiff", "build_timelines", "pair_timelines",
    "render_timeline", "render_timeline_diff",
    "EdgeDelta", "ProfileDiff", "diff_profiles",
    "PROTO_VERSION", "Disconnect", "FleetPublisher", "FrameError",
    "frame_checksum", "parse_addr", "recv_frame", "send_frame",
    "Collector", "collect_main",
]
