"""Serving throughput + TTFT benchmark on a tiny config (CPU-lane safe).

Drives the continuous-batching engine — open-loop Poisson arrivals on
the background serving thread by default (TTFT and queue wait are only
meaningful under an arrival process), or closed-loop with --mode closed
— and emits name,value CSV rows like the other benchmarks:

  serve.requests / serve.tokens / serve.wall_s
  serve.throughput_tok_s
  serve.ttft_mean_ms / serve.ttft_p95_ms
  serve.queue_wait_mean_ms
  serve.decode_ms_per_tok

With --profile-dir the run registers in the run registry (kind=serve)
and writes its XFA shard there, so

  python -m repro.profile query DIR --kind serve
  python -m repro.profile report DIR --component serve

work against the benchmark's output — the serve-bench CI lane asserts
exactly that round trip.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serving import (SamplingParams, ServingEngine, latency_stats,
                           run_workload)


def tiny_cfg(arch: str):
    """2-layer reduction of the smoke config: benchmark the ENGINE, not
    the model."""
    return dataclasses.replace(get_smoke(arch), n_layers=2, vocab=512)


def run(args) -> dict:
    cfg = tiny_cfg(args.arch)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, ServeConfig(
        max_batch=args.max_batch, max_seq_len=args.max_seq,
        prefill_chunk=args.prefill_chunk,
        prefill_budget_tokens=args.prefill_budget,
        eos_token=-1,
        profile_dir=args.profile_dir,
        profile_interval_ticks=64,
        profile_label="serve-bench",
        profile_meta=(("bench", "serve"),)))
    sampling = SamplingParams(temperature=args.temperature, seed=1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, args.max_seq // 4)))
               for _ in range(args.requests)]

    # warmup: compile prefill/decode/sampler outside the timed window
    engine.submit(prompts[0][:4], 2, sampling=sampling)
    engine.run_until_drained()
    engine.completed.clear()

    t0 = time.monotonic()
    done = run_workload(engine, prompts, args.max_new, mode=args.mode,
                        rate=args.rate, rng=rng, sampling=sampling)
    s = latency_stats(done, time.monotonic() - t0)
    if not s["requests"] or "ttft_mean_s" not in s:
        # reachable diagnostic BEFORE any stats key is touched
        raise SystemExit("degenerate serve run: no requests completed")
    return {
        "serve.requests": int(s["requests"]),
        "serve.tokens": int(s["tokens"]),
        "serve.wall_s": round(s["wall_s"], 4),
        "serve.throughput_tok_s": round(s["throughput_tok_s"], 2),
        "serve.ttft_mean_ms": round(s["ttft_mean_s"] * 1e3, 3),
        "serve.ttft_p95_ms": round(s["ttft_p95_s"] * 1e3, 3),
        "serve.queue_wait_mean_ms": round(s["queue_wait_mean_s"] * 1e3, 3),
        "serve.decode_ms_per_tok": round(s["decode_s_per_tok"] * 1e3, 3),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--mode", choices=("open", "closed"), default="open")
    ap.add_argument("--rate", type=float, default=16.0,
                    help="open-loop mean arrival rate, requests/s")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--prefill-budget", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--profile-dir", default="",
                    help="register the run + write its XFA shard here")
    ap.add_argument("-o", "--output", default="",
                    help="also write the CSV rows to this file")
    args = ap.parse_args()
    if args.requests < 1:
        ap.error("--requests must be >= 1")

    rows = run(args)
    lines = ["name,value"] + [f"{k},{v}" for k, v in rows.items()]
    out = "\n".join(lines)
    print(out)
    if args.output:
        with open(args.output, "w") as f:
            f.write(out + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
