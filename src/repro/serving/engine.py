"""Serving engine: iteration-level continuous batching behind a client API.

Layering of this package:

    scheduler.py  admission — FCFS queue -> free slots under a per-tick
                  chunked-prefill token budget
    sampling.py   per-request sampling params as per-slot vectors, ONE
                  jitted pooled sampler (greedy/temperature/top-k/top-p)
    engine.py     the slot pool + compiled per-slot-position decode tick,
                  the background serving thread, and the client handles

Decode runs ONE compiled decode_step per tick over the whole pool with a
per-slot position vector `pos: [B] int32` — every slot's KV/state row
advances independently (rope angles, cache writes and kv-length masks
are per-row in the model layer), so mixed-length requests admitted at
staggered ticks decode at their own depths: true iteration-level
batching with zero recompilation as requests come and go.  Prompt tails
beyond `prefill_chunk` are merged into the decode stream one token per
tick (host-chunked prefill).

Client API: `submit()` returns a Request handle immediately; tokens
stream through an optional `on_token` callback and `handle.result()`
blocks until completion.  `start()` runs the engine on a background
thread (open-loop serving); without it, `run_until_drained()` drives the
same loop synchronously (closed-loop benchmarks, tests).

XFA instrumentation ('serve'): prefill_request and decode_tick are
traced boundaries; queue_wait (Wait kind), ttft, decode_token and e2e
latency phases fold via tracer.record_duration; truncated_prompt is a
count event.  Shards land in the profile store exactly like trainer
shards — `repro.profile query --kind serve`, report/diff/timeline all
apply to serving runs natively.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.core import tracer as xfa
from repro.core.shadow import KIND_WAIT
from repro.models.api import Model

from .sampling import GREEDY, PooledSampler, SamplingParams
from .scheduler import Scheduler


@dataclasses.dataclass
class Request:
    """Client handle for one generation request.

    Returned by ServingEngine.submit; safe to read from other threads.
    `result()` blocks until the request finishes; `on_token` (if given)
    is invoked from the engine thread for every generated token."""
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 32
    sampling: SamplingParams = GREEDY
    submitted_at: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False            # prompt cut to fit the cache row
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    on_token: Optional[Callable[["Request", int], None]] = None
    error: Optional[BaseException] = None      # engine failure, if any
    _done_event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    def result(self, timeout: Optional[float] = None) -> "Request":
        """Block until the request completes; raises TimeoutError, or
        RuntimeError if the engine failed while this request was live."""
        if not self._done_event.wait(timeout):
            raise TimeoutError(f"request {self.uid} not done in {timeout}s")
        if self.error is not None:
            raise RuntimeError(
                f"serving engine failed while request {self.uid} was "
                f"in flight") from self.error
        return self

    # -- latency accessors (None until the phase happened) ------------------
    @property
    def queue_wait_s(self) -> Optional[float]:
        return None if self.admitted_at is None \
            else self.admitted_at - self.submitted_at

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.first_token_at is None \
            else self.first_token_at - self.submitted_at

    @property
    def e2e_s(self) -> Optional[float]:
        return None if self.finished_at is None \
            else self.finished_at - self.submitted_at


def _scatter_slot(pool, one, slot_idx: int):
    """Write a batch=1 cache pytree into row `slot_idx` of the pool cache.

    The batch axis differs per family/leaf ([L,B,...] KV rows, xlstm's
    [n_super,n_m,B,...] states, ...) — it is inferred per leaf as the
    first axis where the batch=1 tree has extent 1 and the pool differs.
    (The previous engine hardcoded axis 1, which silently aliased every
    xlstm request onto batch row 0.)"""
    def leaf(p, o):
        if p.shape == o.shape:         # max_batch == 1: full replace
            return o.astype(p.dtype)
        ax = next(d for d, (a, b) in enumerate(zip(p.shape, o.shape))
                  if b == 1 and a != b)
        idx = [0] * p.ndim
        idx[ax] = slot_idx
        return jax.lax.dynamic_update_slice(p, o.astype(p.dtype), tuple(idx))
    return jax.tree.map(leaf, pool, one)


class ServingEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig) -> None:
        self.model = model
        self.params = params
        self.scfg = scfg
        self.scheduler = Scheduler(scfg)
        self.sampler = PooledSampler(scfg.max_batch)
        self.table = model.table()
        self.cache = model.init_cache(scfg.max_batch, scfg.max_seq_len)
        self._decode = jax.jit(model.decode_step, donate_argnums=(3,))
        self._uid = 0
        self.completed: List[Request] = []
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        self._error: Optional[BaseException] = None   # terminal loop failure
        self._profile_store = None
        self._ticks = 0
        if scfg.profile_dir:
            from repro.profile import (ProfileStore, RetentionPolicy,
                                       register_run)
            self._profile_store = ProfileStore(
                scfg.profile_dir,
                retention=RetentionPolicy(
                    keep_last=scfg.profile_keep_last,
                    max_age_s=scfg.profile_max_age_s,
                    max_bytes=scfg.profile_max_bytes))
            # index this replica in the run registry so fleets of serving
            # runs are queryable (`repro.profile query --kind serve ...`)
            from repro.parallel.axes import get_runtime_mesh
            mesh = get_runtime_mesh()
            register_run(
                scfg.profile_dir,
                config=model.cfg.name, arch=model.cfg.family,
                mesh_shape=tuple(mesh.devices.shape)
                if mesh is not None else None,
                mesh_axes=tuple(mesh.axis_names)
                if mesh is not None else None,
                label=scfg.profile_label, kind="serve",
                meta={"max_batch": scfg.max_batch,
                      "max_seq_len": scfg.max_seq_len,
                      **dict(scfg.profile_meta)})

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               sampling: Optional[SamplingParams] = None,
               on_token: Optional[Callable[[Request, int], None]] = None
               ) -> Request:
        """Enqueue a request; returns its handle immediately."""
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the engine "
                             "always samples at least the first token)")
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            # reject per-request: a malformed prompt failing inside
            # _admit would kill the engine loop and every other client
            raise ValueError(f"prompt must be a non-empty 1-D token "
                             f"array, got shape {prompt.shape}")
        if sampling is None:
            sampling = SamplingParams(
                temperature=self.scfg.temperature, top_k=self.scfg.top_k,
                top_p=self.scfg.top_p, seed=self.scfg.sample_seed)
        # timestamp BEFORE taking the lock: a tick in progress holds it,
        # and that wait is queueing delay the client really experienced
        submitted_at = time.monotonic()
        with self._work:
            if self._error is not None:
                # a dead engine must reject, not enqueue into a void where
                # result() would block forever
                raise RuntimeError("serving engine has failed; no further "
                                   "requests accepted") from self._error
            self._uid += 1
            req = Request(self._uid, prompt,
                          max_new_tokens, sampling=sampling,
                          submitted_at=submitted_at, on_token=on_token)
            self.scheduler.add(req)
            self._work.notify_all()
        return req

    def start(self) -> "ServingEngine":
        """Run the engine loop on a background daemon thread.  After a
        timed-out stop() this blocks until the old loop finishes its tick
        and is reaped — there is never a second loop over the same pool,
        and start() returning means the engine IS serving."""
        while True:
            with self._lock:
                if self._error is not None:
                    raise RuntimeError("serving engine has failed; it "
                                       "cannot be restarted") from self._error
                t = self._thread
                if t is None:
                    self._stop = False
                    self._thread = threading.Thread(
                        target=self._serve_loop, name="serve-engine",
                        daemon=True)
                    self._thread.start()
                    return self
                if t.is_alive() and not self._stop:
                    return self            # genuinely running
            # finished, or stopping after a timed-out stop(): reap OUTSIDE
            # the lock (the loop's current tick needs it to complete)
            t.join()
            with self._lock:
                if self._thread is t:
                    self._thread = None

    def stop(self, timeout: float = 30.0) -> bool:
        """Stop the background thread (in-flight requests stay in place).
        Returns False if the loop is still finishing its current tick —
        the thread stays owned so a later start() can never spawn a
        second loop over the same pool; call stop() again to reap it."""
        with self._work:
            if self._thread is None:
                return True
            self._stop = True
            self._work.notify_all()
            t = self._thread
        t.join(timeout)
        if t.is_alive():
            return False
        with self._lock:
            if self._thread is t:
                self._thread = None
        return True

    # -- engine internals ---------------------------------------------------
    @xfa.api("serve", "prefill_request")
    def _admit(self, slot_idx: int, req: Request) -> None:
        """Bulk-prefill up to prefill_chunk tokens of `req` into slot
        `slot_idx`'s cache rows; the prompt tail (if any) is left pending
        for the decode stream."""
        model, scfg = self.model, self.scfg
        now = time.monotonic()
        req.admitted_at = now
        xfa.record_duration("serve", "queue_wait",
                            (now - req.submitted_at) * 1e9, kind=KIND_WAIT)
        # keep at least one prompt token even when max_new_tokens alone
        # (nearly) fills the row — matches Scheduler.admit_cost's clamp
        limit = max(1, scfg.max_seq_len - req.max_new_tokens - 1)
        prompt = req.prompt
        if len(prompt) > limit:
            # visible truncation: flagged on the handle AND folded as a
            # count event so fleets can alarm on it
            prompt = prompt[:limit]
            req.truncated = True
            xfa.count_event("serve", "truncated_prompt")
        cap = scfg.max_seq_len - len(prompt)
        if req.max_new_tokens > cap:
            # generation budget clamped so the slot's pos can never run
            # off the end of its cache row (oversized max_new_tokens)
            req.max_new_tokens = cap
            req.truncated = True
            xfa.count_event("serve", "clamped_max_new")
        chunk = self.scheduler.admit_cost(req)
        head, tail = prompt[:chunk], prompt[chunk:]
        # single-slot prefill: run the chunk at batch=1 and scatter the
        # resulting rows into the pool cache at slot_idx
        tiny_cache = model.init_cache(1, scfg.max_seq_len)
        batch = {"tokens": jnp.asarray(head[None])}
        logits, tiny_cache, self.table = model.prefill(
            self.params, batch, self.table, tiny_cache)
        self.cache = _scatter_slot(self.cache, tiny_cache, slot_idx)
        self.scheduler.bind(slot_idx, req, pos=len(head), pending=tail)
        self.sampler.bind(slot_idx, req.sampling)
        if len(tail) == 0:
            # whole prompt prefilled: the first token samples NOW (and is
            # EOS-checked — a first-token EOS finishes without any decode
            # ticks instead of burning max_new_tokens - 1 of them)
            tok = self.sampler.sample_one(np.asarray(logits[0]),
                                          req.sampling, step=len(head))
            self._emit(slot_idx, tok, time.monotonic())

    @xfa.api("serve", "decode_tick")
    def _tick(self) -> int:
        """One pooled decode step at per-slot positions; returns #active."""
        slots = self.scheduler.slots
        active = self.scheduler.active()
        if not active:
            return 0
        tokens = np.zeros((self.scfg.max_batch,), np.int32)
        pos = self.scheduler.pos_vector()
        feeding = {}           # idx -> prompt tokens REMAIN after this tick
        for i in active:
            s = slots[i]
            if s.pending:
                tokens[i] = s.pending.popleft()
                feeding[i] = bool(s.pending)
            else:
                tokens[i] = s.request.output[-1]
                feeding[i] = False
        t0 = time.perf_counter_ns()
        logits, self.cache, self.table = self._decode(
            self.params, jnp.asarray(tokens), self.table, self.cache,
            jnp.asarray(pos))
        nxt = self.sampler(logits, step=pos + 1)
        tick_ns = time.perf_counter_ns() - t0
        now = time.monotonic()
        emitted = 0
        for i in active:
            slots[i].pos += 1
            if feeding[i]:     # mid-prompt: the sampled token is discarded
                continue
            emitted += 1
            self._emit(i, int(nxt[i]), now)
        if emitted:
            xfa.record_duration("serve", "decode_token",
                                tick_ns / emitted, n=emitted)
        return len(active)

    def _emit(self, slot_idx: int, tok: int, now: float) -> None:
        """Accept one generated token for the request in `slot_idx`."""
        req = self.scheduler.slots[slot_idx].request
        first = not req.output
        req.output.append(tok)
        if first:
            req.first_token_at = now
            xfa.record_duration("serve", "ttft",
                                (now - req.submitted_at) * 1e9)
        if req.on_token is not None:
            try:
                req.on_token(req, tok)
            except Exception:
                xfa.count_event("serve", "callback_error")
        if tok == self.scfg.eos_token or len(req.output) >= req.max_new_tokens:
            self._finish(slot_idx, now)

    def _finish(self, slot_idx: int, now: float) -> None:
        req = self.scheduler.slots[slot_idx].request
        req.done = True
        req.finished_at = now
        xfa.record_duration("serve", "e2e", (now - req.submitted_at) * 1e9)
        self.completed.append(req)
        self.scheduler.release(slot_idx)
        self.sampler.release(slot_idx)
        req._done_event.set()

    def step(self) -> int:
        """One engine iteration: admit under the budget, then one pooled
        decode tick.  Returns the number of active slots ticked.

        Failure handling lives HERE, not in the background loop, so the
        synchronous (closed-loop) driver gets the same guarantee: an
        error marks the engine dead and wakes every waiter before the
        exception propagates to whoever drove the step."""
        with self._lock:
            try:
                # queue depth at tick start, folded as a gauge: its
                # per-interval mean across the snapshot ring is the
                # saturation signal `diagnose` reads (a growing mean says
                # admission is structurally behind the arrival rate)
                xfa.record_gauge("serve", "queue_depth",
                                 len(self.scheduler.waiting))
                picked = self.scheduler.schedule()
                for k, (idx, req) in enumerate(picked):
                    try:
                        self._admit(idx, req)
                    except Exception as e:
                        # every request in `picked` was already popped
                        # from the queue — none may vanish without waking
                        # waiters: the failing one errors out, later ones
                        # go back to the queue head (FCFS preserved) for
                        # _fail_outstanding to find
                        req.error = e
                        req._done_event.set()
                        self.scheduler.release(idx)
                        for _, later in reversed(picked[k + 1:]):
                            self.scheduler.waiting.appendleft(later)
                        raise
                n = self._tick()
                self._ticks += 1
                interval = self.scfg.profile_interval_ticks
                if self._profile_store is not None and interval \
                        and self._ticks % interval == 0:
                    self.write_profile_shard()
                return n
            except Exception as e:      # noqa: BLE001 — fail loud AND clean
                self._fail_outstanding(e)
                raise

    def _serve_loop(self) -> None:
        xfa.set_thread_group("serve")
        while True:
            with self._work:
                while not self._stop and not self.scheduler.has_work():
                    self._work.wait(0.05)
                if self._stop:
                    break
            try:
                self.step()
            except Exception:               # noqa: BLE001 — must not die mute
                break                       # step() already failed waiters
        self.write_profile_shard()

    def _fail_outstanding(self, exc: BaseException) -> None:
        """A serve-loop error must not strand clients on result(): mark
        every live request failed and wake its waiters."""
        xfa.count_event("serve", "engine_error")
        with self._lock:
            self._error = exc
            live = [s.request for s in self.scheduler.slots
                    if s.request is not None]
            live += list(self.scheduler.waiting)
            self.scheduler.waiting.clear()
            for i in self.scheduler.active():
                self.scheduler.release(i)
            for req in live:
                req.error = exc
                req._done_event.set()
            self._stop = True

    # -- profiling ----------------------------------------------------------
    def write_profile_shard(self) -> None:
        """Refresh this replica's profile shard (host tracer folds)."""
        if self._profile_store is None:
            return
        from repro.profile import tracer_folded
        self._profile_store.write_shard(
            tracer_folded(), label=self.scfg.profile_label,
            meta={"ticks": self._ticks, "completed": len(self.completed)})

    # -- synchronous driver -------------------------------------------------
    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Serve until queue and pool are empty.  With a background thread
        running this just waits for quiescence; otherwise it drives the
        loop inline (closed-loop mode)."""
        t = self._thread
        if t is not None and t.is_alive():
            deadline = time.monotonic() + max_ticks * 0.1
            while True:
                # observe under the engine lock: step() holds it across
                # pop -> bind -> tick, so a request mid-admission can
                # never look like "neither waiting nor active" from here
                with self._lock:
                    if not self.scheduler.has_work():
                        break
                if time.monotonic() > deadline:
                    break
                time.sleep(0.002)
            return self.completed
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and not self.scheduler.has_waiting():
                break
        self.write_profile_shard()
        return self.completed
