"""Relation-Aware Data Folding — the fold/merge algebra over shadow tables.

Paper mapping (Scaler §3.4 "Online Data Folder"): events are never appended
to a log; they are folded online into per-(caller → callee API) accumulators.
Memory is O(#edges), not O(#events).  The fold keeps the *relation* — the same
API invoked from two components stays two edges — so per-component accuracy
survives the folding.

This module provides the pure-data half:

  * `EdgeStats` — one folded edge: count/total/child/min/max, optional
    folded metrics, and an optional bounded latency histogram
    (core.histogram) from which p50/p95/p99 and jitter derive.
  * `FoldedTable` — edge → stats mapping with a commutative, associative
    merge, plus constructors from per-thread ShadowTables and device
    fold vectors.
  * `EdgeColumns` — the struct-of-arrays twin of FoldedTable: aligned
    numpy columns (plus the optional [N, HIST_BUCKETS] histogram block),
    row projections (`select`), key-part grouping for graph aggregation
    (`group_rows`), and round-trips to/from FoldedTable.  This is the
    shape the snapshot format serializes.
  * `merge_columns` — the vectorized N-way merge over EdgeColumns that
    the snapshot reducer uses instead of per-edge boxing.

The merge algebra is property-tested (tests/test_xfa_properties.py,
tests/test_histograms.py):

    merge(a, merge(b, c)) == merge(merge(a, b), c)      (associativity)
    merge(a, b) == merge(b, a)                          (commutativity)
    merge(a, empty) == a                                (identity)
    total_ns / count conservation under arbitrary splits of an event stream
    histogram merge = exact bucket-wise add (loss-free, order-independent)
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from .histogram import HIST_BUCKETS, jitter_ns as _hist_jitter, percentile_ns
from .shadow import (KIND_CALL, KIND_NAMES, KIND_WAIT, ShadowTable,
                     ShadowTableSet, SlotInfo, SlotKey)

_I64_MAX = np.iinfo(np.int64).max


def merge_rates(rate_a: Optional[float], count_a: int,
                rate_b: Optional[float], count_b: int) -> Optional[float]:
    """Count-weighted merge of two effective sampling rates.

    `None` means fully sampled (rate 1.0).  With rate = timed/seen per
    shard, the count-weighted arithmetic mean is exactly the merged
    shard's timed/seen — so the merged rate stays the true effective
    rate.  A merge that lands back at >= 1.0 normalizes to None so
    fully-sampled data never grows a redundant column."""
    if rate_a is None and rate_b is None:
        return None
    ra = 1.0 if rate_a is None else rate_a
    rb = 1.0 if rate_b is None else rate_b
    total = count_a + count_b
    if total <= 0:
        return None
    rate = (ra * count_a + rb * count_b) / total
    return None if rate >= 1.0 else rate


@dataclass
class EdgeStats:
    """Folded statistics of one cross-flow edge (caller → component.api)."""

    count: int = 0
    total_ns: int = 0
    child_ns: int = 0
    min_ns: int = _I64_MAX
    max_ns: int = 0
    kind: int = KIND_CALL
    # extra folded metrics from the device layer (flops, bytes, tokens, ...)
    metrics: Dict[str, float] = field(default_factory=dict)
    #: optional [HIST_BUCKETS] uint64 latency histogram (core.histogram);
    #: compare=False keeps dataclass == well-defined (ndarray eq is
    #: elementwise) — conftest.assert_tables_equal compares hists explicitly
    hist: Optional[np.ndarray] = field(default=None, compare=False,
                                       repr=False)
    #: effective timing-sample rate in (0, 1) when the overhead governor
    #: subsampled this edge (core.sampler): counts are exact, time columns
    #: are unbiased scale-ups.  None means fully sampled (rate 1.0) —
    #: compare=False because None and a merged-back 1.0 are the same fact
    sample_rate: Optional[float] = field(default=None, compare=False)

    @property
    def self_ns(self) -> int:
        """Time in the callee itself, excluding its own callees (paper 'Self')."""
        return self.total_ns - self.child_ns

    @property
    def mean_ns(self) -> float:
        return self.total_ns / self.count if self.count else 0.0

    # -- histogram read-out (0.0 when the edge carries no histogram) ------
    def percentile_ns(self, q: float) -> float:
        return percentile_ns(self.hist, q)

    @property
    def p50_ns(self) -> float:
        return percentile_ns(self.hist, 0.50)

    @property
    def p95_ns(self) -> float:
        return percentile_ns(self.hist, 0.95)

    @property
    def p99_ns(self) -> float:
        return percentile_ns(self.hist, 0.99)

    @property
    def jitter_ns(self) -> float:
        """Tail jitter as a percentile delta: p99 - p50."""
        return _hist_jitter(self.hist)

    @property
    def effective_rate(self) -> float:
        """sample_rate with the None == fully-sampled default resolved."""
        return 1.0 if self.sample_rate is None else self.sample_rate

    def merge(self, other: "EdgeStats") -> "EdgeStats":
        metrics = dict(self.metrics)
        for k, v in other.metrics.items():
            metrics[k] = metrics.get(k, 0.0) + v
        hist = None
        if self.hist is not None or other.hist is not None:
            hist = np.zeros(HIST_BUCKETS, dtype=np.uint64)
            if self.hist is not None:
                hist += self.hist
            if other.hist is not None:
                hist += other.hist
        return EdgeStats(
            count=self.count + other.count,
            total_ns=self.total_ns + other.total_ns,
            child_ns=self.child_ns + other.child_ns,
            min_ns=min(self.min_ns, other.min_ns),
            max_ns=max(self.max_ns, other.max_ns),
            kind=self.kind if self.count else other.kind,
            metrics=metrics,
            hist=hist,
            sample_rate=merge_rates(self.sample_rate, self.count,
                                    other.sample_rate, other.count),
        )

    def to_json(self) -> dict:
        out = {
            "count": int(self.count),
            "total_ns": int(self.total_ns),
            "child_ns": int(self.child_ns),
            "min_ns": int(self.min_ns) if self.count else None,
            "max_ns": int(self.max_ns),
            "kind": KIND_NAMES[self.kind],
            "metrics": self.metrics,
        }
        if self.hist is not None and self.hist.any():
            # sparse {bucket: count} — 160 mostly-zero ints don't belong in
            # a human-inspected json dump
            out["hist"] = {str(int(b)): int(self.hist[b])
                           for b in np.nonzero(self.hist)[0]}
        if self.sample_rate is not None:
            out["sample_rate"] = float(self.sample_rate)
        return out

    @staticmethod
    def from_json(d: dict) -> "EdgeStats":
        kind = KIND_WAIT if d.get("kind") == "wait" else KIND_CALL
        hist = None
        if d.get("hist"):
            hist = np.zeros(HIST_BUCKETS, dtype=np.uint64)
            for b, c in d["hist"].items():
                hist[int(b)] = int(c)
        return EdgeStats(
            count=d["count"],
            total_ns=d["total_ns"],
            child_ns=d["child_ns"],
            min_ns=d["min_ns"] if d.get("min_ns") is not None else _I64_MAX,
            max_ns=d["max_ns"],
            kind=kind,
            metrics=dict(d.get("metrics", {})),
            hist=hist,
            sample_rate=d.get("sample_rate"),
        )


class FoldedTable:
    """Edge → EdgeStats mapping; the offline-mergeable form of a shadow table.

    `group` tags which thread-group / host / device shard the fold came from —
    kept so attribution (serial vs parallel, imbalance) can run *before* the
    final cross-group merge, exactly like the paper merges per-thread files in
    the offline visualizer.
    """

    def __init__(self, edges: Optional[Dict[SlotKey, EdgeStats]] = None,
                 group: str = "main") -> None:
        self.edges: Dict[SlotKey, EdgeStats] = edges or {}
        self.group = group

    # -- constructors -------------------------------------------------------
    @staticmethod
    def from_shadow(table: ShadowTable, infos: Iterable[SlotInfo],
                    rates: Optional[Mapping[int, float]] = None
                    ) -> "FoldedTable":
        """`rates` attaches the governor's per-slot effective sampling
        rate (core.sampler — only subsampled slots appear) to the folded
        edges; omitted slots stay at the implicit rate-1.0 None."""
        edges: Dict[SlotKey, EdgeStats] = {}
        for info in infos:
            s = info.slot
            if s >= table.capacity or table.count[s] == 0:
                continue
            hist = None
            if table.hist is not None and table.hist[s].any():
                hist = table.hist[s].copy()
            edges[info.key] = EdgeStats(
                count=int(table.count[s]),
                total_ns=int(table.total_ns[s]),
                child_ns=int(table.child_ns[s]),
                min_ns=int(table.min_ns[s]),
                max_ns=int(table.max_ns[s]),
                kind=info.kind,
                hist=hist,
                sample_rate=rates.get(s) if rates else None,
            )
        return FoldedTable(edges, group=table.group)

    @staticmethod
    def from_set(tables: ShadowTableSet,
                 rates: Optional[Mapping[int, float]] = None
                 ) -> List["FoldedTable"]:
        infos = tables.registry.infos()
        return [FoldedTable.from_shadow(t, infos, rates=rates)
                for t in tables.tables()]

    # -- algebra --------------------------------------------------------------
    def merge(self, other: "FoldedTable") -> "FoldedTable":
        edges = {k: v for k, v in self.edges.items()}
        for k, v in other.edges.items():
            edges[k] = edges[k].merge(v) if k in edges else v
        group = self.group if self.group == other.group else "merged"
        return FoldedTable(edges, group=group)

    @staticmethod
    def merge_all(tables: Iterable["FoldedTable"]) -> "FoldedTable":
        """Pairwise per-edge merge: right for a handful of small in-memory
        tables (per-thread host folds).  Bulk N-way aggregation of already
        -columnar shards goes through merge_columns instead — the snapshot
        reducer (repro.profile) never boxes per-edge EdgeStats at all."""
        out = FoldedTable()
        for t in tables:
            out = out.merge(t)
        return out

    @staticmethod
    def merge_all_columnar(tables: Iterable["FoldedTable"]) -> "FoldedTable":
        """N-way merge via the column algebra; same per-edge stats as
        merge_all (property-tested — the `group` label can differ:
        merge_all's left fold starts from an empty 'main' table), faster
        once tables are large AND already columnar — from FoldedTable
        inputs the conversion cost eats the win, which is exactly why
        snapshots *store* columns (benchmarks/merge.py)."""
        tables = list(tables)
        if not tables:
            return FoldedTable()
        cols = merge_columns([EdgeColumns.from_folded(t) for t in tables])
        return cols.to_folded()

    def to_columns(self) -> "EdgeColumns":
        return EdgeColumns.from_folded(self)

    # -- queries --------------------------------------------------------------
    def components(self) -> List[str]:
        names = set()
        for (caller, component, _api) in self.edges:
            names.add(caller)
            names.add(component)
        return sorted(names)

    def edges_from(self, caller: str) -> Dict[SlotKey, EdgeStats]:
        return {k: v for k, v in self.edges.items() if k[0] == caller}

    def edges_into(self, component: str) -> Dict[SlotKey, EdgeStats]:
        return {k: v for k, v in self.edges.items() if k[1] == component}

    def total_ns(self) -> int:
        return sum(e.total_ns for e in self.edges.values())

    def scale_time(self, factor: float) -> "FoldedTable":
        """Scale all times (serial/parallel attribution divides by #threads).

        Histograms are DROPPED: scaling is an attribution heuristic over
        aggregates, and a per-sample distribution whose buckets no longer
        match its values would be worse than none."""
        edges = {
            k: EdgeStats(
                count=v.count,
                total_ns=int(v.total_ns * factor),
                child_ns=int(v.child_ns * factor),
                min_ns=int(v.min_ns * factor) if v.count else v.min_ns,
                max_ns=int(v.max_ns * factor),
                kind=v.kind,
                metrics=dict(v.metrics),
                sample_rate=v.sample_rate,
            )
            for k, v in self.edges.items()
        }
        return FoldedTable(edges, group=self.group)

    # -- persistence ------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "group": self.group,
            "edges": [
                {"caller": k[0], "component": k[1], "api": k[2], **v.to_json()}
                for k, v in sorted(self.edges.items())
            ],
        }

    @staticmethod
    def from_json(d: dict) -> "FoldedTable":
        edges = {
            (e["caller"], e["component"], e["api"]): EdgeStats.from_json(e)
            for e in d["edges"]
        }
        return FoldedTable(edges, group=d.get("group", "main"))

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @staticmethod
    def load(path: str) -> "FoldedTable":
        with open(path) as f:
            return FoldedTable.from_json(json.load(f))

    def __len__(self) -> int:
        return len(self.edges)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FoldedTable(group={self.group!r}, edges={len(self.edges)})"


@dataclass
class EdgeColumns:
    """Struct-of-arrays form of a FoldedTable: one aligned column per stat.

    This is both the merge hot path (whole-column numpy sums/min/max after
    re-interning keys into a union index — no per-edge EdgeStats allocation)
    and the shape the profile snapshot format (repro.profile.snapshot)
    serializes.  `metric_mask` preserves metric *presence*: an edge that
    never emitted metric m stays absent after a round-trip, it does not
    become m=0.0.

    `hist` is the optional latency-histogram block ([N, HIST_BUCKETS]
    uint64, schema v2): None when no edge carries a distribution; an
    all-zero row means *that* edge carries none (every recorded sample
    lands in a bucket, so a zero row cannot be a real distribution —
    no presence mask needed).
    """

    keys: List[SlotKey]
    count: np.ndarray                  # int64 [N]
    total_ns: np.ndarray               # int64 [N]
    child_ns: np.ndarray               # int64 [N]
    min_ns: np.ndarray                 # int64 [N] (_I64_MAX when count == 0)
    max_ns: np.ndarray                 # int64 [N]
    kind: np.ndarray                   # int8  [N]
    metric_names: List[str]
    metric_values: np.ndarray          # float64 [M, N]
    metric_mask: np.ndarray            # bool    [M, N]
    group: str = "main"
    hist: Optional[np.ndarray] = None  # uint64 [N, HIST_BUCKETS] or None
    #: optional float64 [N] effective timing-sample rate column (schema
    #: v3): None when every edge is fully sampled; rows at exactly 1.0
    #: mean *that* edge is fully sampled (the None of EdgeStats)
    sample_rate: Optional[np.ndarray] = None

    @staticmethod
    def empty(group: str = "main") -> "EdgeColumns":
        z = np.zeros(0, dtype=np.int64)
        return EdgeColumns([], z, z.copy(), z.copy(), z.copy(), z.copy(),
                           np.zeros(0, dtype=np.int8), [],
                           np.zeros((0, 0), dtype=np.float64),
                           np.zeros((0, 0), dtype=bool), group=group)

    @staticmethod
    def from_folded(table: "FoldedTable") -> "EdgeColumns":
        keys = sorted(table.edges)
        n = len(keys)
        count = np.empty(n, dtype=np.int64)
        total_ns = np.empty(n, dtype=np.int64)
        child_ns = np.empty(n, dtype=np.int64)
        min_ns = np.empty(n, dtype=np.int64)
        max_ns = np.empty(n, dtype=np.int64)
        kind = np.empty(n, dtype=np.int8)
        mnames: Dict[str, int] = {}
        for k in keys:
            for m in table.edges[k].metrics:
                mnames.setdefault(m, len(mnames))
        mvals = np.zeros((len(mnames), n), dtype=np.float64)
        mmask = np.zeros((len(mnames), n), dtype=bool)
        hist = None
        if any(e.hist is not None for e in table.edges.values()):
            hist = np.zeros((n, HIST_BUCKETS), dtype=np.uint64)
        rate = None
        if any(e.sample_rate is not None for e in table.edges.values()):
            rate = np.ones(n, dtype=np.float64)  # 1.0 == fully sampled
        for j, k in enumerate(keys):
            e = table.edges[k]
            count[j] = e.count
            total_ns[j] = e.total_ns
            child_ns[j] = e.child_ns
            min_ns[j] = e.min_ns
            max_ns[j] = e.max_ns
            kind[j] = e.kind
            if hist is not None and e.hist is not None:
                hist[j] = e.hist
            if rate is not None and e.sample_rate is not None:
                rate[j] = e.sample_rate
            for m, v in e.metrics.items():
                i = mnames[m]
                mvals[i, j] = v
                mmask[i, j] = True
        return EdgeColumns(keys, count, total_ns, child_ns, min_ns, max_ns,
                           kind, list(mnames), mvals, mmask,
                           group=table.group, hist=hist, sample_rate=rate)

    # -- graph projections ---------------------------------------------------
    @property
    def self_ns(self) -> np.ndarray:
        """Derived per-edge self time column (total - child)."""
        return self.total_ns - self.child_ns

    def select(self, rows) -> "EdgeColumns":
        """Row-subset projection (bool mask or index array) keeping every
        column — including the metric matrix — aligned: the vectorized
        way to slice a profile (one component's inbound edges, one kind,
        one caller, ...) without re-boxing EdgeStats."""
        rows = np.asarray(rows)
        if rows.dtype == bool:
            rows = np.nonzero(rows)[0]
        else:
            # an empty python list arrives float64; indexing needs ints
            rows = rows.astype(np.int64)
        keys = [self.keys[int(i)] for i in rows]
        m = self.metric_values[:, rows] if len(self.metric_names) \
            else self.metric_values[:, :0]
        mm = self.metric_mask[:, rows] if len(self.metric_names) \
            else self.metric_mask[:, :0]
        h = self.hist[rows] if self.hist is not None else None
        r = self.sample_rate[rows] if self.sample_rate is not None else None
        return EdgeColumns(keys, self.count[rows], self.total_ns[rows],
                           self.child_ns[rows], self.min_ns[rows],
                           self.max_ns[rows], self.kind[rows],
                           list(self.metric_names), m, mm, group=self.group,
                           hist=h, sample_rate=r)

    def group_rows(self, by: str = "component") -> Dict[str, np.ndarray]:
        """Edge-row indices grouped by one key part: 'caller' (0),
        'component' (1) or 'api' (2).  One pass over the keys; the returned
        index arrays drive whole-column numpy reductions (np.sum over a
        fancy-indexed column), which is how FlowGraph aggregates nodes
        without boxing per-edge EdgeStats."""
        part = {"caller": 0, "component": 1, "api": 2}[by]
        groups: Dict[str, List[int]] = {}
        for j, k in enumerate(self.keys):
            groups.setdefault(k[part], []).append(j)
        return {name: np.asarray(rows, dtype=np.int64)
                for name, rows in groups.items()}

    def to_folded(self) -> "FoldedTable":
        n = len(self.keys)
        metrics: List[Dict[str, float]] = [{} for _ in range(n)]
        for i, name in enumerate(self.metric_names):
            for j in np.nonzero(self.metric_mask[i])[0]:
                metrics[j][name] = float(self.metric_values[i, j])
        edges: Dict[SlotKey, EdgeStats] = {}
        for j, k in enumerate(self.keys):
            hist = None
            if self.hist is not None and self.hist[j].any():
                hist = self.hist[j].copy()   # zero row == no distribution
            rate = None
            if self.sample_rate is not None and self.sample_rate[j] < 1.0:
                rate = float(self.sample_rate[j])  # 1.0 row == rate None
            edges[k] = EdgeStats(
                count=int(self.count[j]),
                total_ns=int(self.total_ns[j]),
                child_ns=int(self.child_ns[j]),
                min_ns=int(self.min_ns[j]),
                max_ns=int(self.max_ns[j]),
                kind=int(self.kind[j]),
                metrics=metrics[j],
                hist=hist,
                sample_rate=rate,
            )
        return FoldedTable(edges, group=self.group)

    def __len__(self) -> int:
        return len(self.keys)


def merge_columns(parts: List[EdgeColumns]) -> EdgeColumns:
    """Commutative/associative N-way merge over aligned columns.

    Keys are re-interned into one union index (the only per-edge python
    loop); every statistic then merges as one whole-column numpy scatter
    (add/min/max `.at`), matching EdgeStats.merge semantics exactly over
    the full field set:

      count / total_ns / child_ns     sum            (np.add.at)
      min_ns / max_ns                 extrema        (np.minimum/maximum.at)
      kind                            first part that actually observed
                                      the edge (count > 0) decides
      metric_values + metric_mask     sum where present; presence ORs
      hist                            exact bucket-wise add ([N, B] row
                                      scatter) — output has a hist block
                                      iff any input part has one, and a
                                      hist-less part contributes zeros
      sample_rate                     count-weighted mean (merge_rates
                                      semantics) — present iff any part
                                      carries rates; rate-less parts
                                      contribute rate 1.0 per count

    The output row order is first-seen order over `parts` (NOT sorted);
    `group` is the common group label of ALL parts — including empty
    shards, which still carry provenance — or 'merged'.
    """
    # group label from ALL parts (empty shards still carry provenance)
    groups = {p.group for p in parts}
    group = "main" if not groups else \
        (groups.pop() if len(groups) == 1 else "merged")
    parts = [p for p in parts if len(p)]
    if not parts:
        return EdgeColumns.empty(group=group)
    index: Dict[SlotKey, int] = {}
    for p in parts:
        for k in p.keys:
            if k not in index:
                index[k] = len(index)
    u = len(index)
    count = np.zeros(u, dtype=np.int64)
    total_ns = np.zeros(u, dtype=np.int64)
    child_ns = np.zeros(u, dtype=np.int64)
    min_ns = np.full(u, _I64_MAX, dtype=np.int64)
    max_ns = np.zeros(u, dtype=np.int64)
    kind = np.zeros(u, dtype=np.int8)
    decided = np.zeros(u, dtype=bool)
    mnames: Dict[str, int] = {}
    for p in parts:
        for m in p.metric_names:
            mnames.setdefault(m, len(mnames))
    mvals = np.zeros((len(mnames), u), dtype=np.float64)
    mmask = np.zeros((len(mnames), u), dtype=bool)
    hist = np.zeros((u, HIST_BUCKETS), dtype=np.uint64) \
        if any(p.hist is not None for p in parts) else None
    rate_w = np.zeros(u, dtype=np.float64) \
        if any(p.sample_rate is not None for p in parts) else None
    for p in parts:
        inv = np.fromiter((index[k] for k in p.keys), dtype=np.int64,
                          count=len(p.keys))
        np.add.at(count, inv, p.count)
        np.add.at(total_ns, inv, p.total_ns)
        np.add.at(child_ns, inv, p.child_ns)
        np.minimum.at(min_ns, inv, p.min_ns)
        np.maximum.at(max_ns, inv, p.max_ns)
        if hist is not None and p.hist is not None:
            np.add.at(hist, inv, p.hist)
        if rate_w is not None:
            prate = p.sample_rate if p.sample_rate is not None \
                else np.ones(len(p), dtype=np.float64)
            np.add.at(rate_w, inv, prate * p.count)
        und = ~decided[inv]
        kind[inv[und]] = p.kind[und]
        decided[inv] = decided[inv] | (p.count > 0)
        for i, name in enumerate(p.metric_names):
            g = mnames[name]
            present = p.metric_mask[i]
            if present.any():
                tgt = inv[present]
                np.add.at(mvals[g], tgt, p.metric_values[i][present])
                mmask[g][tgt] = True
    rate = None
    if rate_w is not None:
        rate = rate_w / np.maximum(count, 1)
        rate[count == 0] = 1.0   # a never-counted edge is trivially full
    return EdgeColumns(list(index), count, total_ns, child_ns, min_ns,
                       max_ns, kind, list(mnames), mvals, mmask, group=group,
                       hist=hist, sample_rate=rate)


def fold_event_log(events: Iterable[Tuple[str, str, str, int]],
                   kinds: Optional[Mapping[SlotKey, int]] = None) -> FoldedTable:
    """Fold an append-style event log [(caller, component, api, dur_ns), ...].

    Exists for the paper's comparison (Table 5 / §4.3.2): benchmarks build the
    same table from a raw log and from the online fold and assert equality,
    then compare memory/time.  Not used on any hot path.
    """
    edges: Dict[SlotKey, EdgeStats] = {}
    for caller, component, api, dur in events:
        key = (caller, component, api)
        e = edges.get(key)
        if e is None:
            kind = (kinds or {}).get(key, KIND_CALL)
            e = edges[key] = EdgeStats(kind=kind)
        e.count += 1
        e.total_ns += dur
        e.min_ns = min(e.min_ns, dur)
        e.max_ns = max(e.max_ns, dur)
    return FoldedTable(edges)
