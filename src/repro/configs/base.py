"""Config system: one frozen dataclass per concern, composable, hashable.

ModelConfig covers every assigned architecture family (dense / moe / hybrid /
ssm / vlm / audio enc-dec); TrainConfig and ServeConfig parameterize the
drivers; MeshConfig the distribution. Arch files in this package export
`CONFIG` (the exact published config) and `smoke_config()` (a reduced
same-family variant for CPU tests).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # -- attention ----------------------------------------------------------
    rope_theta: float = 10_000.0
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q,k
    attn_impl: str = "auto"          # ref | flash | auto
    attn_logit_softcap: float = 0.0

    # -- MLA (deepseek-v2) --------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # -- MoE ------------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # -- SSM / hybrid ---------------------------------------------------------
    ssm_state: int = 0
    d_inner: int = 0                 # 0 -> 2 * d_model
    ssm_head_dim: int = 64
    conv_kernel: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0              # zamba2: shared attn block every k layers
    slstm_every: int = 0             # xlstm: one sLSTM per k-block super-block
    mlstm_proj_factor: float = 2.0

    # -- encoder-decoder ------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    cross_attn: bool = False
    src_frontend: str = ""           # 'audio_frames' | 'vit_patches' | ''
    frontend_dim: int = 0            # stub embedding dim fed by input_specs
    n_patches: int = 0               # vlm: patches prepended to the text seq

    # -- numerics / structure -------------------------------------------------
    #: cast block-output cotangents to bf16 before they reach the TP dx
    #: all-reduces (halves backward activation-gradient wire bytes)
    bf16_grad_reduce: bool = False
    #: manual Megatron TP for the MLP (parallel/tp.py): ONE bf16 psum fwd +
    #: ONE bf16 psum bwd per block instead of GSPMD's per-projection f32 ARs
    manual_tp: bool = False
    #: models too small to tensor-parallel (heads < TP, params fit
    #: replicated): train with the model axis folded into data parallelism
    #: (EXPERIMENTS.md §Perf internvl2: roofline fraction 0.005 -> 0.36)
    prefer_dp_only: bool = False
    mlp_gated: bool = True           # SwiGLU (3 mats) vs GELU MLP (2 mats)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "dots_saveable"     # none | dots_saveable | full
    scan_layers: bool = True

    # -- derived ----------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner_(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ // self.ssm_head_dim

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def validate(self) -> "ModelConfig":
        assert self.family in ("dense", "moe", "hybrid", "ssm", "vlm", "audio")
        if self.family in ("dense", "moe", "vlm"):
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.moe:
            assert self.n_experts > 0 and self.top_k > 0
        if self.family == "audio":
            assert self.enc_layers and self.dec_layers and self.cross_attn
        if self.attn_every:
            assert self.n_layers % self.attn_every == 0
        if self.slstm_every:
            assert self.n_layers % self.slstm_every == 0
        return self

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, v, h = self.d_model, self.vocab, self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":   # xlstm
            total = (self.n_layers - self.n_slstm) * _mlstm_block_params(self) \
                + self.n_slstm * _slstm_block_params(self)
            return total + emb
        if self.family == "hybrid":
            mamba = self.n_layers * _mamba_block_params(self)
            n_attn = self.n_layers // self.attn_every if self.attn_every else 0
            attn = _attn_params(self)  # weight-tied: ONE copy
            return mamba + attn + emb
        if self.family == "audio":
            enc = self.enc_layers * (_attn_params(self) + _mlp_params(self, self.d_ff))
            dec = self.dec_layers * (2 * _attn_params(self) + _mlp_params(self, self.d_ff))
            return enc + dec + emb
        per_layer = _attn_params(self) + _mlp_or_moe_params(self)
        return self.n_layers * per_layer + emb

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top_k + shared only)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        dense_mlp = _mlp_params(self, self.d_ff) if self.d_ff else 0
        act_moe = (self.top_k + self.n_shared_experts) * _mlp_params(self, self.moe_d_ff)
        per_layer_active = _attn_params(self) + act_moe
        dense_layers = self.first_dense_layers
        moe_layers = self.n_layers - dense_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return (moe_layers * per_layer_active
                + dense_layers * (_attn_params(self) + dense_mlp) + emb)

    @property
    def n_slstm(self) -> int:
        if not self.slstm_every:
            return 0
        return self.n_layers // self.slstm_every


def _attn_params(cfg: ModelConfig) -> int:
    d, h = cfg.d_model, cfg.head_dim_
    if cfg.mla:
        q = d * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
        kv_a = d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        kv_b = cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
        o = cfg.n_heads * cfg.v_head_dim * d
        return q + kv_a + kv_b + o
    q = d * cfg.n_heads * h
    kv = 2 * d * cfg.n_kv_heads * h
    o = cfg.n_heads * h * d
    return q + kv + o


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    # SwiGLU: gate+up+down (3 mats); GELU MLP: up+down (2 mats)
    return (3 if cfg.mlp_gated else 2) * cfg.d_model * d_ff


def _mlp_or_moe_params(cfg: ModelConfig) -> int:
    if not cfg.moe:
        return _mlp_params(cfg, cfg.d_ff)
    routed = cfg.n_experts * _mlp_params(cfg, cfg.moe_d_ff)
    shared = cfg.n_shared_experts * _mlp_params(cfg, cfg.moe_d_ff)
    router = cfg.d_model * cfg.n_experts
    dense_frac = cfg.first_dense_layers / cfg.n_layers
    dense = _mlp_params(cfg, cfg.d_ff) if cfg.d_ff else 0
    # average per layer (first_dense_layers use the dense MLP)
    return int(dense_frac * dense + (1 - dense_frac) * (routed + shared + router))


def _mamba_block_params(cfg: ModelConfig) -> int:
    d, di, n = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    heads = cfg.n_ssm_heads
    in_proj = d * (2 * di + 2 * n + heads)  # x, z, B, C, dt
    conv = 4 * (di + 2 * n)
    out = di * d
    return in_proj + conv + out + 2 * heads  # + A, D per head


def _mlstm_block_params(cfg: ModelConfig) -> int:
    # matches models/xlstm.py: up d->2di, block-diag qkv (per head), scalar
    # gates d->2H, down di->d, norm scales
    d = cfg.d_model
    di = int(d * cfg.mlstm_proj_factor)
    h = max(cfg.n_heads, 1)
    up = d * 2 * di
    qkv = 3 * di * (di // h)  # block-diagonal per head
    gates = d * 2 * h
    down = di * d
    return up + qkv + gates + down + d + di


def _slstm_block_params(cfg: ModelConfig) -> int:
    # matches models/xlstm.py: 4 input gates d->d, block-diag recurrent 4
    # gates, gated FFN with factor 4/3
    d = cfg.d_model
    h = max(cfg.n_heads, 1)
    inp = 4 * d * d
    rec = 4 * d * (d // h)
    ffn = 3 * d * int(d * 4 / 3)
    return inp + rec + ffn + 2 * d


# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        return dict(zip(self.axes, self.shape)).get(name, 1)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode | long_decode

    @property
    def is_serving(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    zero1: bool = True               # shard optimizer state over data axis
    grad_compression: str = "none"   # none | int8
    #: accumulate microbatch grads inside ONE value_and_grad-over-scan so the
    #: data-axis gradient all-reduce happens ONCE per step instead of once
    #: per microbatch (pjit emits the psum inside the scan body otherwise)
    deferred_grad_reduce: bool = False
    microbatches: int = 1            # gradient accumulation / pipeline chunks
    ckpt_interval: int = 200
    ckpt_async: bool = True
    seed: int = 0
    #: host-tracer overhead budget as a fraction of wall time (0 = governor
    #: off, every boundary timed on every call).  When > 0 the trainer
    #: attaches the adaptive governor (core.sampler): hot edges back off to
    #: 1-in-k timing with unbiased scale-up while counting stays exact.
    xfa_overhead_budget: float = 0.0


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq_len: int = 2_048
    #: prefill proceeds in chunks of at most this many prompt tokens per
    #: forward_chunk step: the admission chunk AND every continuation
    #: chunk of a longer prompt's tail (true in-model chunked prefill —
    #: each chunk lands at the slot's cache offset in one positioned
    #: forward; admission cost is O(chunk), never O(prompt))
    prefill_chunk: int = 512
    #: continuation chunks of the prompt tail use this width (0 = same as
    #: prefill_chunk).  tail_chunk=1 reproduces the legacy
    #: one-token-per-tick tail feed through the SAME unified code path —
    #: benchmarks/serve.py uses it as the TTFT comparison baseline
    tail_chunk: int = 0
    #: round every prefill-chunk width up to the next power-of-two bucket
    #: (pad masked in-model via forward_chunk's `valid`): the set of
    #: compiled chunk programs stays O(log max_seq_len) instead of one
    #: per distinct prompt length (per-admission recompile hazard)
    bucket_chunks: bool = True
    #: smallest chunk bucket (floors the power-of-two rounding so tiny
    #: prompts of many distinct lengths share one compiled width)
    min_chunk_bucket: int = 8
    #: max slots whose prefill chunks batch into ONE forward_chunk call
    #: per tick (cross-slot batched prefill): same-width chunks of
    #: DIFFERENT slots gather their stashes into a multi-row cache, run
    #: a single positioned chunk at per-row offsets, and scatter back —
    #: concurrent admissions multiply prefill throughput instead of
    #: serializing on the accelerator.  1 = per-slot batch=1 prefill
    #: (the pre-batching behavior) through the same code path.  The
    #: batch dimension buckets to powers of two when bucket_chunks is
    #: set (pad rows masked via `valid`), so the compiled prefill
    #: program set stays O(log prefill_batch x log max_seq_len).
    prefill_batch: int = 8
    # -- paged KV-cache pool -------------------------------------------------
    #: rows per KV-cache page.  With max_cache_pages > 0 the engine swaps
    #: the contiguous [max_batch, max_seq_len] cache for a fixed arena of
    #: pages plus a per-slot block table: pages are granted lazily as a
    #: slot's pos crosses page boundaries and recycled at finish, so a
    #: 30-token request stops paying for a full-context row
    page_size: int = 64
    #: total pages in the arena (0 = paged cache off, contiguous pool).
    #: Page 0 is reserved as a scratch page (bucket-pad rows and
    #: past-frontier pad writes land there, masked on read), so the
    #: usable pool is max_cache_pages - 1 pages.  Admission is gated by
    #: free pages — the resource that actually runs out — with FCFS
    #: back-pressure into the waiting queue.  Families whose cache is
    #: O(1) in sequence length (hybrid/ssm/audio) ignore this and keep
    #: their dense layout behind the same engine API.
    max_cache_pages: int = 0
    eos_token: int = 2
    #: default per-request e2e deadline in ms (0 = deadlines untracked);
    #: submit(deadline_ms=...) overrides per request.  Tracked requests
    #: fold deadline_met/deadline_miss count events at finish, which the
    #: slo-violation detector turns into a miss-rate finding.
    deadline_ms: float = 0.0
    # -- scheduler ----------------------------------------------------------
    #: per-tick admission budget in bulk-prefill tokens (0 = unbounded);
    #: bounds prefill/decode interference — a burst of long prompts cannot
    #: stall slots already decoding.  The head-of-line request always fits,
    #: so a single prompt longer than the budget cannot starve (FCFS).
    prefill_budget_tokens: int = 0
    # -- sampling defaults (per-request SamplingParams override these) ------
    temperature: float = 0.0         # 0 -> greedy
    top_k: int = 0                   # 0 -> full vocab
    top_p: float = 1.0
    sample_seed: int = 0
    #: when set, the engine writes one XFA profile shard per process under
    #: this directory (refreshed every `profile_interval_ticks` decode ticks
    #: and at drain); fleet replicas reduce via `python -m repro.profile`.
    profile_dir: str = ""
    profile_interval_ticks: int = 256
    #: shard label; give replicas sharing a host+dir distinct labels (e.g.
    #: serve-0, serve-1) so the reducer can tell them from stale shards
    profile_label: str = "serve"
    #: retention for this replica's snapshot ring (see profile/store.py:
    #: RetentionPolicy): ring length per shard, max snapshot age, and a
    #: per-run-dir byte budget; 0 means unbounded for each knob, and the
    #: newest snapshot of a shard is never deleted
    profile_keep_last: int = 8
    profile_max_age_s: float = 0.0
    profile_max_bytes: int = 0
    #: free-form key=value metadata merged into the run manifest at engine
    #: start (the run registry indexes it for `repro.profile query`)
    profile_meta: Tuple[Tuple[str, str], ...] = ()
    #: fleet collector address 'HOST:PORT'; when set (with profile_dir)
    #: every shard refresh also streams the ring's unacked entries to the
    #: collector (repro.profile.FleetPublisher) — failures degrade to
    #: local-only rings, they never stall the serve loop
    xfa_collector: str = ""
    #: host-tracer overhead budget as a fraction of wall time (0 = governor
    #: off); see TrainConfig.xfa_overhead_budget — the engine attaches the
    #: governor at construction so the serve loop's per-tick boundaries
    #: back off under load instead of eating the latency budget
    xfa_overhead_budget: float = 0.0


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build the smoke-test variant: same family/wiring, tiny dims."""
    base = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) or 1,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        head_dim=32 if not cfg.mla else 0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    if cfg.moe:
        base.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                    moe_d_ff=64,
                    first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.mla:
        base.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=16,
                    v_head_dim=32)
    if cfg.family in ("hybrid", "ssm"):
        base.update(ssm_state=16, d_inner=256, ssm_head_dim=32, ssm_chunk=32)
    if cfg.attn_every:
        base.update(attn_every=2, n_layers=4)
    if cfg.slstm_every:
        base.update(slstm_every=2, n_layers=4)
    if cfg.family == "audio":
        base.update(enc_layers=2, dec_layers=2)
    if cfg.family == "vlm":
        base.update(n_patches=min(cfg.n_patches, 16) or 16, frontend_dim=64)
    if cfg.src_frontend:
        base.update(frontend_dim=64)
    base.update(overrides)
    return dataclasses.replace(cfg, **base).validate()


SMOKE_SHAPES = {
    "train": ShapeConfig("smoke_train", 64, 4, "train"),
    "prefill": ShapeConfig("smoke_prefill", 64, 2, "prefill"),
    "decode": ShapeConfig("smoke_decode", 64, 2, "decode"),
    "long_decode": ShapeConfig("smoke_long", 128, 1, "long_decode"),
}
