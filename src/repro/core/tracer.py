"""Interceptor + Tracer — host-level cross-flow interception.

Paper mapping (Scaler §3.1–§3.3): the interceptor redirects every API
invocation to the Universal Shadow Table; the tracer brackets the real call
with two timestamps and folds (count, duration) into the callee's shadow
entry, keyed by the *calling component*.

TPU/JAX adaptation of the mechanisms:

  .plt entry rewrite            ->  @xfa.api decorator on framework boundaries
                                    (selective: only registered boundaries,
                                    never whole-program instrumentation)
  return-address inspection     ->  an explicit per-thread caller stack; the
   (who called me?)                 top frame's component is the caller
  lazy PLT address resolution   ->  slot id resolved on first invocation and
                                    cached on the wrapper (no dict lookup on
                                    the steady-state hot path)
  rdtsc                         ->  time.perf_counter_ns (user-space, no
                                    syscall on Linux vDSO)
  initial-exec TLS              ->  threading.local with __slots__-style use
  dlsym interposition           ->  xfa.wrap(fn, component=...) for callables
                                    resolved at runtime (e.g. a jit'd step fn
                                    chosen from a registry)
  __noreturn handling           ->   'finally' blocks — Python exceptions are
                                    the host analogue of abnormal control flow
                                    and the frame is always popped

Wait separation (Scaler §3.5): boundaries tagged kind='wait' (blocking joins,
queue gets, device sync) fold into a separate Wait category so views can
report not-useful time distinctly.
"""

from __future__ import annotations

import functools
import threading
import time
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional

from .shadow import (APP_COMPONENT, KIND_CALL, KIND_WAIT, ShadowTableSet,
                     SlotInfo)

perf_ns = time.perf_counter_ns


class _Frame:
    __slots__ = ("component", "api", "start_ns", "child_ns")

    def __init__(self, component: str, api: str, start_ns: int) -> None:
        self.component = component
        self.api = api
        self.start_ns = start_ns
        self.child_ns = 0


class _Stack(threading.local):
    def __init__(self) -> None:
        self.frames: List[_Frame] = []


class Tracer:
    """Process-wide tracer: caller stack + shadow tables + enable switch.

    ``enabled=False`` reduces every instrumented call to a single attribute
    load + branch — the analogue of Scaler's "timing off, counting only"
    configuration knob, except we also allow full off for baseline runs
    (paper Table 3 measures against an uninstrumented baseline).
    """

    def __init__(self) -> None:
        self.tables = ShadowTableSet()
        self.enabled = True
        self.timing = True  # paper: counting always on, timing configurable
        #: optional adaptive overhead governor (core.sampler); None means
        #: every boundary is timed on every call
        self.sampler = None
        self._stack = _Stack()

    # -- caller identity ----------------------------------------------------
    def current_component(self) -> str:
        frames = self._stack.frames
        return frames[-1].component if frames else APP_COMPONENT

    def stack_depth(self) -> int:
        return len(self._stack.frames)

    # -- core bracket ---------------------------------------------------------
    def enter(self, component: str, api: str) -> _Frame:
        f = _Frame(component, api, perf_ns())
        self._stack.frames.append(f)
        return f

    def exit(self, frame: _Frame, slot: SlotInfo, scale: int = 1) -> int:
        end = perf_ns()
        frames = self._stack.frames
        frames.pop()
        dur = end - frame.start_ns
        if frames:
            # the parent observes the RAW elapsed time of this call (its
            # bracket measures true wall, so child <= total must hold);
            # scale-up applies only to THIS edge's folded columns
            frames[-1].child_ns += dur
        t = self.tables.table()
        if scale == 1:
            t.record(slot.slot, dur, frame.child_ns)
        else:
            t.record_scaled(slot.slot, dur, frame.child_ns, scale)
        return dur

    # -- public API -----------------------------------------------------------
    def api(self, component: str, name: Optional[str] = None,
            kind: int = KIND_CALL) -> Callable:
        """Decorator: declare `fn` a cross-flow boundary into `component`.

        Slot resolution is per-(caller, callee) edge and cached in a tiny
        dict on the wrapper; after the first call from a given caller the
        hot path does no interning (lazy-PLT analogue).
        """

        def deco(fn: Callable) -> Callable:
            api_name = name or fn.__name__
            slot_cache: Dict[str, SlotInfo] = {}

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                if not self.enabled:
                    return fn(*args, **kwargs)
                caller = self.current_component()
                slot = slot_cache.get(caller)
                if slot is None:
                    slot = self.tables.registry.resolve(
                        caller, component, api_name, kind)
                    slot_cache[caller] = slot
                scale = 1
                if not self.timing:
                    scale = 0
                elif self.sampler is not None:
                    scale = self.sampler.observe(slot.slot)
                if scale == 0:
                    # counting-only / sampled-out: exact count, plus a
                    # lightweight NO-TIMESTAMP frame so nested boundaries
                    # still fold with the true caller (Relation-Aware
                    # Data Folding holds in every mode)
                    self.tables.table().record_count(slot.slot)
                    frames = self._stack.frames
                    frames.append(_Frame(component, api_name, 0))
                    try:
                        return fn(*args, **kwargs)
                    finally:
                        frames.pop()
                frame = self.enter(component, api_name)
                try:
                    return fn(*args, **kwargs)
                finally:
                    self.exit(frame, slot, scale)

            wrapper.__xfa__ = (component, api_name, kind)  # type: ignore
            return wrapper

        return deco

    def wait(self, component: str, name: Optional[str] = None) -> Callable:
        """Decorator for blocking boundaries (paper's Wait category)."""
        return self.api(component, name, kind=KIND_WAIT)

    def wrap(self, fn: Callable, component: str,
             name: Optional[str] = None, kind: int = KIND_CALL) -> Callable:
        """Interpose a callable obtained at runtime (the dlsym analogue)."""
        return self.api(component, name or getattr(fn, "__name__", "anon"),
                        kind)(fn)

    @contextmanager
    def scope(self, component: str, api: str = "scope", kind: int = KIND_CALL):
        """Context-manager boundary for regions that are not function calls."""
        if not self.enabled:
            yield
            return
        caller = self.current_component()
        slot = self.tables.registry.resolve(caller, component, api, kind)
        frame = self.enter(component, api)
        try:
            yield
        finally:
            self.exit(frame, slot)

    def count_event(self, component: str, api: str, n: int = 1,
                    kind: int = KIND_CALL) -> None:
        """Count-only event (no timing bracket)."""
        if not self.enabled:
            return
        caller = self.current_component()
        slot = self.tables.registry.resolve(caller, component, api, kind)
        self.tables.table().record_count(slot.slot, n)

    def record_duration(self, component: str, api: str, dur_ns: float,
                        kind: int = KIND_CALL, n: int = 1) -> None:
        """Fold an externally-measured span into the caller->component.api
        edge — for latency phases whose start and end are observed on
        different control paths and so cannot be bracketed by a decorator
        (a request's queue wait is known only at admit time, its TTFT only
        at first-token time).  `n` > 1 folds n events of dur_ns each (e.g.
        per-token decode latency attributed from one pooled tick).

        Unlike the bracketed decorators, these edges also fold a bounded
        log-bucket latency histogram (core.histogram), so latency-phase
        edges get p50/p95/p99 read-out for free; ordinary call edges stay
        at the five-column v1 footprint.  `record_gauge` deliberately does
        NOT feed histograms — gauge samples are not durations."""
        if not self.enabled:
            return
        caller = self.current_component()
        slot = self.tables.registry.resolve(caller, component, api, kind)
        t = self.tables.table()
        if not self.timing:
            t.record_count(slot.slot, n)
            return
        d = int(dur_ns)
        t.record_n(slot.slot, d, n)
        t.record_hist(slot.slot, d, n)

    def record_gauge(self, component: str, api: str, value: float,
                     kind: int = KIND_CALL) -> None:
        """Fold a dimensionless SAMPLE through the duration columns: count
        accumulates #observations, total_ns the sum, min/max the extremes
        — so mean_ns of the edge is the mean gauge value and the timeline
        view differences per-interval means for free.  Used for state the
        bracket model can't time (serve queue depth at each tick); the
        diagnosis layer reads it as saturation evidence."""
        if not self.enabled:
            return
        caller = self.current_component()
        slot = self.tables.registry.resolve(caller, component, api, kind)
        t = self.tables.table()
        if not self.timing:
            t.record_count(slot.slot)
            return
        t.record(slot.slot, int(value), 0)

    # -- overhead governor --------------------------------------------------
    def set_overhead_budget(self, budget_fraction: float,
                            recalc_every: int = 256,
                            bracket_ns: Optional[float] = None):
        """Attach (or detach, with budget <= 0) the adaptive overhead
        governor: `@api` boundaries whose estimated bracket cost pushes
        total tracer overhead past `budget_fraction` of wall time back
        off to 1-in-k timing (counting stays exact).  Returns the
        attached SamplerController (or None)."""
        if budget_fraction and budget_fraction > 0:
            from .sampler import SamplerController
            self.sampler = SamplerController(budget_fraction,
                                             recalc_every=recalc_every,
                                             bracket_ns=bracket_ns)
        else:
            self.sampler = None
        return self.sampler

    def sample_rates(self) -> Optional[Dict[int, float]]:
        """Per-slot effective sampling rates from the governor (only the
        subsampled slots; None when no governor is attached)."""
        return self.sampler.rates() if self.sampler is not None else None

    # -- lifecycle ----------------------------------------------------------
    def reset(self) -> None:
        """Zero every shadow table IN PLACE, preserving the registry: the
        `@api` wrappers cache SlotInfos interned there, so replacing the
        ShadowTableSet would leave every already-decorated boundary
        recording at indices the fresh registry re-assigns to other
        edges (stale-slot misattribution).  The governor's counters
        reset with the tables."""
        self.tables.reset()
        if self.sampler is not None:
            self.sampler.reset()

    def set_thread_group(self, group: str) -> None:
        """Tag this thread's table with a group (pipeline stage, pool name)."""
        self.tables.table(group=group)


#: process-global tracer — mirrors Scaler being LD_PRELOADed process-wide.
TRACER = Tracer()

api = TRACER.api
wait = TRACER.wait
wrap = TRACER.wrap
scope = TRACER.scope
count_event = TRACER.count_event
record_duration = TRACER.record_duration
record_gauge = TRACER.record_gauge
current_component = TRACER.current_component
set_thread_group = TRACER.set_thread_group


def set_enabled(on: bool) -> None:
    TRACER.enabled = on


def set_timing(on: bool) -> None:
    TRACER.timing = on


def set_overhead_budget(budget_fraction: float, **kwargs):
    return TRACER.set_overhead_budget(budget_fraction, **kwargs)


def reset() -> None:
    TRACER.reset()
