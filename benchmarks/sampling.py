"""Paper Table 6 analogue: can sampling close the gap? (it cannot)

Scaler shows perf at 2x sampling rate changes its output by <1% — sampling
has converged to an answer that still MISSES the short-burst APIs. We
reproduce the phenomenon: a synthetic workload where one API fires in dense
short bursts. The full fold sees every event; step-sampled observation (the
perf model) underestimates the bursty API's share even as the rate rises."""

from __future__ import annotations

import numpy as np

from repro.core.folding import FoldedTable, fold_event_log


def synth_events(n=200_000, seed=0):
    """background API: steady 1us calls; bursty API: rare 40-call bursts of
    0.2us each (short enough to fall between samples)."""
    rng = np.random.default_rng(seed)
    events = []
    t = 0
    while len(events) < n:
        if rng.random() < 0.02:
            for _ in range(40):
                events.append(("app", "lib", "bursty", 200, t))
                t += 200
        events.append(("app", "lib", "steady", 1000, t))
        t += 1000
    return events


def sampled_share(events, period_ns):
    """perf model: at each sample tick attribute the tick to whatever call
    is executing then."""
    hits = {"bursty": 0, "steady": 0}
    next_tick = 0
    for caller, comp, api, dur, t0 in events:
        while next_tick < t0 + dur:
            if next_tick >= t0:
                hits[api] += 1
            next_tick += period_ns
    total = sum(hits.values()) or 1
    return hits["bursty"] / total


def run():
    events = synth_events()
    folded = fold_event_log([(c, m, a, d) for c, m, a, d, _ in events])
    true_share = folded.edges[("app", "lib", "bursty")].total_ns / \
        folded.total_ns()
    rows = [("sampling.true_bursty_share_pct", 100 * true_share,
             "full-trace fold (ground truth)")]
    for rate_hz, label in ((4000, "perf-4000Hz"), (8000, "perf-8000Hz")):
        period = int(1e9 / rate_hz)
        share = sampled_share(events, period)
        rows.append((f"sampling.{label}_share_pct", 100 * share,
                     f"error {100*abs(share-true_share):.2f}pp"))
    rows.append(("sampling.rate_doubling_gain_pp",
                 100 * abs(sampled_share(events, int(1e9 / 8000))
                           - sampled_share(events, int(1e9 / 4000))),
                 "paper: 0.57% avg output diff at 2x rate"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.2f},{note}")
