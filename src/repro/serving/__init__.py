"""repro.serving — layered continuous-batching serving subsystem.

scheduler.py (admission + chunked-prefill budget) -> sampling.py (pooled
per-slot sampling) -> paging.py (page-arena allocator for the paged
KV-cache pool) -> engine.py (per-slot-position decode pool, background
serving thread, client handles).  See engine.py for the full design notes.
"""

from .engine import Request, ServingEngine
from .paging import PageAllocator
from .sampling import GREEDY, PooledSampler, SamplingParams, sample_tokens
from .scheduler import Scheduler, Slot
from .workload import latency_stats, run_workload

__all__ = [
    "Request", "ServingEngine",
    "PageAllocator",
    "GREEDY", "PooledSampler", "SamplingParams", "sample_tokens",
    "Scheduler", "Slot",
    "latency_stats", "run_workload",
]
