"""Integration: the trainer loop (loss goes down, checkpoint-resume is
bit-exact in expectation), the serving engine (continuous batching), and the
end-to-end XFA session."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_smoke
from repro.configs.base import ServeConfig, TrainConfig
from repro.core.session import XFASession
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.runtime.trainer import Trainer
from repro.serving.engine import ServingEngine


def small_cfg():
    return dataclasses.replace(get_smoke("tinyllama_1_1b"),
                               n_layers=2, d_model=64, d_ff=128, vocab=512,
                               n_heads=2, n_kv_heads=2, head_dim=32)


class TestTrainer:
    def test_loss_decreases(self):
        """Overfit one fixed batch — deterministic memorization signal."""
        from repro.runtime.trainer import init_train_state, make_train_step
        cfg = small_cfg()
        model = build_model(cfg, impl="ref")
        tcfg = TrainConfig(total_steps=40, warmup_steps=2, ckpt_interval=0,
                           learning_rate=1e-2)
        step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        batch = {k: jnp.asarray(v) for k, v in
                 SyntheticLMData(cfg, 4, 32).generate(0).items()}
        state = init_train_state(model, jax.random.key(0), tcfg)
        table = model.table()
        losses = []
        for _ in range(30):
            state, m, table = step(state, batch, table)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses[::6]

    def test_checkpoint_resume_continues(self, tmp_path):
        cfg = small_cfg()
        model = build_model(cfg, impl="ref")
        tcfg = TrainConfig(total_steps=10, warmup_steps=0, ckpt_interval=5,
                           learning_rate=1e-3)
        # run 1: 10 steps straight
        t1 = Trainer(model, tcfg, CheckpointManager(str(tmp_path / "a")))
        s1, m1 = t1.run(jax.random.key(0), SyntheticLMData(cfg, 2, 32),
                        n_steps=10, resume=False)
        # run 2: 5 steps, "crash", resume to 10 (same data stream)
        mgr = CheckpointManager(str(tmp_path / "b"))
        t2 = Trainer(model, tcfg, mgr)
        t2.run(jax.random.key(0), SyntheticLMData(cfg, 2, 32), n_steps=5,
               resume=False)
        assert mgr.latest_step() is not None
        t3 = Trainer(model, tcfg, mgr)
        s3, m3 = t3.run(jax.random.key(0), SyntheticLMData(cfg, 2, 32),
                        n_steps=10, resume=True)
        # resumed run reaches the same step counter and a finite close loss
        assert int(s3["opt"]["step"]) == int(s1["opt"]["step"])
        assert abs(m3["loss"] - m1["loss"]) < 0.2

    def test_session_report_has_flows(self, tmp_path):
        cfg = small_cfg()
        model = build_model(cfg, impl="ref")
        tcfg = TrainConfig(ckpt_interval=0)
        sess = XFASession(device_spec=model.fold_spec)
        trainer = Trainer(model, tcfg, CheckpointManager(str(tmp_path)),
                          session=sess)
        trainer.run(jax.random.key(0), SyntheticLMData(cfg, 2, 32),
                    n_steps=3, resume=False)
        rep = sess.report()
        assert rep.n_steps == 3
        comps = rep.folded.components()
        assert "runtime" in comps and "data" in comps

    def test_microbatched_step_matches_single(self):
        """grad accumulation over k microbatches == one big batch (linearity
        of gradients; AdamW applied once either way)."""
        from repro.runtime.trainer import init_train_state, make_train_step
        cfg = small_cfg()
        model = build_model(cfg, impl="ref")
        data = SyntheticLMData(cfg, 4, 32)
        batch = {k: jnp.asarray(v) for k, v in data.generate(0).items()}
        outs = []
        for micro in (1, 2):
            tcfg = TrainConfig(microbatches=micro, warmup_steps=0,
                               learning_rate=1e-3)
            step = make_train_step(model, tcfg)
            state = init_train_state(model, jax.random.key(0), tcfg)
            state, m, _ = step(state, batch, model.table())
            outs.append(state["params"])
        for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-4, rtol=1e-3)


class TestServing:
    def test_continuous_batching_completes_all(self):
        cfg = small_cfg()
        model = build_model(cfg, impl="ref")
        params = model.init(jax.random.key(0))
        engine = ServingEngine(model, params,
                               ServeConfig(max_batch=2, max_seq_len=64))
        rng = np.random.default_rng(0)
        reqs = [engine.submit(rng.integers(0, cfg.vocab, n), 4)
                for n in (5, 9, 7)]   # 3 requests, 2 slots: queueing needed
        done = engine.run_until_drained()
        assert len(done) == 3
        for r in done:
            assert r.done and 1 <= len(r.output) <= 4
            assert r.first_token_at is not None

    def test_greedy_matches_manual_decode(self):
        """Engine output == manual prefill+decode for a single request."""
        cfg = small_cfg()
        model = build_model(cfg, impl="ref")
        params = model.init(jax.random.key(0))
        prompt = np.asarray([3, 5, 7, 11, 13], np.int32)
        engine = ServingEngine(model, params,
                               ServeConfig(max_batch=1, max_seq_len=64,
                                           eos_token=-1))
        req = engine.submit(prompt, max_new_tokens=4)
        engine.run_until_drained()

        cache = model.init_cache(1, 64)
        table = model.table()
        logits, cache, table = model.prefill(
            params, {"tokens": jnp.asarray(prompt[None])}, table, cache)
        toks = [int(jnp.argmax(logits[0]))]
        pos = len(prompt)
        for _ in range(3):
            lg, cache, table = model.decode_step(
                params, jnp.asarray([toks[-1]], jnp.int32), table, cache,
                jnp.int32(pos))
            toks.append(int(jnp.argmax(lg[0])))
            pos += 1
        assert req.output == toks
