"""Built-in cross-flow detectors — automated findings over the FlowGraph.

Scaler's claim is that XFA *detects* performance issues, not just renders
flow matrices; each detector here encodes one pathology as a deterministic
rule over the diagnosis context (merged graph, per-shard graphs, snapshot
rings, optional baseline run and calibrated noise bands) and emits
structured `Finding`s with a severity and the evidence that fired it.

Detectors are small and independent (the ScALPEL argument: adaptive,
lightweight probes, not one monolithic analysis); adding one means
implementing the two-member `Detector` protocol and appending to
`builtin_detectors()`.

Built-ins:

  wait-dominance       a component's inbound Wait share exceeds bound
                       (Scaler §3.5 Wait category)
  hot-edge             one edge owns almost all of a component's self time
  rank-imbalance       straggler rank/replica across a run's shards
  queue-saturation     serve queue_wait per-interval mean grows along the
                       ring (admission can't keep up with arrivals)
  cache-pressure       the paged KV-cache pool is the bottleneck: the
                       cache_pages_in_use gauge approaches capacity while
                       queue depth grows — PAGES, not slots, are the
                       saturation resource (add pages or shrink max_new,
                       not max_batch)
  drift-regression     per-interval delta-of-deltas vs a baseline run
                       trends up (cost grows run-over-run AND over time)
  call-amplification   count blowup along a caller -> B -> callee chain
  slo-violation        deadline-miss rate against the per-request deadlines
                       the serving engine folds (deadline_met/deadline_miss
                       count edges), with e2e latency percentiles from the
                       schema-v2 histograms as evidence
  sampling-backoff     informational: which edges the adaptive overhead
                       governor (core.sampler) subsampled, at what
                       effective rate — time columns on those edges are
                       unbiased scale-ups, counts stay exact
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Protocol, Sequence

import numpy as np

from ..core.histogram import jitter_ns as _hist_jitter, percentile_ns
from ..core.shadow import KIND_CALL, KIND_WAIT
from .calibrate import Thresholds
from .graph import FlowGraph, edge_label

SEVERITIES = ("info", "warn", "crit")


def severity_rank(sev: str) -> int:
    return SEVERITIES.index(sev)


@dataclass(frozen=True)
class Finding:
    """One structured diagnosis result."""

    detector: str
    severity: str          # info | warn | crit
    subject: str           # "component:runtime" / "edge:app -> x.y" / ...
    message: str
    evidence: Dict[str, Any] = field(default_factory=dict)

    def sort_key(self):
        return (-severity_rank(self.severity), self.detector, self.subject)

    def to_json(self) -> dict:
        return {"detector": self.detector, "severity": self.severity,
                "subject": self.subject, "message": self.message,
                "evidence": self.evidence}


@dataclass
class DiagnosisContext:
    """Everything PR 1-3 left behind for one run, in analyzable form."""

    graph: FlowGraph
    shard_graphs: Dict[str, FlowGraph] = field(default_factory=dict)
    timelines: List = field(default_factory=list)       # [ShardTimeline]
    baseline_graph: Optional[FlowGraph] = None
    baseline_timelines: List = field(default_factory=list)
    thresholds: Optional[Thresholds] = None
    run_dir: str = ""

    def noise_ns(self, key, fld: str = "total_ns") -> float:
        return self.thresholds.noise_ns(key, fld) if self.thresholds else 0.0


class Detector(Protocol):
    name: str

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        ...  # pragma: no cover - protocol


def _pct(x: float) -> str:
    return f"{100.0 * x:.0f}%"


def _ms(ns: float) -> str:
    return f"{ns / 1e6:.2f}ms"


@dataclass
class WaitDominance:
    """Component whose inbound time is mostly Wait (not useful work)."""

    name: str = "wait-dominance"
    warn_share: float = 0.4
    crit_share: float = 0.7
    min_total_ns: int = 1_000_000

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        out = []
        for comp in ctx.graph.components():
            node = ctx.graph.nodes[comp]
            if node.in_total_ns < self.min_total_ns:
                continue
            share = node.wait_share
            if share < self.warn_share:
                continue
            waits = sorted(ctx.graph.in_edges(comp, kind=KIND_WAIT),
                           key=lambda e: -e.total_ns)
            top = waits[0]
            out.append(Finding(
                self.name,
                "crit" if share >= self.crit_share else "warn",
                f"component:{comp}",
                f"{_pct(share)} of component '{comp}' time "
                f"({_ms(node.in_total_ns)}) is Wait; top wait edge "
                f"{edge_label(top.key)} ({_ms(top.total_ns)})",
                evidence={"wait_share": share,
                          "wait_ns": node.wait_ns,
                          "in_total_ns": node.in_total_ns,
                          "top_wait_edge": list(top.key),
                          "top_wait_ns": top.total_ns}))
        return out


@dataclass
class HotEdgeConcentration:
    """One edge owns (almost) all of a component's self time."""

    name: str = "hot-edge"
    warn_share: float = 0.8
    crit_share: float = 0.95
    min_edges: int = 2
    min_self_ns: int = 1_000_000

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        out = []
        for comp in ctx.graph.components():
            calls = ctx.graph.in_edges(comp, kind=KIND_CALL)
            if len(calls) < self.min_edges:
                continue
            total_self = sum(max(e.self_ns, 0) for e in calls)
            if total_self < self.min_self_ns:
                continue
            top = max(calls, key=lambda e: e.self_ns)
            share = max(top.self_ns, 0) / total_self
            if share < self.warn_share:
                continue
            out.append(Finding(
                self.name,
                "crit" if share >= self.crit_share else "warn",
                f"edge:{edge_label(top.key)}",
                f"edge {edge_label(top.key)} holds {_pct(share)} of "
                f"component '{comp}' self time ({_ms(top.self_ns)} of "
                f"{_ms(total_self)}) across {len(calls)} edges",
                evidence={"share": share, "self_ns": top.self_ns,
                          "component_self_ns": total_self,
                          "count": top.count, "n_edges": len(calls)}))
        return out


@dataclass
class RankImbalance:
    """Straggler detection across a run's shards (ranks / replicas)."""

    name: str = "rank-imbalance"
    warn_rel: float = 0.25
    crit_rel: float = 0.5
    min_shards: int = 2
    min_total_ns: int = 1_000_000

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        shards = ctx.shard_graphs
        if len(shards) < self.min_shards:
            return []
        totals = {stem: g.total_ns() for stem, g in sorted(shards.items())}
        mean = sum(totals.values()) / len(totals)
        if mean < self.min_total_ns:
            return []
        straggler = max(sorted(totals), key=lambda s: totals[s])
        rel = (totals[straggler] - mean) / mean if mean else 0.0
        if rel < self.warn_rel:
            return []
        # the component with the widest per-shard spread localizes WHERE
        # the straggler loses its time
        comps = sorted({c for g in shards.values() for c in g.components()})
        spread = {}
        for c in comps:
            per = [shards[s].nodes[c].in_total_ns if c in shards[s].nodes
                   else 0 for s in sorted(shards)]
            spread[c] = max(per) - min(per)
        culprit = max(comps, key=lambda c: (spread[c], c)) if comps else ""
        return [Finding(
            self.name,
            "crit" if rel >= self.crit_rel else "warn",
            f"shard:{straggler}",
            f"shard '{straggler}' folded {_ms(totals[straggler])}, "
            f"{_pct(rel)} above the {len(totals)}-shard mean "
            f"({_ms(mean)}); widest spread in component '{culprit}'",
            evidence={"rel_above_mean": rel, "shard_total_ns": totals,
                      "mean_ns": mean, "widest_component": culprit})]


@dataclass
class QueueSaturation:
    """Serving queue wait growing along a ring's sequence numbers."""

    name: str = "queue-saturation"
    api: str = "queue_wait"
    warn_ratio: float = 2.0
    crit_ratio: float = 4.0
    min_intervals: int = 3
    tolerance: float = 0.1     # per-interval dips smaller than this are ok
    min_mean_ns: float = 1_000.0

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        out = []
        for tl in ctx.timelines:
            # a trimmed ring's first "delta" is a cumulative fold, not an
            # interval (cf. calibrate_ring) — it would dilute the ratio
            start = 0 if (tl.seqs and tl.seqs[0] == 1) else 1
            for key in tl.edges():
                if key[2] != self.api:
                    continue
                means = [m for m in tl.deltas(key, "mean_ns")[start:]
                         if m > 0]
                if len(means) < self.min_intervals:
                    continue
                if means[0] < self.min_mean_ns:
                    continue
                rising = all(b >= a * (1.0 - self.tolerance)
                             for a, b in zip(means, means[1:]))
                ratio = means[-1] / means[0]
                if not rising or ratio < self.warn_ratio:
                    continue
                # queue_depth gauge as corroborating evidence; its caller
                # differs from queue_wait's (engine loop vs admit bracket)
                # so match on (component, api) only
                depth = None
                for dkey in tl.edges():
                    if dkey[1] == key[1] and dkey[2] == "queue_depth":
                        depth = tl.deltas(dkey, "mean_ns")
                        break
                out.append(Finding(
                    self.name,
                    "crit" if ratio >= self.crit_ratio else "warn",
                    f"edge:{edge_label(key)}",
                    f"per-interval mean of {edge_label(key)} grew "
                    f"{ratio:.1f}x across {len(means)} intervals of ring "
                    f"'{tl.stem}' ({_ms(means[0])} -> {_ms(means[-1])}): "
                    f"admission is falling behind arrivals",
                    evidence={"ratio": ratio, "means_ns": means,
                              "shard": tl.stem,
                              "queue_depth_means": depth}))
        return out


@dataclass
class CachePressure:
    """Paged serving cache near exhaustion while the queue backs up.

    Reads the engine's paged-pool gauges (per-interval means along the
    snapshot ring): `cache_pages_in_use` against `cache_pages_capacity`
    — the usable arena the allocator reports — corroborated by a growing
    `queue_depth`.  Fires only when BOTH hold: high page utilization
    with a draining queue is a healthy full pipe, and a growing queue
    with free pages is some other bottleneck (see queue-saturation).
    The point of the finding is the RESOURCE: admission stalls on pages,
    so the fix is more pages / smaller max_new_tokens, not more slots."""

    name: str = "cache-pressure"
    in_use_api: str = "cache_pages_in_use"
    capacity_api: str = "cache_pages_capacity"
    depth_api: str = "queue_depth"
    warn_util: float = 0.80
    crit_util: float = 0.95
    min_intervals: int = 3
    tolerance: float = 0.1     # queue dips smaller than this still "grow"

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        out = []
        for tl in ctx.timelines:
            # a trimmed ring's first "delta" is a cumulative fold, not an
            # interval (cf. calibrate_ring)
            start = 0 if (tl.seqs and tl.seqs[0] == 1) else 1
            for key in tl.edges():
                if key[2] != self.in_use_api:
                    continue
                used = [m for m in tl.deltas(key, "mean_ns")[start:]
                        if m >= 0]
                if len(used) < self.min_intervals:
                    continue
                # capacity/queue gauges fold from the engine loop like
                # in_use but under their own api; match on component
                capacity = depth = None
                for okey in tl.edges():
                    if okey[1] != key[1]:
                        continue
                    if okey[2] == self.capacity_api:
                        caps = [m for m in tl.deltas(okey, "mean_ns")[start:]
                                if m > 0]
                        capacity = caps[-1] if caps else None
                    elif okey[2] == self.depth_api:
                        depth = tl.deltas(okey, "mean_ns")[start:]
                if not capacity:
                    continue
                util = used[-1] / capacity
                if util < self.warn_util:
                    continue
                growing = (depth is not None
                           and len(depth) >= self.min_intervals
                           and all(b >= a * (1.0 - self.tolerance)
                                   for a, b in zip(depth, depth[1:]))
                           and depth[-1] > depth[0])
                if not growing:
                    continue
                out.append(Finding(
                    self.name,
                    "crit" if util >= self.crit_util else "warn",
                    f"edge:{edge_label(key)}",
                    f"KV-cache pages are the saturation resource on ring "
                    f"'{tl.stem}': {_pct(util)} of {capacity:.0f} usable "
                    f"pages in use while queue depth grew "
                    f"{depth[0]:.1f} -> {depth[-1]:.1f} — admission is "
                    f"gated by free pages, not slots (grow "
                    f"max_cache_pages or cut max_new_tokens; adding "
                    f"max_batch slots will not help)",
                    evidence={"util": util, "capacity_pages": capacity,
                              "in_use_means": used,
                              "queue_depth_means": list(depth),
                              "shard": tl.stem}))
        return out


@dataclass
class DriftRegression:
    """Cross-run drift: per-interval cost grows vs baseline, and keeps
    growing over the run (delta-of-deltas trending up)."""

    name: str = "drift-regression"
    warn_growth: float = 0.25
    crit_growth: float = 1.0
    min_intervals: int = 3
    min_total_ns: float = 1_000_000.0

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        if not ctx.baseline_timelines or not ctx.timelines:
            return []
        from ..profile.timeline import pair_timelines
        out = []
        for td in pair_timelines(ctx.baseline_timelines, ctx.timelines):
            if len(td) < self.min_intervals:
                continue
            for key in td.edges():
                da = td.deltas(td.a, key, "total_ns")     # baseline
                db = td.deltas(td.b, key, "total_ns")     # candidate
                dd = [y - x for x, y in zip(da, db)]
                base_total = sum(da)
                if max(base_total, sum(db)) < self.min_total_ns:
                    continue
                noise = ctx.noise_ns(key, "total_ns")
                if any(v < -noise for v in dd):
                    continue                      # not a consistent growth
                if dd[-1] <= dd[0] + noise:
                    continue                      # flat offset, not a trend
                growth = (sum(dd) / base_total) if base_total > 0 \
                    else float("inf")
                if growth < self.warn_growth:
                    continue
                out.append(Finding(
                    self.name,
                    "crit" if growth >= self.crit_growth else "warn",
                    f"edge:{edge_label(key)}",
                    f"{edge_label(key)} per-interval cost is "
                    f"{_pct(growth)} above baseline across {len(dd)} "
                    f"aligned intervals and TRENDING UP "
                    f"({_ms(dd[0])} -> {_ms(dd[-1])} extra per interval)",
                    evidence={"growth": growth if growth != float("inf")
                              else None,
                              "delta_of_deltas_ns": dd,
                              "baseline_deltas_ns": da,
                              "candidate_deltas_ns": db,
                              "noise_floor_ns": noise,
                              "shards": [td.a.stem, td.b.stem]}))
        return out


@dataclass
class CallAmplification:
    """Count ratio blowup along a caller -> B -> callee chain: each call
    into B fans out into `ratio` calls out of B (N+1-query-style)."""

    name: str = "call-amplification"
    warn_ratio: float = 100.0
    crit_ratio: float = 1000.0
    min_count: int = 1000

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        out = []
        for mid in ctx.graph.components():
            ins = [e for e in ctx.graph.in_edges(mid, kind=KIND_CALL)
                   if e.count > 0]
            if not ins:
                continue
            in_total = sum(e.count for e in ins)
            # the ratio denominator is ALL calls into B — pairing each
            # outbound edge with its single smallest inbound edge would
            # manufacture blowups out of rare side entrances
            top_in = max(ins, key=lambda e: (e.count, e.key))
            worst = None
            for e2 in ctx.graph.out_edges(mid, kind=KIND_CALL):
                if e2.count < self.min_count or e2.key == top_in.key:
                    continue
                ratio = e2.count / in_total
                if ratio >= self.warn_ratio and \
                        (worst is None or ratio > worst[0]):
                    worst = (ratio, e2)
            if worst is None:
                continue
            ratio, e2 = worst
            out.append(Finding(
                self.name,
                "crit" if ratio >= self.crit_ratio else "warn",
                f"chain:{edge_label(top_in.key)} => {e2.component}.{e2.api}",
                f"{in_total} calls into '{mid}' (top: "
                f"{edge_label(top_in.key)}) amplify into {ratio:.0f}x "
                f"calls {edge_label(e2.key)} ({e2.count} total)",
                evidence={"ratio": ratio, "in_count": in_total,
                          "out_count": e2.count,
                          "top_in_edge": list(top_in.key),
                          "out_edge": list(e2.key)}))
        return out


@dataclass
class SloViolation:
    """Deadline-miss rate against per-request deadlines.

    The serving engine (serving/engine.py) folds one `deadline_met` or
    `deadline_miss` count event per finished request that carried a
    deadline (Request.deadline_ms / ServeConfig.deadline_ms); this
    detector reads those counts off the merged graph and converts the
    miss RATE into severity — an SLO is a rate contract, not a one-off.
    The component's e2e latency histogram (schema v2) supplies the
    percentile spread as evidence, so a firing finding shows WHERE the
    tail sits, not just that it crossed."""

    name: str = "slo-violation"
    component: str = "serve"
    miss_api: str = "deadline_miss"
    met_api: str = "deadline_met"
    latency_api: str = "e2e"
    warn_rate: float = 0.01
    crit_rate: float = 0.05
    min_tracked: int = 10

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        ins = ctx.graph.in_edges(self.component)
        missed = sum(e.count for e in ins if e.api == self.miss_api)
        met = sum(e.count for e in ins if e.api == self.met_api)
        tracked = missed + met
        if tracked < self.min_tracked:
            return []
        rate = missed / tracked
        if rate < self.warn_rate:
            return []
        evidence: Dict[str, Any] = {"miss_rate": rate, "missed": missed,
                                    "tracked": tracked}
        spread = ""
        lat = [e.hist for e in ins
               if e.api == self.latency_api and e.hist is not None]
        if lat:
            h = np.sum(lat, axis=0, dtype=np.uint64) if len(lat) > 1 \
                else lat[0]
            p50, p95, p99 = (percentile_ns(h, q)
                             for q in (0.50, 0.95, 0.99))
            evidence.update({"e2e_p50_ns": p50, "e2e_p95_ns": p95,
                             "e2e_p99_ns": p99,
                             "e2e_jitter_ns": _hist_jitter(h)})
            spread = (f"; e2e p50/p95/p99 = {_ms(p50)}/{_ms(p95)}/"
                      f"{_ms(p99)} (jitter {_ms(p99 - p50)})")
        return [Finding(
            self.name,
            "crit" if rate >= self.crit_rate else "warn",
            f"component:{self.component}",
            f"{missed} of {tracked} deadline-tracked requests "
            f"({_pct(rate)}) missed their deadline in component "
            f"'{self.component}'{spread}",
            evidence=evidence)]


@dataclass
class SamplingBackoff:
    """Informational read-out of the overhead governor's sampling state.

    Never warns on its own — back-off is the governor doing its job —
    but every diagnosis that reasons about time columns should see when
    those columns are scaled estimates rather than full traces.  Fires
    one info finding per subsampled edge (rate below `max_rate`), with
    the effective rate and the exact count as evidence."""

    name: str = "sampling-backoff"
    max_rate: float = 1.0
    min_count: int = 1

    def detect(self, ctx: DiagnosisContext) -> List[Finding]:
        out = []
        for key in sorted(ctx.graph.edges):
            e = ctx.graph.edges[key]
            if e.sample_rate is None or e.sample_rate >= self.max_rate \
                    or e.count < self.min_count:
                continue
            k = round(1.0 / e.sample_rate) if e.sample_rate > 0 else 0
            out.append(Finding(
                self.name, "info", f"edge:{edge_label(key)}",
                f"overhead governor subsampled {edge_label(key)} at "
                f"effective rate {e.sample_rate:.4f} (~1-in-{k}); its "
                f"{e.count} calls counted exactly, time columns are "
                f"unbiased scale-ups",
                evidence={"sample_rate": e.sample_rate, "count": e.count,
                          "total_ns": e.total_ns}))
        return out


def detector_classes() -> Dict[str, type]:
    """Shipped detector classes keyed by their canonical name."""
    classes = (WaitDominance, HotEdgeConcentration, RankImbalance,
               QueueSaturation, CachePressure, DriftRegression,
               CallAmplification, SloViolation, SamplingBackoff)
    return {cls().name: cls for cls in classes}


def builtin_detectors(**overrides) -> List[Detector]:
    """The shipped detector set.  `overrides` maps a detector name (with
    '-' or '_') to a dict of constructor kwargs, so CLI/config can retune
    any rule without redefining it.  Unknown detector names or constructor
    parameters raise ValueError — the CLI contract surfaces them as usage
    errors (exit 2), never as a silently-ignored misspelled threshold."""
    classes = detector_classes()
    norm = {k.replace("_", "-"): v for k, v in overrides.items()}
    unknown = sorted(set(norm) - set(classes))
    if unknown:
        raise ValueError(
            f"unknown detector(s): {', '.join(unknown)}; "
            f"valid: {', '.join(sorted(classes))}")
    out = []
    for name, cls in classes.items():
        kwargs = dict(norm.get(name, {}))
        params = {f.name for f in dataclasses.fields(cls)} - {"name"}
        bad = sorted(set(kwargs) - params)
        if bad:
            raise ValueError(
                f"detector {name!r}: unknown parameter(s) "
                f"{', '.join(bad)}; valid: {', '.join(sorted(params))}")
        out.append(cls(**kwargs))
    return out


def run_detectors(ctx: DiagnosisContext,
                  detectors: Optional[Sequence[Detector]] = None
                  ) -> List[Finding]:
    """Run detectors and return findings in deterministic order (severity
    desc, then detector name, then subject)."""
    findings: List[Finding] = []
    for det in (builtin_detectors() if detectors is None else detectors):
        found = det.detect(ctx)
        for f in found:
            if f.severity not in SEVERITIES:
                raise ValueError(f"{det.name}: bad severity {f.severity!r}")
        findings.extend(found)
    findings.sort(key=Finding.sort_key)
    return findings
