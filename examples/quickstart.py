"""Quickstart: build a model, train a few steps, read the XFA report.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.core.session import XFASession
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.runtime.trainer import Trainer


def main():
    cfg = get_smoke("tinyllama_1_1b")
    model = build_model(cfg, impl="auto")
    tcfg = TrainConfig(total_steps=5, ckpt_interval=0, microbatches=1)
    trainer = Trainer(model, tcfg, CheckpointManager("artifacts/quickstart"),
                      session=XFASession(device_spec=model.fold_spec))
    data = SyntheticLMData(cfg, batch=4, seq_len=64)
    state, metrics = trainer.run(jax.random.key(0), data, n_steps=5,
                                 resume=False)
    print(f"final metrics: {metrics}")
    report = trainer.session.report()
    print(report.render(components=("app", "runtime")))


if __name__ == "__main__":
    main()
