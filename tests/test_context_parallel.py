"""Context-parallel split-K decode: exactness vs the unsharded oracle.

Runs under a multi-device CPU mesh in a SUBPROCESS (the 8-device XLA flag
must be set before jax initializes; the main test process keeps 1 device)."""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.kernels import ref
    from repro.parallel.context import context_parallel_decode

    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, D = 2, 8, 4, 256, 32
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    for pos in (S - 1, 100, 63):
        want = ref.decode_attention(
            q, k, v, kv_len=jnp.full((B,), pos + 1, jnp.int32))
        got = context_parallel_decode(q, k, v, jnp.int32(pos), mesh,
                                      context_axis="data",
                                      head_axis="model", impl="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
    # the wire win: ensure no big gathers — lower and count collective bytes
    from repro.core.hlo_analysis import analyze_module
    f = jax.jit(lambda q, k, v, p: context_parallel_decode(
        q, k, v, p, mesh, impl="ref"))
    mc = analyze_module(f.lower(q, k, v, jnp.int32(200)).compile().as_text(),
                        mesh_axes={"data": 4, "model": 2})
    kv_bytes = 2 * B * Hkv * S * D * 4
    assert mc.wire_bytes < kv_bytes / 4, (mc.wire_bytes, kv_bytes)
    print("OK", mc.wire_bytes)
""")


def test_context_parallel_decode_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=300,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
