"""Slot admission + chunked-prefill budgeting for the serving engine.

The scheduler owns the WAITING side of continuous batching: the FCFS
queue of submitted requests, the fixed slot pool's occupancy bookkeeping
(which request holds which cache row, at what depth, with how much
prompt left to feed), and the per-tick admission decision.

Admission is iteration-level (vLLM-style): any tick with free slots may
admit, bounded by a chunked-prefill token budget so a burst of long
prompts cannot stall slots that are already decoding (Sarathi-style
prefill/decode interference control).  A prompt is bulk-prefilled only
up to `prefill_chunk` tokens; the tail is fed through the pooled decode
stream one token per tick — each slot's cache row advances at its own
position — which keeps admission cost O(chunk) instead of O(prompt).

Fairness: strict FCFS.  The budget never reorders the queue, and the
head-of-line request always fits once a slot is free, so one huge prompt
is delayed (by the budget) but never starved.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.configs.base import ServeConfig


@dataclasses.dataclass
class Slot:
    """One row of the batched cache pool."""
    request: Optional[object] = None   # serving.engine.Request (duck-typed)
    pos: int = 0                       # next cache position to write
    pending: Deque[int] = dataclasses.field(default_factory=deque)

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def prefilling(self) -> bool:
        """Still feeding prompt-tail tokens through the decode stream."""
        return self.request is not None and bool(self.pending)


class Scheduler:
    """Iteration-level admission control over a fixed slot pool."""

    def __init__(self, scfg: ServeConfig) -> None:
        self.scfg = scfg
        self.waiting: Deque = deque()
        self.slots: List[Slot] = [Slot() for _ in range(scfg.max_batch)]

    # -- queue side ---------------------------------------------------------
    def add(self, req) -> None:
        self.waiting.append(req)

    def has_waiting(self) -> bool:
        return bool(self.waiting)

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.active())

    # -- pool side ----------------------------------------------------------
    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def admit_cost(self, req) -> int:
        """Bulk-prefill tokens this admission will actually consume —
        after the engine's truncation to fit the cache row (charging the
        raw prompt length would overbill truncated requests and block
        cheap neighbours for no real work)."""
        limit = self.scfg.max_seq_len \
            - getattr(req, "max_new_tokens", 0) - 1
        plen = min(len(req.prompt), max(limit, 1))
        chunk = self.scfg.prefill_chunk or plen
        return max(1, min(plen, chunk))

    def schedule(self) -> List[Tuple[int, object]]:
        """Admissions for this tick: FCFS into free slots under the
        prefill token budget.  The first admission of a tick always fits
        regardless of its cost (no starvation of long prompts)."""
        budget = self.scfg.prefill_budget_tokens
        out: List[Tuple[int, object]] = []
        spent = 0
        free = self.free_slots()
        while free and self.waiting:
            cost = self.admit_cost(self.waiting[0])
            if out and budget and spent + cost > budget:
                break
            out.append((free.pop(0), self.waiting.popleft()))
            spent += cost
        return out

    def bind(self, idx: int, req, pos: int, pending) -> None:
        """Occupy slot `idx`: cache holds `pos` tokens, `pending` is the
        unprefilled prompt tail to merge into the decode stream."""
        self.slots[idx] = Slot(request=req, pos=pos, pending=deque(pending))

    def release(self, idx: int) -> None:
        self.slots[idx] = Slot()

    def pos_vector(self) -> np.ndarray:
        """[max_batch] int32 per-slot cache depths (free slots at 0)."""
        return np.asarray([s.pos for s in self.slots], np.int32)
