"""Paper Table 5 analogue: memory is O(#edges), not O(#events).

Scaler: 15.5% memory overhead because Relation-Aware Data Folding never
appends. We fold a synthetic stream and compare the shadow-table bytes with
what an append-style event log (ltrace/perf model) would need, at several
stream lengths — the fold's slope over events must be ZERO.

Serving arm (--serving): the same economy argument for the KV-cache.
The contiguous pool charges every admitted request a full
[max_seq_len]-row cache; the paged pool charges the pages it actually
touches.  At EQUAL arena bytes (max_batch x max_seq_len rows vs
max_cache_pages x page_size rows) a mixed 32/2048-token workload is
driven through both engines and the peak number of CONCURRENTLY
admitted requests is compared — the paged pool must admit at least
--assert-admission-ratio (CI: 4.0) times more, and resident cache bytes
per admitted request are reported for both.  --profile-dir additionally
writes the paged run's XFA shard so CI can assert the
serve.cache_pages_in_use gauge round-trips through
`repro.profile query --kind serve`."""

from __future__ import annotations

import argparse
import sys

from repro.core import Tracer
from repro.core.folding import FoldedTable

EDGES = [("app", "glibc", f"api{i}") for i in range(64)] + \
        [("moe", "glibc", f"api{i}") for i in range(32)]

EVENT_BYTES = 32  # (caller_id, callee_id, api_id, t_start, t_end) packed


def run():
    rows = []
    t = Tracer()
    fns = {}
    for caller, comp, api in EDGES:
        slot = t.tables.registry.resolve(caller, comp, api)
        fns[(caller, comp, api)] = slot
    prev = None
    for n_events in (10_000, 100_000, 1_000_000):
        table = t.tables.table()
        for i in range(n_events if prev is None else n_events - prev):
            slot = fns[EDGES[i % len(EDGES)]]
            table.record(slot.slot, 100)
        prev = n_events
        fold_bytes = t.tables.nbytes()
        log_bytes = n_events * EVENT_BYTES
        rows.append((f"memory.fold_bytes@{n_events}", fold_bytes,
                     f"append log would be {log_bytes}"))
        rows.append((f"memory.ratio@{n_events}", log_bytes / fold_bytes,
                     "x smaller than a log"))
    # the paper's accuracy claim: the fold still has every edge
    folded = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
    assert len(folded) == len(EDGES), "fold lost edges!"
    rows.append(("memory.edges_preserved", len(folded), "relation-aware"))
    return rows


def _cache_bytes(tree) -> int:
    import jax
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def run_serving(profile_dir: str = "") -> list:
    """Contiguous vs paged pool at equal arena bytes, mixed-length load."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_smoke
    from repro.configs.base import ServeConfig
    from repro.models import build_model
    from repro.serving import ServingEngine

    MAX_SEQ = 2048
    PAGE = 64
    CONTIG_SLOTS = 4                       # 4 x 2048 rows
    PAGES = CONTIG_SLOTS * MAX_SEQ // PAGE  # same rows as the contiguous pool
    MAX_NEW = 8

    cfg = dataclasses.replace(get_smoke("tinyllama_1_1b"), n_layers=2,
                              vocab=256)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    # mixed workload: many short requests + a few full-context ones — the
    # shape where per-slot worst-case reservation hurts most
    prompts = [rng.integers(3, 250, size=32).astype(np.int32)
               for _ in range(24)]
    prompts += [rng.integers(3, 250, size=2000).astype(np.int32)
                for _ in range(2)]

    def drive(paged: bool):
        scfg = ServeConfig(
            max_batch=32 if paged else CONTIG_SLOTS, max_seq_len=MAX_SEQ,
            prefill_chunk=512, eos_token=-1,   # no early EOS: peak is exact
            page_size=PAGE, max_cache_pages=PAGES if paged else 0,
            profile_dir=profile_dir if paged else "",
            profile_interval_ticks=1)
        eng = ServingEngine(model, params, scfg)
        assert eng.paged == paged
        for p in prompts:
            eng.submit(p, max_new_tokens=MAX_NEW)
        peak = 0
        for _ in range(10_000):
            n = eng.step()
            peak = max(peak, n)
            if n == 0 and not eng.scheduler.has_waiting():
                break
        if paged and profile_dir:
            eng.write_profile_shard()
        return peak, _cache_bytes(eng.cache)

    contig_peak, contig_bytes = drive(False)
    paged_peak, paged_bytes = drive(True)
    assert contig_bytes == paged_bytes, "arms must compare equal arenas"
    rows = [
        ("memory.serve_arena_bytes", float(contig_bytes),
         f"{CONTIG_SLOTS}x{MAX_SEQ} rows == {PAGES}x{PAGE} rows"),
        ("memory.serve_contig_peak_admitted", float(contig_peak),
         "slot-gated admission"),
        ("memory.serve_paged_peak_admitted", float(paged_peak),
         "page-gated admission"),
        ("memory.serve_contig_bytes_per_request",
         contig_bytes / max(contig_peak, 1), "resident cache per admitted"),
        ("memory.serve_paged_bytes_per_request",
         paged_bytes / max(paged_peak, 1), "resident cache per admitted"),
        ("memory.serve_admission_ratio", paged_peak / max(contig_peak, 1),
         "paged vs contiguous concurrent admissions at equal arena bytes"),
    ]
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--serving", action="store_true",
                    help="run the serving-cache arm instead of the fold arm")
    ap.add_argument("--assert-admission-ratio", type=float, default=0.0,
                    help="fail unless paged/contiguous peak concurrent "
                         "admissions >= this (CI gate: 4.0)")
    ap.add_argument("--profile-dir", default="",
                    help="write the paged serving run's XFA profile shard "
                         "here (for the cache_pages_in_use round-trip "
                         "assert)")
    args = ap.parse_args()
    rows = run_serving(args.profile_dir) if args.serving else run()
    for name, val, note in rows:
        print(f"{name},{val:.1f},{note}")
    if args.assert_admission_ratio:
        ratio = dict((n, v) for n, v, _ in rows)["memory.serve_admission_ratio"]
        if ratio < args.assert_admission_ratio:
            print(f"FAIL: admission ratio {ratio:.2f} < "
                  f"{args.assert_admission_ratio}", file=sys.stderr)
            sys.exit(1)
        print(f"admission ratio {ratio:.2f} >= "
              f"{args.assert_admission_ratio} (gate passed)")
