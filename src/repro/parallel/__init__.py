from .axes import (axis_size, get_runtime_mesh, named_sharding, resolve_spec,
                   runtime_mesh, set_runtime_mesh, shard)
from .sharding import (logical_axes_for, sharding_tree, spec_tree,
                       validate_rules)
