"""Decoder-only transformer LM (dense + MoE) with scan-over-layers.

Structure decisions that matter at scale:
  * jax.lax.scan over stacked layer params: one compiled layer body per
    block family regardless of depth -> small HLO, tractable dry-run
    compiles, and the remat policy applies per scanned body.
  * the XFA device fold table rides in the scan carry; MoE layers emit
    data-dependent metrics into it.
  * trace-time static costs use core.device_fold.scan_multiplier so one
    traced body registers L layers' worth of analytic FLOPs.
  * KV caches are stacked [L, ...] pytrees scanned together with the params;
    prefill and decode share ONE positioned-chunk body (forward_chunk) — a
    chunk of T tokens lands at per-row cache offsets, T = 1 being the pooled
    decode tick and pos = 0, T = S being bulk prefill.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.device_fold import DeviceFoldSpec, scan_multiplier
from repro.parallel.axes import shard

from . import moe as moe_lib
from .layers import (Params, Runtime, attention, cross_entropy, embed,
                     init_attention, init_embed, init_kv_cache, init_lm_head,
                     init_mlp, init_norm, last_valid, lm_head, mlp, norm)


# ------------------------------------------------------------ one layer ----
def init_decoder_layer(key, cfg: ModelConfig, kind: str) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    p.update(init_attention(k1, cfg))
    if kind == "moe":
        p.update(moe_lib.init_moe(k2, cfg))
    else:
        p.update(init_mlp(k2, cfg))
    return p


def decoder_layer(p: Params, x: jax.Array, rt: Runtime, table: jax.Array,
                  positions: jax.Array, kind: str,
                  cache: Optional[Params] = None,
                  pos: Optional[jax.Array] = None,
                  return_kv: bool = False,
                  block_table: Optional[jax.Array] = None):
    """Pre-norm block. Returns (x, table, aux, new_cache)."""
    h = norm(p["norm1"], x, rt)
    a, new_cache = attention(p, h, rt, positions, cache=cache, pos=pos,
                             block_table=block_table)
    x = x + a
    h = norm(p["norm2"], x, rt)
    if kind == "moe":
        y, table, aux = moe_lib.moe(p, h, rt, table)
    else:
        y = mlp(p, h, rt)
        aux = jnp.float32(0.0)
    x = x + y
    return shard(x, "batch", "seq", None), table, aux, new_cache


# ------------------------------------------------------------ full model ----
def _layer_kinds(cfg: ModelConfig) -> Tuple[Tuple[str, int], ...]:
    """Layer stacks in order: ((kind, count), ...)."""
    if cfg.moe:
        k = cfg.first_dense_layers
        stacks = []
        if k:
            stacks.append(("dense", k))
        stacks.append(("moe", cfg.n_layers - k))
        return tuple(stacks)
    return (("dense", cfg.n_layers),)


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 3 + len(_layer_kinds(cfg)))
    p: Dict[str, Any] = {}
    p.update(init_embed(keys[0], cfg))
    p.update(init_lm_head(keys[1], cfg))
    p["final_norm"] = init_norm(cfg)
    if cfg.family == "vlm":
        p.update(init_frontend(keys[2], cfg))
    for i, (kind, count) in enumerate(_layer_kinds(cfg)):
        lkeys = jax.random.split(keys[3 + i], count)
        stack = jax.vmap(
            functools.partial(init_decoder_layer, cfg=cfg, kind=kind))(lkeys)
        p[f"stack_{kind}" if cfg.moe else "stack"] = {"stack": stack}
    return p


def _stacks(p: Params, cfg: ModelConfig):
    for kind, count in _layer_kinds(cfg):
        name = f"stack_{kind}" if cfg.moe else "stack"
        yield kind, count, p[name]["stack"]


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots_saveable":
        policy = jax.checkpoint_policies.dots_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            prefix_embeds: Optional[jax.Array] = None):
    """tokens: [B, S] -> (hidden [B, S(+P), d], table, aux_total).

    prefix_embeds: [B, P, d] multimodal prefix (vlm) prepended to the text."""
    cfg = rt.cfg
    x = embed(p, tokens, rt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    aux_total = jnp.float32(0.0)

    for kind, count, stack in _stacks(p, cfg):
        def body(carry, layer_p, kind=kind):
            x, table, aux = carry
            x, table, aux_i, _ = decoder_layer(layer_p, x, rt, table,
                                               positions, kind)
            return (x, table, aux + aux_i), None

        body = _remat(body, cfg)
        if cfg.scan_layers:
            with scan_multiplier(count):
                (x, table, aux_total), _ = jax.lax.scan(
                    body, (x, table, aux_total), stack)
        else:
            for i in range(count):
                layer_p = jax.tree.map(lambda a: a[i], stack)
                (x, table, aux_total), _ = body((x, table, aux_total), layer_p)

    x = norm(p["final_norm"], x, rt)
    return x, table, aux_total


def loss_fn(p: Params, batch: Dict[str, jax.Array], rt: Runtime,
            table: jax.Array):
    """batch: tokens [B,S], labels [B,S], mask [B,S] (+ patches for vlm)."""
    cfg = rt.cfg
    prefix = None
    if cfg.family == "vlm":
        prefix = _project_patches(p, batch["patches"], rt)
    x, table, aux = forward(p, batch["tokens"], rt, table, prefix)
    if prefix is not None:
        x = x[:, prefix.shape[1]:]          # loss on text positions only
    logits = lm_head(p, x, rt)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    metrics = {"loss": loss, "aux_loss": aux,
               "tokens": jnp.sum(batch.get("mask", jnp.ones_like(
                   batch["labels"]))).astype(jnp.float32)}
    return loss + aux, (metrics, table)


# --------------------------------------------------------------- serving ----
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None
               ) -> Params:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    return init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)


def _split_cache(cache: Params, boundaries) -> Tuple[Params, ...]:
    """Split the [L, ...] stacked cache into per-stack segments."""
    outs = []
    start = 0
    for count in boundaries:
        outs.append(jax.tree.map(lambda a: a[start:start + count], cache))
        start += count
    return tuple(outs)


def forward_chunk(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
                  cache: Params, pos: jax.Array,
                  valid: Optional[jax.Array] = None,
                  prefix_embeds: Optional[jax.Array] = None,
                  block_table: Optional[jax.Array] = None):
    """THE serving entry point: write a T-token chunk at per-slot offsets.

    tokens: [B, T]; pos: [B] int32 per-slot cache depths (scalar
    broadcasts); valid: [B] tokens of the chunk that are real (None = T;
    bucket-padded chunks mask the pad — pad K/V rows are written past the
    frontier but the NEXT chunk overwrites them and no query ever attends
    them).  block_table: [B, NB] int32 — when given, `cache` is the paged
    arena ([L, P, Hkv, page_size, h]) and every layer writes/reads through
    the SAME per-slot table (one table per slot, shared across layers).
    Returns (last-valid-token logits [B, V], new stacked cache, table).

    Prefill and decode are this operation at different widths: pos = 0,
    T = prompt length is bulk prefill; T = 1 is the pooled decode tick;
    anything between is a mid-prompt prefill chunk.  Every batch row
    advances independently — rope angles, row-range cache scatters and
    offset-causal masks are all per-row — so one compiled call serves
    slots at arbitrary mixed depths."""
    cfg = rt.cfg
    x = embed(p, tokens, rt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, T = x.shape[:2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(T)[None, :]   # [B, T] per-row rope
    counts = [c for _, c in _layer_kinds(cfg)]
    cache_segs = _split_cache(cache, counts)

    new_segs = []
    for (kind, count, stack), seg in zip(_stacks(p, cfg), cache_segs):
        def body(carry, inp, kind=kind):
            x, table = carry
            layer_p, layer_cache = inp
            x, table, _, new_cache = decoder_layer(
                layer_p, x, rt, table, positions, kind,
                cache=layer_cache, pos=pos, block_table=block_table)
            return (x, table), new_cache

        with scan_multiplier(count):
            (x, table), new_seg = jax.lax.scan(body, (x, table), (stack, seg))
        new_segs.append(new_seg)

    x = norm(p["final_norm"], x, rt)
    logits = lm_head(p, last_valid(x, valid), rt)[:, 0]
    new_cache = jax.tree.map(
        lambda *segs: jnp.concatenate(segs, 0), *new_segs) \
        if len(new_segs) > 1 else new_segs[0]
    return logits, new_cache, table


def prefill(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            cache: Params, prefix_embeds: Optional[jax.Array] = None):
    """Bulk prefill = forward_chunk at offset 0 with T = prompt length."""
    zero = jnp.zeros((tokens.shape[0],), jnp.int32)
    return forward_chunk(p, tokens, rt, table, cache, zero,
                         prefix_embeds=prefix_embeds)


def decode_step(p: Params, token: jax.Array, rt: Runtime, table: jax.Array,
                cache: Params, pos: jax.Array):
    """Pooled decode = forward_chunk at width T = 1.  token: [B]."""
    return forward_chunk(p, token[:, None], rt, table, cache, pos)


# ------------------------------------------------------- paged serving ----
def init_paged_cache(cfg: ModelConfig, pages: int, page_size: int, dtype=None
                     ) -> Params:
    """Page-arena KV cache: the per-slot batch dim of init_cache becomes
    the PAGE dim ([L, P, Hkv, page_size, h] / MLA [L, P, page_size, r]).
    Ownership lives outside: the engine's block tables map (slot,
    virtual page) -> arena page, so a 30-token slot holds one page and a
    full-context one holds max_seq_len / page_size — memory follows the
    request, not the worst case.  Page 0 is reserved scratch."""
    return init_cache(cfg, pages, page_size, dtype)


def forward_chunk_paged(p: Params, tokens: jax.Array, rt: Runtime,
                        table: jax.Array, cache: Params, pos: jax.Array,
                        block_table: jax.Array,
                        valid: Optional[jax.Array] = None,
                        prefix_embeds: Optional[jax.Array] = None):
    """forward_chunk against the page arena — same math, block-table
    indirection for every cache write and read."""
    return forward_chunk(p, tokens, rt, table, cache, pos, valid=valid,
                         prefix_embeds=prefix_embeds,
                         block_table=block_table)


def decode_step_paged(p: Params, token: jax.Array, rt: Runtime,
                      table: jax.Array, cache: Params, pos: jax.Array,
                      block_table: jax.Array):
    """Pooled paged decode = forward_chunk_paged at width T = 1."""
    return forward_chunk_paged(p, token[:, None], rt, table, cache, pos,
                               block_table)


# -------------------------------------------------------------- vlm stub ----
def init_frontend(key, cfg: ModelConfig) -> Params:
    """Projection from precomputed frontend embeddings into d_model."""
    from .layers import _init, pdtype
    return {"frontend": {"w": _init(key, (cfg.frontend_dim, cfg.d_model),
                                    pdtype(cfg))}}


def _project_patches(p: Params, patches: jax.Array, rt: Runtime) -> jax.Array:
    from .layers import linear
    with jax.named_scope("embed"):
        x = linear(p["frontend"]["w"], patches.astype(rt.cdtype))
        return shard(x, "batch", "seq", None)


def declare_fold_slots(spec: DeviceFoldSpec, cfg: ModelConfig) -> None:
    if cfg.moe:
        moe_lib.declare_moe_slots(spec, cfg)
    spec.declare("app", "loss", "train_step", "count")
