"""Cross Flow Graph — the typed graph form of a folded XFA profile.

Scaler's views (component view / API view / flow matrix) answer "where
did the time go" for a human; automated diagnosis needs the same data as
a *graph*: components as nodes, caller -> callee.api relations as typed
edges, with the count/total/self/wait aggregates precomputed on both.
ScalAna (PAPERS.md) builds exactly such a program-performance graph to
localize scaling losses; this module is the XFA analogue built from
merged `EdgeColumns`, so construction is whole-column numpy reductions
over `EdgeColumns.group_rows`, never per-edge python loops over stats.

Two projections matter for diagnosis:

  * the MERGED graph of a run (all shards reduced) — what wait-dominance,
    hot-edge and call-amplification detectors read;
  * PER-SHARD graphs (one per trainer rank / serving replica, from the
    newest ring entry of each shard) — comparable subgraphs of one run,
    which is what rank/replica imbalance detection needs.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..core.folding import EdgeColumns, FoldedTable
from ..core.histogram import jitter_ns as _hist_jitter, percentile_ns
from ..core.shadow import KIND_NAMES, KIND_WAIT, SlotKey, edge_label


@dataclass(frozen=True)
class FlowEdge:
    """One typed caller -> component.api relation with folded aggregates."""

    key: SlotKey
    kind: int
    count: int
    total_ns: int
    child_ns: int
    min_ns: int
    max_ns: int
    metrics: Dict[str, float] = field(default_factory=dict)
    #: optional latency histogram (schema v2); compare=False keeps the
    #: frozen dataclass' == well-defined despite the ndarray
    hist: Optional[np.ndarray] = field(default=None, compare=False,
                                       repr=False)
    #: effective timing-sample rate (schema v3) when the overhead governor
    #: subsampled this edge; None == fully sampled.  Counts stay exact,
    #: time columns are unbiased scale-ups — detectors can weigh evidence
    #: from subsampled edges accordingly
    sample_rate: Optional[float] = field(default=None, compare=False)

    @property
    def caller(self) -> str:
        return self.key[0]

    @property
    def component(self) -> str:
        return self.key[1]

    @property
    def api(self) -> str:
        return self.key[2]

    @property
    def self_ns(self) -> int:
        return self.total_ns - self.child_ns

    @property
    def mean_ns(self) -> float:
        return self.total_ns / self.count if self.count else 0.0

    # -- histogram read-out (0.0 for hist-less edges) ---------------------
    @property
    def p50_ns(self) -> float:
        return percentile_ns(self.hist, 0.50)

    @property
    def p95_ns(self) -> float:
        return percentile_ns(self.hist, 0.95)

    @property
    def p99_ns(self) -> float:
        return percentile_ns(self.hist, 0.99)

    @property
    def jitter_ns(self) -> float:
        return _hist_jitter(self.hist)

    def to_json(self) -> dict:
        return {
            "key": list(self.key),
            "kind": KIND_NAMES[self.kind],
            "count": int(self.count),
            "total_ns": int(self.total_ns),
            "self_ns": int(self.self_ns),
            "metrics": dict(self.metrics),
        }


@dataclass
class FlowNode:
    """One component with inbound/outbound aggregates.

    `in_*` sums every edge INTO the component (time spent inside it, by
    caller); `wait_ns` is the inbound wait-kind share of that (Scaler
    §3.5's Wait category); `self_ns` is inbound total minus inbound child
    — the time the component spent in its own body.  `out_*` sums edges
    FROM the component (time it spent calling into others)."""

    name: str
    in_count: int = 0
    in_total_ns: int = 0
    in_child_ns: int = 0
    wait_count: int = 0
    wait_ns: int = 0
    out_count: int = 0
    out_total_ns: int = 0

    @property
    def self_ns(self) -> int:
        return max(self.in_total_ns - self.in_child_ns, 0)

    @property
    def wait_share(self) -> float:
        return self.wait_ns / self.in_total_ns if self.in_total_ns else 0.0

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "in_count": int(self.in_count),
            "in_total_ns": int(self.in_total_ns),
            "self_ns": int(self.self_ns),
            "wait_ns": int(self.wait_ns),
            "wait_share": self.wait_share,
            "out_total_ns": int(self.out_total_ns),
        }


class FlowGraph:
    """Typed cross-flow graph of one profile (or one shard of one run)."""

    def __init__(self, edges: Dict[SlotKey, FlowEdge],
                 nodes: Dict[str, FlowNode], group: str = "main",
                 meta: Optional[Dict] = None) -> None:
        self.edges = edges
        self.nodes = nodes
        self.group = group
        self.meta = dict(meta or {})
        self._out: Dict[str, List[SlotKey]] = {}
        self._in: Dict[str, List[SlotKey]] = {}
        for k in sorted(edges):
            self._out.setdefault(k[0], []).append(k)
            self._in.setdefault(k[1], []).append(k)

    # -- constructors -------------------------------------------------------
    @staticmethod
    def from_columns(cols: EdgeColumns,
                     meta: Optional[Dict] = None) -> "FlowGraph":
        """Build nodes/edges from aligned columns: per-node aggregates are
        fancy-indexed whole-column sums (EdgeColumns.group_rows), mirroring
        how merge_columns avoids per-edge boxing."""
        folded_metrics: List[Dict[str, float]] = [
            {} for _ in range(len(cols))]
        for i, name in enumerate(cols.metric_names):
            for j in np.nonzero(cols.metric_mask[i])[0]:
                folded_metrics[j][name] = float(cols.metric_values[i, j])
        edges: Dict[SlotKey, FlowEdge] = {}
        for j, k in enumerate(cols.keys):
            hist = None
            if cols.hist is not None and cols.hist[j].any():
                hist = cols.hist[j]
            rate = None
            if cols.sample_rate is not None and cols.sample_rate[j] < 1.0:
                rate = float(cols.sample_rate[j])
            edges[k] = FlowEdge(
                key=k, kind=int(cols.kind[j]), count=int(cols.count[j]),
                total_ns=int(cols.total_ns[j]),
                child_ns=int(cols.child_ns[j]),
                min_ns=int(cols.min_ns[j]), max_ns=int(cols.max_ns[j]),
                metrics=folded_metrics[j], hist=hist, sample_rate=rate)
        nodes: Dict[str, FlowNode] = {}
        wait = cols.kind == KIND_WAIT
        for name, rows in cols.group_rows("component").items():
            w = rows[wait[rows]]
            nodes[name] = FlowNode(
                name=name,
                in_count=int(cols.count[rows].sum()),
                in_total_ns=int(cols.total_ns[rows].sum()),
                in_child_ns=int(cols.child_ns[rows].sum()),
                wait_count=int(cols.count[w].sum()),
                wait_ns=int(cols.total_ns[w].sum()))
        for name, rows in cols.group_rows("caller").items():
            n = nodes.setdefault(name, FlowNode(name=name))
            n.out_count = int(cols.count[rows].sum())
            n.out_total_ns = int(cols.total_ns[rows].sum())
        return FlowGraph(edges, nodes, group=cols.group, meta=meta)

    @staticmethod
    def from_folded(table: FoldedTable,
                    meta: Optional[Dict] = None) -> "FlowGraph":
        return FlowGraph.from_columns(table.to_columns(), meta=meta)

    @staticmethod
    def from_snapshot(snap) -> "FlowGraph":
        return FlowGraph.from_columns(snap.columns, meta=snap.meta)

    # -- queries ------------------------------------------------------------
    def components(self) -> List[str]:
        return sorted(self.nodes)

    def in_edges(self, component: str,
                 kind: Optional[int] = None) -> List[FlowEdge]:
        out = [self.edges[k] for k in self._in.get(component, ())]
        return out if kind is None else [e for e in out if e.kind == kind]

    def out_edges(self, component: str,
                  kind: Optional[int] = None) -> List[FlowEdge]:
        out = [self.edges[k] for k in self._out.get(component, ())]
        return out if kind is None else [e for e in out if e.kind == kind]

    def successors(self, component: str) -> List[str]:
        return sorted({k[1] for k in self._out.get(component, ())})

    def total_ns(self) -> int:
        return sum(e.total_ns for e in self.edges.values())

    def total_count(self) -> int:
        return sum(e.count for e in self.edges.values())

    def __len__(self) -> int:
        return len(self.edges)

    def to_json(self) -> dict:
        return {
            "group": self.group,
            "nodes": [self.nodes[c].to_json() for c in self.components()],
            "edges": [self.edges[k].to_json() for k in sorted(self.edges)],
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"FlowGraph(nodes={len(self.nodes)}, edges={len(self.edges)},"
                f" group={self.group!r})")


def shard_graphs(run_dir: str) -> Dict[str, FlowGraph]:
    """Per-shard projection of one run: stem -> FlowGraph built from the
    NEWEST ring entry of each shard (the shard's cumulative truth).  One
    trainer rank / serving replica each becomes a comparable subgraph —
    the input to straggler/imbalance detection.  Stems come from the
    store (host-qualified `host/shard` in a collector spool run dir), so
    two hosts' same-named rank-0 rings stay two subgraphs instead of
    silently aliasing.  Merge products that were written into the run
    dir are excluded, mirroring the reducer."""
    from ..profile.snapshot import ProfileSnapshot
    from ..profile.store import ProfileStore
    out: Dict[str, FlowGraph] = {}
    for stem, ring in sorted(ProfileStore(run_dir).shards().items()):
        snap = ProfileSnapshot.load(ring[-1][1])
        if "merged_from" in snap.meta:
            continue
        out[stem] = FlowGraph.from_snapshot(snap)
    return out


def run_graph(run_dir: str) -> FlowGraph:
    """The merged graph of a run dir (newest-per-shard reduce)."""
    from ..profile.store import ProfileStore
    snap = ProfileStore(run_dir).reduce()
    g = FlowGraph.from_snapshot(snap)
    g.meta.setdefault("run_dir", os.path.abspath(run_dir))
    return g
