"""Mamba2 chunked SSD scan — Pallas TPU kernel.

The SSD (state-space dual) insight [arXiv:2405.21060]: within a chunk the
recurrence is a *masked attention-like matmul* (MXU work), across chunks it
is a tiny state recurrence (carried in VMEM scratch). The GPU version tiles
for warps/SMEM; here the chunk matmuls are shaped for the 128x128 MXU and
the [N, P] state never leaves VMEM between chunk iterations:

  grid = (B, H, L/chunk), chunk dim innermost + 'arbitrary' (sequential);
  per-iteration VMEM blocks:  dtx [T, P], ldec [T, lanes], b/c [T, N]
  scratch: h [N, P] f32 — the recurrent state, initialized at chunk 0.

Inputs are pre-arranged by ops.py into head-major layout so every BlockSpec
is a plain slice:
  dtx  [B, H, L, P]   dt-weighted inputs (dt[...,None] * x)
  ldec [B, H, L]      per-step log decay (A * dt), <= 0
  b, c [B, L, N]      shared across heads (single SSD group)
Output y [B, H, L, P]; the D*x skip connection is applied by ops.py outside.
Final state h [B, H, N, P] is a second output (needed for decode prefill).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params

LANES = 128


def _ssd_kernel(h0_ref, dtx_ref, ldec_ref, b_ref, c_ref, y_ref, h_out_ref,
                h_ref, *, chunk: int, num_chunks: int):
    ck = pl.program_id(2)

    @pl.when(ck == 0)
    def _init():
        # resume from the caller's carried state (in-model chunked prefill:
        # each prompt chunk continues the scan where the last one stopped)
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    dtx = dtx_ref[0, 0].astype(jnp.float32)               # [T, P]
    ldec = ldec_ref[0, 0, :, 0].astype(jnp.float32)       # [T]
    b = b_ref[0].astype(jnp.float32)                      # [T, N]
    c = c_ref[0].astype(jnp.float32)                      # [T, N]

    cum = jnp.cumsum(ldec)                                # inclusive [T]
    # intra-chunk: masked (C B^T ⊙ decay) @ dtx
    seg = cum[:, None] - cum[None, :]                     # [T, T]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(cols <= rows, jnp.exp(seg), 0.0)
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [T, T]
    y_intra = jax.lax.dot_general(g * m, dtx, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y += exp(cum) * (C @ h_prev)
    h_prev = h_ref[...]                                   # [N, P]
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(cum[-1]) h_prev + B^T @ (w ⊙ dtx)
    w = jnp.exp(cum[-1] - cum)                            # [T]
    s_in = jax.lax.dot_general(b, w[:, None] * dtx,
                               (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [N, P]
    h_ref[...] = jnp.exp(cum[-1]) * h_prev + s_in

    @pl.when(ck == num_chunks - 1)
    def _emit_state():
        h_out_ref[0, 0] = h_ref[...].astype(h_out_ref.dtype)


def ssd_scan(dtx: jax.Array, ldec: jax.Array, b: jax.Array, c: jax.Array, *,
             chunk: int = 128, h0: jax.Array = None, interpret: bool = False):
    """dtx: [B, H, L, P]; ldec: [B, H, L]; b, c: [B, L, N];
    h0: [B, H, N, P] initial state (None = zeros — fresh sequence).

    Returns (y [B, H, L, P], h_final [B, H, N, P])."""
    B, H, L, P = dtx.shape
    N = b.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    # lane-shape the per-step decay for TPU tiling: [B, H, L, 1]
    ldec4 = ldec[..., None]
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, N, P), lambda bb, hh, ck: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, chunk, P), lambda bb, hh, ck: (bb, hh, ck, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bb, hh, ck: (bb, hh, ck, 0)),
            pl.BlockSpec((1, chunk, N), lambda bb, hh, ck: (bb, ck, 0)),
            pl.BlockSpec((1, chunk, N), lambda bb, hh, ck: (bb, ck, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bb, hh, ck: (bb, hh, ck, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bb, hh, ck: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, L, P), dtx.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="xfa_ssd_scan",
    )(h0.astype(jnp.float32), dtx, ldec4, b, c)
    return y, h
