"""Vectorized columnar merge vs the per-edge dict-loop merge.

The cross-process reducer (repro.profile) merges N snapshot shards of a 10k+
edge table.  The pre-columnar path rebuilds an EdgeStats object per edge per
shard (dict lookups + allocation + per-field python adds); the columnar path
re-interns keys once and then does whole-column numpy scatter-add/min/max.

  merge.loop_ms       merge_all (pairwise EdgeStats.merge) over FoldedTables
  merge.columnar_ms   merge_all_columnar (conversion + vectorized merge)
  merge.columnar_only_ms   merge_columns over pre-built columns — the shard
                      reduce path, where snapshots load as columns directly
  merge.speedup_x / merge.reduce_speedup_x   loop_ms / the above

Both paths must produce identical per-edge stats (asserted here).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.folding import (EdgeColumns, EdgeStats, FoldedTable,
                                merge_columns)

N_EDGES = 10_000
N_SHARDS = 8


def _make_shards(n_shards: int = N_SHARDS, n_edges: int = N_EDGES,
                 seed: int = 0) -> List[FoldedTable]:
    rng = np.random.default_rng(seed)
    keys = [(f"comp{i % 37}", f"lib{i % 101}", f"api{i}")
            for i in range(n_edges)]
    shards = []
    for s in range(n_shards):
        # each shard observes ~70% of the edge universe
        mask = rng.random(n_edges) < 0.7
        durs = rng.integers(1, 1_000_000, size=n_edges)
        counts = rng.integers(1, 100, size=n_edges)
        edges = {}
        for j in np.nonzero(mask)[0]:
            edges[keys[j]] = EdgeStats(
                count=int(counts[j]), total_ns=int(durs[j]) * int(counts[j]),
                child_ns=int(durs[j]) // 2, min_ns=int(durs[j]) // 2,
                max_ns=int(durs[j]) * 2,
                metrics={"flops": float(durs[j])} if j % 5 == 0 else {})
        shards.append(FoldedTable(edges, group=f"proc{s}"))
    return shards


def _best_of(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def run():
    shards = _make_shards()
    cols = [EdgeColumns.from_folded(t) for t in shards]

    loop_ms = _best_of(lambda: FoldedTable.merge_all(shards))
    columnar_ms = _best_of(lambda: FoldedTable.merge_all_columnar(shards))
    columnar_only_ms = _best_of(lambda: merge_columns(cols))

    # correctness: both paths agree edge-for-edge
    a = FoldedTable.merge_all(shards)
    b = FoldedTable.merge_all_columnar(shards)
    assert a.edges.keys() == b.edges.keys()
    for k in a.edges:
        assert a.edges[k].to_json() == b.edges[k].to_json(), k

    # notes must stay comma-free: run.py prints unquoted name,value,note CSV
    note = f"{N_SHARDS} shards x {N_EDGES} edges"
    yield "merge.loop_ms", loop_ms, note
    yield "merge.columnar_ms", columnar_ms, note
    yield "merge.columnar_only_ms", columnar_only_ms, "pre-built columns"
    yield "merge.speedup_x", loop_ms / columnar_ms, "vs loop incl conversion"
    yield "merge.reduce_speedup_x", loop_ms / columnar_only_ms, \
        "vs loop on shard-reduce path"


if __name__ == "__main__":
    print("name,value,note")
    for name, val, note in run():
        print(f"{name},{val:.3f},{note}")
