"""Paper Table 1/3 analogue: runtime overhead of full-trace XFA,
plus the adaptive-governor gate (`--budget-pct`).

Scaler claims 20.3% runtime overhead for 100% API-invocation tracing. Our
layers are measured on real (CPU) train AND serve loops:

  baseline      XFA fully disabled
  host          L1 host tracer on every framework boundary
  host+device   L1 + L2 in-graph fold table threaded through the step
  governed      L1 under the adaptive overhead governor (core.sampler):
                hot edges back off to 1-in-k timing with unbiased
                scale-up while counting stays exact

The paper's bar is ~20%; the in-graph fold should be far cheaper because
the fold rides inside the compiled step (a few scalar adds vs 1e9-FLOP
matmuls).

Measurement discipline: all variants of a section are INTERLEAVED
(round-robin steps / alternating drains / alternating hot-loop blocks)
and compared by median — on a shared machine, wall time drifts by more
per minute than the host tracer costs, so back-to-back loops would
measure the drift, not the tracer.

`--budget-pct G` turns the run into a GATE (the overhead-sentinel CI
lane): exit 1 unless, with the governor attached at budget G,

  * train host overhead stays <= G percent of the baseline step,
  * serve host overhead stays <= G percent of the untraced drain,
  * a pure no-op hot loop (nothing but bracket cost) does not run
    slower governed than fully traced beyond noise, with back-off
    actually engaged (min effective sampling rate < 1).

The hot loop cannot itself get under a percent-level budget — the
irreducible counting floor (count fold + caller frame) is a large share
of the full bracket — which is exactly why the budget assertion runs on
the real loops and the hot loop only has to show the governor removes
timing cost where nothing else exists to hide it.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ServeConfig, TrainConfig
from repro.core import tracer as xfa
from repro.core.tracer import Tracer
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.runtime.trainer import init_train_state, make_train_step

perf_ns = time.perf_counter_ns


def _train_medians(steps: int, budget: float = 0.0):
    """Median per-step wall ns for the four train variants, measured
    round-robin (one step of each per round) so machine drift hits every
    variant equally."""
    import dataclasses

    from repro.core.sampler import SamplerController

    # an arch with live device-fold traffic (MoE emits expert loads)
    model_full = build_model(get_smoke("phi3_5_moe_42b"), impl="ref")
    tcfg = TrainConfig(microbatches=1, ckpt_interval=0)
    data = SyntheticLMData(model_full.cfg, 4, 64)
    # device-fold OFF: rebuild with fold_spec stripped
    model_off = dataclasses.replace(
        model_full, rt=dataclasses.replace(model_full.rt, fold_spec=None))

    ctl = SamplerController(budget) if budget > 0 else None
    variants = [("base", model_off, False, None),
                ("host", model_off, True, None),
                ("full", model_full, True, None)]
    if ctl is not None:
        variants.append(("gov", model_off, True, ctl))

    ctxs = {}
    xfa.reset()
    xfa.set_enabled(True)
    for name, model, _enabled, _ctl in variants:
        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        state = init_train_state(model, jax.random.key(0), tcfg)
        table = model.table()
        batch = {k: jnp.asarray(v) for k, v in data.generate(0).items()}
        state, m, table = step_fn(state, batch, table)   # compile
        jax.block_until_ready(m["loss"])
        ctxs[name] = [step_fn, state, batch, table]

    times = {name: [] for name, *_ in variants}
    try:
        for _ in range(steps):
            for name, _model, enabled, c in variants:
                step_fn, state, batch, table = ctxs[name]
                xfa.TRACER.enabled = enabled
                xfa.TRACER.sampler = c
                t0 = perf_ns()
                if enabled:
                    with xfa.scope("runtime", "dispatch_step"):
                        state, m, table = step_fn(state, batch, table)
                    with xfa.scope("runtime", "device_sync", xfa.KIND_WAIT):
                        jax.block_until_ready(m["loss"])
                else:
                    state, m, table = step_fn(state, batch, table)
                    jax.block_until_ready(m["loss"])
                times[name].append(perf_ns() - t0)
                ctxs[name] = [step_fn, state, batch, table]
    finally:
        xfa.TRACER.enabled = True
        xfa.TRACER.sampler = None
    return {name: float(np.median(v)) for name, v in times.items()}


def _serve_medians(budget: float = 0.0, rounds: int = 4,
                   requests: int = 4, max_new: int = 12):
    """Median wall ns of draining a fixed closed-loop workload on the
    tiny serving engine, alternating untraced / traced(/governed) drains
    on the SAME engine."""
    import dataclasses

    from repro.serving import SamplingParams, ServingEngine

    cfg = dataclasses.replace(get_smoke("tinyllama_1_1b"),
                              n_layers=2, vocab=512)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, ServeConfig(
        max_batch=4, max_seq_len=256, eos_token=-1))
    sampling = SamplingParams(temperature=0.0, seed=1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 48)))
               for _ in range(requests)]
    # warmup: compile every chunk bucket + pooled decode outside the window
    for w in engine.chunk_buckets() or [64]:
        engine.submit(rng.integers(0, cfg.vocab, min(w, 200)), 2,
                      sampling=sampling)
        engine.run_until_drained()
    engine.completed.clear()

    def drain() -> float:
        t0 = perf_ns()
        for p in prompts:
            engine.submit(p, max_new, sampling=sampling)
        engine.run_until_drained()
        engine.completed.clear()
        return float(perf_ns() - t0)

    times = {"untraced": [], "traced": []}
    xfa.reset()
    try:
        for _ in range(rounds):
            xfa.set_enabled(False)
            times["untraced"].append(drain())
            xfa.set_enabled(True)
            xfa.set_overhead_budget(budget)
            times["traced"].append(drain())
            xfa.set_overhead_budget(0.0)
    finally:
        xfa.set_enabled(True)
        xfa.set_overhead_budget(0.0)
    return {name: float(np.median(v)) for name, v in times.items()}


def _hot_loop(budget: float, blocks: int = 8, iters: int = 20_000):
    """Per-call ns of a no-op `@api` boundary on scratch tracers —
    nothing but bracket cost — with fully-traced and governed blocks
    alternating.  Returns (full_ns, governed_ns, min_rate) where
    min_rate is the smallest effective sampling rate the governor
    reached (1.0 if it never backed off)."""
    t_full = Tracer()
    t_gov = Tracer()
    ctl = t_gov.set_overhead_budget(budget)

    @t_full.api("hot")
    def f_full() -> None:
        return None

    @t_gov.api("hot")
    def f_gov() -> None:
        return None

    for _ in range(1024):
        f_full()
        f_gov()
    full, gov = [], []
    for _ in range(blocks):
        t0 = perf_ns()
        for _ in range(iters):
            f_full()
        full.append((perf_ns() - t0) / iters)
        t0 = perf_ns()
        for _ in range(iters):
            f_gov()
        gov.append((perf_ns() - t0) / iters)
    rates = ctl.rates() if ctl is not None else {}
    return (float(np.median(full)), float(np.median(gov)),
            min(rates.values(), default=1.0))


def run(steps: int = 8, budget_pct: float = 0.0):
    budget = budget_pct / 100.0
    tm = _train_medians(steps, budget=budget)
    base = tm["base"]
    rows = [
        ("overhead.baseline_step_us", base / 1e3, ""),
        ("overhead.host_pct", 100 * (tm["host"] - base) / base,
         "paper Scaler: 20.3%"),
        ("overhead.host_device_pct", 100 * (tm["full"] - base) / base,
         "full trace incl. in-graph fold"),
    ]

    sm = _serve_medians(budget=budget)
    serve_pct = 100 * (sm["traced"] - sm["untraced"]) / sm["untraced"]
    rows.append(("overhead.serve_untraced_ms", sm["untraced"] / 1e6, ""))
    rows.append(("overhead.serve_host_pct", serve_pct,
                 "traced-vs-untraced closed-loop drain"
                 + (" (governed)" if budget else "")))

    ok = True
    if budget > 0:
        gov_pct = 100 * (tm["gov"] - base) / base
        rows.append(("overhead.host_governed_pct", gov_pct,
                     f"governor at budget {budget_pct:.0f}%"))

        hot_full, hot_gov, min_rate = _hot_loop(budget)
        rows.append(("overhead.hotloop_full_ns", hot_full,
                     "no-op @api boundary, every call timed"))
        rows.append(("overhead.hotloop_governed_ns", hot_gov,
                     "same boundary under the governor"))
        rows.append(("overhead.hotloop_min_rate", min_rate,
                     "effective sampling rate after back-off"))

        checks = [
            ("train_under_budget", gov_pct <= budget_pct),
            ("serve_under_budget", serve_pct <= budget_pct),
            # the governed boundary keeps the counting floor, so parity
            # within noise already demonstrates the bracket cost is gone;
            # a governed loop RELIABLY slower than full trace would mean
            # the governor itself is the overhead
            ("governed_not_slower", hot_gov <= hot_full * 1.10),
            ("backoff_engaged", min_rate < 1.0),
        ]
        for name, passed in checks:
            rows.append((f"overhead.gate.{name}", float(passed),
                         "1 = pass"))
            ok = ok and passed
    return rows, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=8,
                    help="timed train steps per variant (round-robin)")
    ap.add_argument("--budget-pct", type=float, default=0.0,
                    help="attach the overhead governor at this budget "
                         "(percent of wall time) and GATE: exit 1 unless "
                         "host overhead stays under it on train + serve "
                         "and back-off engages on the hot loop")
    ap.add_argument("-o", "--output", default="",
                    help="also write the CSV rows to this file")
    args = ap.parse_args(argv)
    rows, ok = run(steps=args.steps, budget_pct=args.budget_pct)
    lines = [f"{name},{val:.2f},{note}" for name, val, note in rows]
    print("\n".join(lines))
    if args.output:
        with open(args.output, "w") as f:
            f.write("\n".join(lines) + "\n")
    if not ok:
        print("overhead: budget gate FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
