"""Run registry — metadata manifests + the cross-run query API.

Folded XFA profiles only surface *unknown* performance issues when many
runs and many points in time are comparable (PAPER.md §4.3; ScalAna's
cross-run scaling-loss detection makes the same point).  That needs an
index: every trainer / serving process registers its run by writing a
`manifest.json` into its run directory with structured metadata — config
name, model arch (family), mesh shape, jax version, snapshot schema
version, label, start time — plus free-form extras.  A registry root is
any directory tree containing run dirs; `RunRegistry.query` (and
`python -m repro.profile query`) filters runs by metadata predicates, so
"all runs of arch X on mesh Y" is one call away and `diff`/`timeline`
always have a baseline to point at.

Registration is idempotent and multi-writer: every rank / replica of a
run calls `register_run` on the same dir; writers merge into the
manifest's `writers` list and the earliest start time wins.  Writes are
atomic (tmp + rename), mirroring the snapshot writer.
"""

from __future__ import annotations

import fnmatch
import json
import os
import tempfile
import time
from contextlib import contextmanager
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .snapshot import SCHEMA_VERSION

MANIFEST_NAME = "manifest.json"


@contextmanager
def _manifest_lock(run_dir: str):
    """Serialize register_run's load-modify-save: ranks of one run race on
    the same manifest, and a lost update would drop writer entries.  flock
    is advisory and Linux-only-reliable, which matches where fleets run;
    hosts without fcntl fall back to best-effort (single-writer) behavior."""
    try:
        import fcntl
    except ImportError:  # pragma: no cover - non-posix fallback
        yield
        return
    os.makedirs(run_dir, exist_ok=True)
    fd = os.open(os.path.join(run_dir, MANIFEST_NAME + ".lock"),
                 os.O_CREAT | os.O_RDWR, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)

MeshShape = Optional[Tuple[int, ...]]


def _jax_version() -> str:
    try:
        import jax
        return jax.__version__
    except Exception:  # registry must work on hosts without jax
        return ""


def parse_mesh(mesh: Union[None, str, Sequence[int]]) -> MeshShape:
    """'4x2' / (4, 2) / [4, 2] -> (4, 2); ''/None -> None."""
    if mesh is None or mesh == "":
        return None
    if isinstance(mesh, str):
        return tuple(int(x) for x in mesh.split("x"))
    return tuple(int(x) for x in mesh)


def kv_pair(s: str) -> Tuple[str, str]:
    """argparse type for KEY=VALUE flags (--profile-meta / --where): fail
    at the parser with a usage error, not deep in a dict() later."""
    key, sep, value = s.partition("=")
    if not sep or not key:
        import argparse
        raise argparse.ArgumentTypeError(
            f"expected KEY=VALUE, got {s!r}")
    return key, value


@dataclass
class RunManifest:
    """One run's structured metadata (the per-run half of the registry)."""

    run_dir: str = ""                    # filled at load; not serialized
    config: str = ""                     # config name (e.g. tinyllama_1_1b)
    arch: str = ""                       # model family (dense/moe/ssm/...)
    mesh_shape: MeshShape = None
    mesh_axes: Optional[Tuple[str, ...]] = None
    label: str = ""
    kind: str = ""                       # train | serve | ...
    jax_version: str = ""
    schema: int = SCHEMA_VERSION
    started_at: float = 0.0
    meta: Dict[str, Any] = field(default_factory=dict)
    writers: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def run_id(self) -> str:
        return os.path.basename(os.path.normpath(self.run_dir)) or self.run_dir

    # -- (de)serialization ----------------------------------------------------
    def to_json(self) -> dict:
        d = asdict(self)
        d.pop("run_dir")
        d["mesh_shape"] = list(self.mesh_shape) if self.mesh_shape else None
        d["mesh_axes"] = list(self.mesh_axes) if self.mesh_axes else None
        return d

    @staticmethod
    def from_json(d: dict, run_dir: str = "") -> "RunManifest":
        return RunManifest(
            run_dir=run_dir,
            config=d.get("config", ""),
            arch=d.get("arch", ""),
            mesh_shape=parse_mesh(d.get("mesh_shape")),
            mesh_axes=tuple(d["mesh_axes"]) if d.get("mesh_axes") else None,
            label=d.get("label", ""),
            kind=d.get("kind", ""),
            jax_version=d.get("jax_version", ""),
            schema=int(d.get("schema", SCHEMA_VERSION)),
            started_at=float(d.get("started_at", 0.0)),
            meta=dict(d.get("meta", {})),
            writers=list(d.get("writers", [])),
        )

    @staticmethod
    def load(run_dir: str) -> "RunManifest":
        with open(os.path.join(run_dir, MANIFEST_NAME)) as f:
            return RunManifest.from_json(json.load(f), run_dir=run_dir)

    def save(self) -> str:
        path = os.path.join(self.run_dir, MANIFEST_NAME)
        os.makedirs(self.run_dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.run_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path

    # -- predicates ------------------------------------------------------------
    def matches(self, config: Optional[str] = None,
                arch: Optional[str] = None,
                mesh: Union[None, str, Sequence[int]] = None,
                label: Optional[str] = None,
                kind: Optional[str] = None,
                since: Optional[float] = None,
                where: Optional[Dict[str, str]] = None) -> bool:
        """Metadata predicate; string fields accept fnmatch globs, `mesh`
        accepts '4x2' or a shape tuple, `since` is an epoch lower bound on
        started_at, `where` matches free-form keys against top-level fields
        first and then `meta` (string compare)."""
        for pat, val in ((config, self.config), (arch, self.arch),
                         (label, self.label), (kind, self.kind)):
            if pat is not None and not fnmatch.fnmatchcase(val, pat):
                return False
        if mesh is not None and parse_mesh(mesh) != self.mesh_shape:
            return False
        if since is not None and self.started_at < since:
            return False
        for k, v in (where or {}).items():
            have = getattr(self, k, None)
            if have is None or isinstance(have, (dict, list)):
                have = self.meta.get(k)
            if have is None or str(have) != str(v):
                return False
        return True

    def describe(self) -> str:
        mesh = "x".join(map(str, self.mesh_shape)) if self.mesh_shape else "-"
        when = time.strftime("%Y-%m-%dT%H:%M:%S",
                             time.localtime(self.started_at)) \
            if self.started_at else "-"
        return (f"{self.run_dir}  config={self.config or '-'} "
                f"arch={self.arch or '-'} mesh={mesh} "
                f"label={self.label or '-'} kind={self.kind or '-'} "
                f"started={when} writers={len(self.writers)}")


def register_run(run_dir: str, *,
                 config: str = "", arch: str = "",
                 mesh_shape: Union[None, str, Sequence[int]] = None,
                 mesh_axes: Optional[Sequence[str]] = None,
                 label: str = "", kind: str = "",
                 meta: Optional[Dict[str, Any]] = None,
                 started_at: Optional[float] = None) -> RunManifest:
    """Create or update `run_dir`'s manifest (idempotent, multi-writer).

    Called by every writing process at run start; concurrent ranks merge
    into one manifest: earliest started_at wins, meta keys union (latest
    write wins per key), and each (label, host, pid) appears once in
    `writers`.
    """
    now = time.time() if started_at is None else started_at
    with _manifest_lock(run_dir):
        try:
            m = RunManifest.load(run_dir)
        except (FileNotFoundError, json.JSONDecodeError, ValueError):
            m = RunManifest(run_dir=run_dir, started_at=now)
        m.run_dir = run_dir
        m.started_at = min(m.started_at or now, now)
        if config:
            m.config = config
        if arch:
            m.arch = arch
        if mesh_shape is not None:
            m.mesh_shape = parse_mesh(mesh_shape)
        if mesh_axes is not None:
            m.mesh_axes = tuple(mesh_axes)
        if label:
            m.label = label
        if kind:
            m.kind = kind
        m.jax_version = m.jax_version or _jax_version()
        m.schema = SCHEMA_VERSION
        m.meta.update(meta or {})
        from .store import host_label
        writer = {"label": label, "host": host_label(),
                  "pid": os.getpid()}
        ident = (writer["label"], writer["host"], writer["pid"])
        if ident not in {(w.get("label"), w.get("host"), w.get("pid"))
                         for w in m.writers}:
            m.writers.append(dict(writer, registered_at=now))
        m.save()
    return m


class RunRegistry:
    """All registered runs under a root directory tree."""

    def __init__(self, root: str) -> None:
        self.root = root

    def run_dirs(self) -> List[str]:
        hits = glob_manifests(self.root)
        return sorted(os.path.dirname(p) for p in hits)

    def runs(self) -> List[RunManifest]:
        """Load every registered run from ONE snapshot of the directory
        listing.  The registry is scanned while publishers/collectors
        register concurrently (fleet spools grow mid-query), so a run
        dir that appears after the listing is simply absent from this
        scan, and one whose manifest vanishes or is mid-merge between
        listing and load is skipped — never an exception out of query.
        """
        out = []
        for d in self.run_dirs():
            try:
                out.append(RunManifest.load(d))
            except FileNotFoundError:
                continue          # registered mid-scan and gone, or racing
            except (json.JSONDecodeError, ValueError, OSError) as e:
                import warnings
                warnings.warn(f"run registry: skipping unreadable manifest "
                              f"in {d!r}: {e}", stacklevel=2)
        out.sort(key=lambda m: (m.started_at, m.run_dir))
        return out

    def query(self, **predicates) -> List[RunManifest]:
        """Filter runs by RunManifest.matches predicates (config, arch,
        mesh, label, kind, since, where)."""
        return [m for m in self.runs() if m.matches(**predicates)]

    def find(self, pattern: Optional[str] = None) -> str:
        """Resolve ONE run dir by a run-id / label / config fnmatch glob
        (`diagnose --run`, `--baseline`).  No pattern picks the sole
        registered run; zero or several matches raise LookupError listing
        the candidates — selection must be explicit, never first-match."""
        runs = self.runs()
        if pattern:
            runs = [m for m in runs
                    if fnmatch.fnmatchcase(m.run_id, pattern)
                    or fnmatch.fnmatchcase(m.label, pattern)
                    or fnmatch.fnmatchcase(m.config, pattern)]
        what = f"pattern {pattern!r}" if pattern else "an implicit run"
        if not runs:
            raise LookupError(f"no registered run under {self.root!r} "
                              f"matches {what}")
        if len(runs) > 1:
            ids = ", ".join(m.run_id for m in runs)
            raise LookupError(f"{what} is ambiguous under {self.root!r}: "
                              f"matches [{ids}] — narrow it (--run takes "
                              f"run-id/label/config globs)")
        return runs[0].run_dir


def glob_manifests(root: str) -> List[str]:
    import glob as _glob
    direct = os.path.join(root, MANIFEST_NAME)
    hits = set(_glob.glob(os.path.join(root, "**", MANIFEST_NAME),
                          recursive=True))
    if os.path.exists(direct):
        hits.add(direct)
    return sorted(hits)
