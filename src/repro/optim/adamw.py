"""AdamW with mixed-precision master weights, global-norm clipping, decay
masking, warmup-cosine schedule — pure JAX (no optax on this box).

State layout (all pytrees mirroring params):
  master  f32 master copy of the (possibly bf16) params
  mu, nu  f32 first/second moments
  step    i32 scalar

ZeRO-1: the optimizer is purely elementwise, so sharding the state over the
data axis is a *layout* decision — parallel/zero.py produces the state
sharding specs (params' spec + largest replicated dim sharded over 'data'),
and pjit's out_shardings do the rest. No optimizer code changes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.device_fold import annotate_cost


def warmup_cosine(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        prog = (step - cfg.warmup_steps) / jnp.maximum(
            cfg.total_steps - cfg.warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        lr = jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)
        return cfg.learning_rate * lr
    return schedule


def _decay_mask(path: str) -> float:
    """No weight decay on norms / scalars / biases (1-D leaves)."""
    for token in ("norm", "scale", "bias", "a_log", "dt_bias", "d_skip",
                  "skip"):
        if token in path:
            return 0.0
    return 1.0


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                    for p in path)


def init_state(params) -> Dict[str, Any]:
    # master must be a DISTINCT buffer even when params are already f32
    # (astype is a no-op alias; donating aliased state buffers is an error)
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"master": f32(params), "mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params, state, grads, cfg: TrainConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    with jax.named_scope("optimizer"):
        step = state["step"] + 1
        lr = warmup_cosine(cfg)(step)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
            if cfg.grad_clip > 0 else 1.0

        b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        masks = jax.tree_util.tree_map_with_path(
            lambda path, x: _decay_mask(_path_str(path)), params)

        def upd(g, mu, nu, master, mask):
            g = g.astype(jnp.float32) * scale
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mu_hat = mu / bc1
            nu_hat = nu / bc2
            delta = mu_hat / (jnp.sqrt(nu_hat) + eps) \
                + cfg.weight_decay * mask * master
            master = master - lr * delta
            return mu, nu, master

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"],
                            state["master"], masks)
        mu = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
        master = jax.tree.map(lambda t: t[2], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), master, params)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        annotate_cost("optimizer", "optimizer", "adamw",
                      flops=12.0 * n_params, bytes=16.0 * n_params)
        new_state = {"master": master, "mu": mu, "nu": nu, "step": step}
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# Gradient compression (int8 with error feedback) — the distributed-
# optimization knob for collective-bound cells. quantize/dequantize are used
# two ways: (a) in-graph QDQ before the (implicit pjit) gradient reduction to
# bound compression error, (b) inside parallel/compress.py's shard_map
# all-reduce where the WIRE format is genuinely int8.
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads, error_state):
    """Error-feedback int8 compression: g' = Q(g + e); e' = (g + e) - g'."""
    def comp(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat = jax.tree.map(comp, grads, error_state)
    new_grads = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, new_err


def init_error_state(params):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
