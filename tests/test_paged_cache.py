"""Paged KV-cache pool tests: block-table serving cache, memory-gated
admission.

The load-bearing invariant: with `ServeConfig.max_cache_pages > 0` the
engine swaps its contiguous [max_batch, max_seq_len] cache for a page
arena + per-slot block tables, and every serving family must stay
TOKEN-IDENTICAL to both the contiguous engine and per-request sequential
decode — paging changes where cache rows live, never what attention
sees.  Checked bottom-up: `update_cache_pages` against the dense row
scatter, the ref/blocked/Pallas(interpret) paged attention kernels
against their dense oracles (including scratch-page garbage invariance
— page 0 content must carry exactly-zero softmax mass), then
engine-level equivalence at chunk widths {1, 3, bucket-padded,
whole-prompt} for every serving family (recurrent families assert the
documented dense fallback instead).  On top: admission semantics —
page exhaustion back-pressures the FCFS queue head without reordering
or deadlock, impossible requests fail structurally at submit(), pages
recycle across request waves (bounded high-water mark, empty allocator
at drain), the per-tick pad-stash scratch is released, and the
(batch bucket, width) compiled-program bound survives paging.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.kernels import ops, ref
from repro.models import layers
from repro.serving import PageAllocator, ServingEngine
from test_serving_engine import (SERVING_ARCHS, build, mixed_prompts,
                                 sequential_decode)

PAGED_ARCHS = ["tinyllama_1_1b", "deepseek_v2_lite_16b"]   # attention KV
DENSE_ARCHS = ["zamba2_2_7b", "xlstm_1_3b"]                # recurrent state


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_programs():
    """This module compiles an unusually large program set (paged+dense
    engines at 5 chunk widths, Pallas interpret kernels); on the CPU CI
    box the executables otherwise stay resident for the rest of the
    session and later suite modules crash inside XLA.  Drop them once
    the module is done."""
    yield
    jax.clear_caches()


def scatter_pages(rng, dense_k, page_size, n_pages, bt=None):
    """Shred per-row dense caches [B, Hkv, S, D] into a page arena with a
    randomly permuted block table (page 0 left as scratch).  Pass `bt` to
    reuse a layout (k and v of one cache share one block table)."""
    B, Hkv, S, D = dense_k.shape
    nb = S // page_size
    assert nb * page_size == S
    if bt is None:
        ids = rng.permutation(np.arange(1, n_pages))[:B * nb]
        bt = ids.reshape(B, nb).astype(np.int32)
    else:
        bt = np.asarray(bt)
    pages = np.asarray(rng.normal(size=(n_pages, Hkv, page_size, D)),
                       np.float32)   # garbage everywhere not granted
    for b in range(B):
        for v in range(nb):
            pages[bt[b, v]] = np.asarray(
                dense_k[:, :, v * page_size:(v + 1) * page_size][b])
    return jnp.asarray(pages), jnp.asarray(bt)


class TestUpdateCachePages:
    @pytest.mark.parametrize("seq_axis,shape", [
        (2, (3, 2, 32, 8)),     # GQA KV cache [B, Hkv, S, D]
        (1, (3, 32, 16)),       # MLA latent cache [B, S, dc]
    ])
    def test_matches_dense_row_scatter(self, seq_axis, shape):
        """Scatter-through-indirection == the dense row-range scatter when
        the block table is the identity layout."""
        rng = np.random.default_rng(0)
        B, ps, T = shape[0], 8, 5
        S = shape[seq_axis]
        nb = S // ps
        dense = jnp.asarray(rng.normal(size=shape), jnp.float32)
        src_shape = list(shape)
        src_shape[seq_axis] = T
        src = jnp.asarray(rng.normal(size=src_shape), jnp.float32)
        pos = jnp.asarray([0, 7, 19], jnp.int32)   # straddles page edges
        want = layers.update_cache_rows(dense, src, pos, seq_axis=seq_axis)

        # identity layout: row b's pages are 1+b*nb .. 1+(b+1)*nb-1
        bt = jnp.asarray(1 + np.arange(B * nb).reshape(B, nb), jnp.int32)
        arena_shape = list(shape)
        arena_shape[0] = 1 + B * nb
        arena_shape[seq_axis] = ps
        arena = jnp.zeros(arena_shape, jnp.float32)
        # pre-seed the arena with the dense content so untouched rows match
        for b in range(B):
            for v in range(nb):
                sl = [slice(None)] * dense.ndim
                sl[seq_axis] = slice(v * ps, (v + 1) * ps)
                arena = arena.at[1 + b * nb + v].set(dense[tuple(sl)][b])
        arena = layers.update_cache_pages(arena, src, pos, bt,
                                          seq_axis=seq_axis)
        got = jnp.concatenate(
            [jnp.concatenate([arena[bt[b, v]] for v in range(nb)],
                             axis=seq_axis - 1)[None]
             for b in range(B)])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_pad_rows_land_on_scratch_page(self):
        """A zero block table routes every write to page 0 — the engine's
        pad/overhang contract: real pages stay untouched."""
        rng = np.random.default_rng(1)
        arena = jnp.asarray(rng.normal(size=(4, 2, 8, 4)), jnp.float32)
        src = jnp.ones((1, 2, 3, 4), jnp.float32)
        bt = jnp.zeros((1, 4), jnp.int32)
        out = layers.update_cache_pages(arena, src, jnp.asarray([5]), bt)
        np.testing.assert_array_equal(np.asarray(out[1:]),
                                      np.asarray(arena[1:]))
        assert not np.array_equal(np.asarray(out[0]), np.asarray(arena[0]))


class TestPagedAttentionKernels:
    B, Hq, Hkv, D, PS, NB = 3, 4, 2, 64, 8, 4
    S = PS * NB

    def _fixture(self, seed=0):
        rng = np.random.default_rng(seed)
        k = jnp.asarray(rng.normal(size=(self.B, self.Hkv, self.S, self.D)),
                        jnp.float32)
        v = jnp.asarray(rng.normal(size=(self.B, self.Hkv, self.S, self.D)),
                        jnp.float32)
        kp, bt = scatter_pages(rng, k, self.PS, 1 + 2 * self.B * self.NB)
        vp, _ = scatter_pages(rng, v, self.PS, 1 + 2 * self.B * self.NB,
                              bt=bt)
        return rng, k, v, kp, vp, bt

    def test_gather_kv_pages_roundtrip(self):
        _, k, _, kp, _, bt = self._fixture()
        np.testing.assert_array_equal(
            np.asarray(ref.gather_kv_pages(kp, bt)), np.asarray(k))

    def test_ref_paged_chunk_matches_dense(self):
        rng, k, v, kp, vp, bt = self._fixture()
        T = 5
        q = jnp.asarray(rng.normal(size=(self.B, self.Hq, T, self.D)),
                        jnp.float32)
        pos = jnp.asarray([0, 9, 22], jnp.int32)
        want = ref.chunk_attention(q, k, v, pos=pos)
        got = ref.chunk_attention_paged(q, kp, vp, block_table=bt, pos=pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)

    def test_ref_paged_decode_matches_dense(self):
        rng, k, v, kp, vp, bt = self._fixture(1)
        q = jnp.asarray(rng.normal(size=(self.B, self.Hq, self.D)),
                        jnp.float32)
        kv_len = jnp.asarray([1, 13, 32], jnp.int32)
        want = ref.decode_attention(q, k, v, kv_len=kv_len)
        got = ref.decode_attention_paged(q, kp, vp, block_table=bt,
                                        kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)

    def test_blocked_paged_matches_oracle(self):
        rng, _, _, kp, vp, bt = self._fixture(2)
        T = 3
        q = jnp.asarray(rng.normal(size=(self.B, self.Hq, T, self.D)),
                        jnp.float32)
        pos = jnp.asarray([2, 0, 17], jnp.int32)
        want = ref.chunk_attention_paged(q, kp, vp, block_table=bt, pos=pos)
        got = ref.chunk_attention_paged_blocked(q, kp, vp, block_table=bt,
                                                pos=pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    @pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 4), (2, 1)])
    def test_pallas_chunk_paged_interpret(self, hq, hkv):
        """Pallas paged chunk kernel (interpret mode) == ref oracle,
        across GQA group sizes including Hkv=1 (the MLA latent shape)."""
        rng = np.random.default_rng(3)
        k = jnp.asarray(rng.normal(size=(self.B, hkv, self.S, self.D)),
                        jnp.float32)
        v = jnp.asarray(rng.normal(size=(self.B, hkv, self.S, self.D)),
                        jnp.float32)
        kp, bt = scatter_pages(rng, k, self.PS, 1 + 2 * self.B * self.NB)
        vp, _ = scatter_pages(rng, v, self.PS, 1 + 2 * self.B * self.NB,
                              bt=bt)
        T = 4
        q = jnp.asarray(rng.normal(size=(self.B, hq, T, self.D)),
                        jnp.float32)
        pos = jnp.asarray([0, 11, 25], jnp.int32)
        want = ref.chunk_attention_paged(q, kp, vp, block_table=bt, pos=pos)
        got = ops.chunk_attention_paged(q, kp, vp, block_table=bt, pos=pos,
                                        impl="pallas", interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_pallas_decode_paged_interpret(self):
        rng, _, _, kp, vp, bt = self._fixture(4)
        q = jnp.asarray(rng.normal(size=(self.B, self.Hq, self.D)),
                        jnp.float32)
        kv_len = jnp.asarray([3, 32, 18], jnp.int32)
        want = ref.decode_attention_paged(q, kp, vp, block_table=bt,
                                          kv_len=kv_len)
        got = ops.decode_attention_paged(q, kp, vp, block_table=bt,
                                         kv_len=kv_len, impl="pallas",
                                         interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_scratch_page_garbage_cannot_leak(self):
        """Block-table entries past each row's frontier can point anywhere
        (the engine leaves them 0 = the scratch page, which decode-tick
        overhang writes trash): masked columns must carry exactly-zero
        softmax mass in every paged variant."""
        rng, _, _, kp, vp, bt = self._fixture(5)
        pos = jnp.asarray([1, 9, 17], jnp.int32)   # frontiers mid-arena
        T = 2
        q = jnp.asarray(rng.normal(size=(self.B, self.Hq, T, self.D)),
                        jnp.float32)
        # zero out every block-table entry strictly past the frontier and
        # dump garbage on the scratch page
        bt2 = np.asarray(bt).copy()
        for b in range(self.B):
            first_unused = (int(pos[b]) + T - 1) // self.PS + 1
            bt2[b, first_unused:] = 0
        kp2 = kp.at[0].set(1e4)
        vp2 = vp.at[0].set(-1e4)
        for fn, kw in (
                (ref.chunk_attention_paged, {}),
                (ref.chunk_attention_paged_blocked, {}),
                (ops.chunk_attention_paged,
                 {"impl": "pallas", "interpret": True})):
            want = fn(q, kp, vp, block_table=bt, pos=pos, **kw)
            got = fn(q, kp2, vp2, block_table=jnp.asarray(bt2), pos=pos,
                     **kw)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-5)


class TestPageAllocator:
    def test_reserve_grant_release_accounting(self):
        a = PageAllocator(9, 4)          # 8 usable (page 0 scratch)
        assert a.usable == 8
        assert a.pages_needed(1) == 1 and a.pages_needed(9) == 3
        assert a.try_reserve(1, 5)
        assert not a.try_reserve(2, 4)   # 5 committed, 4 > 3 left
        assert a.try_reserve(2, 3)
        got = a.grant(1, 2)
        assert len(got) == 2 and 0 not in got
        assert a.in_use == 2
        with pytest.raises(RuntimeError):
            a.grant(1, 4)                # exceeds uid 1's reservation (3)
        assert a.release(1) == 2
        a.cancel(2)
        assert a.in_use == 0 and a.hwm == 2
        assert a.try_reserve(3, 8)       # whole pool free again

    def test_rejects_degenerate_pools(self):
        with pytest.raises(ValueError):
            PageAllocator(1, 4)          # scratch page only
        with pytest.raises(ValueError):
            PageAllocator(4, 0)


def paged_scfg(chunk, *, max_batch=3, pages=40, page_size=8, **kw):
    return ServeConfig(max_batch=max_batch, max_seq_len=64, eos_token=-1,
                       prefill_chunk=chunk, min_chunk_bucket=4,
                       page_size=page_size, max_cache_pages=pages, **kw)


class TestPagedEngineEquivalence:
    # chunk=64: whole-prompt admission chunks, both pageable families;
    # chunk=3 (min_chunk_bucket=4): bucket-PADDED continuation chunks
    # whose pad/overhang rows write through zero block-table entries
    # onto the scratch page; chunk=1: token-at-a-time prefill crossing
    # page boundaries on every 8th tick
    @pytest.mark.parametrize("arch,chunk", [
        *[(a, 64) for a in PAGED_ARCHS],
        ("tinyllama_1_1b", 1), ("tinyllama_1_1b", 3),
        ("deepseek_v2_lite_16b", 3),
    ])
    def test_paged_matches_contiguous_and_sequential(self, arch, chunk):
        cfg, model, params = build(arch)
        prompts = mixed_prompts(cfg)
        max_new = [6, 5, 6, 4]

        def drive(paged):
            scfg = paged_scfg(chunk) if paged else ServeConfig(
                max_batch=3, max_seq_len=64, eos_token=-1,
                prefill_chunk=chunk, min_chunk_bucket=4)
            eng = ServingEngine(model, params, scfg)
            assert eng.paged == paged
            reqs = [eng.submit(p, n) for p, n in zip(prompts, max_new)]
            eng.run_until_drained()
            return [r.output for r in reqs]

        paged_out = drive(True)
        assert paged_out == drive(False), f"{arch}: paged != contiguous"
        for out, p, n in zip(paged_out, prompts, max_new):
            assert out == sequential_decode(model, params, p, n), \
                f"{arch}: paged != sequential for prompt len {len(p)}"

    @pytest.mark.parametrize("arch", DENSE_ARCHS)
    def test_recurrent_families_fall_back_dense(self, arch):
        """Recurrent state is O(1) per slot — nothing to page.  Asking for
        pages anyway must degrade gracefully to the contiguous pool and
        stay sequential-identical."""
        cfg, model, params = build(arch)
        assert model.forward_chunk_paged is None
        eng = ServingEngine(model, params, paged_scfg(64))
        assert not eng.paged and eng.allocator is None
        prompts = mixed_prompts(cfg, lengths=(5, 9))
        reqs = [eng.submit(p, 5) for p in prompts]
        eng.run_until_drained()
        for r, p in zip(reqs, prompts):
            assert r.output == sequential_decode(model, params, p, 5)


class TestPageBackPressure:
    def test_exhaustion_backpressures_fcfs_without_reorder(self):
        """3 free slots but pages for ~one long request: the queue head
        waits on pages (not slots), younger requests may NOT jump it,
        and everyone eventually completes token-identically."""
        cfg, model, params = build("tinyllama_1_1b")
        rng = np.random.default_rng(11)
        long = rng.integers(0, cfg.vocab, 40).astype(np.int32)
        shorts = [rng.integers(0, cfg.vocab, 6).astype(np.int32)
                  for _ in range(2)]
        # 40+6-1 rows -> 6 pages of 8; 7 usable pages fit one long OR
        # both shorts (2 pages each), never a long plus anything
        eng = ServingEngine(model, params, paged_scfg(64, pages=8))
        r_long = eng.submit(long, max_new_tokens=6)
        r_shorts = [eng.submit(s, max_new_tokens=6) for s in shorts]
        eng.step()
        assert len(eng.scheduler.active()) == 1   # long admitted alone
        for _ in range(8):
            eng.step()
            # strict FCFS under page pressure: while the long request
            # holds the pool, the shorts stay queued even though slots
            # (and, for the second short, pages) are free
            if not r_long.done:
                assert len(eng.scheduler.active()) == 1
        eng.run_until_drained()
        assert r_long.done and all(r.done for r in r_shorts)
        assert r_long.output == sequential_decode(model, params, long, 6)
        for r, s in zip(r_shorts, shorts):
            assert r.output == sequential_decode(model, params, s, 6)
        assert eng.allocator.in_use == 0

    def test_impossible_request_fails_at_submit(self):
        cfg, model, params = build("tinyllama_1_1b")
        eng = ServingEngine(model, params, paged_scfg(64, pages=4))
        prompt = np.arange(40, dtype=np.int32) % cfg.vocab
        with pytest.raises(ValueError, match="pages"):
            eng.submit(prompt, max_new_tokens=8)
        # the pool is untouched and serviceable afterwards
        assert eng.allocator.in_use == 0
        r = eng.submit(prompt[:10], max_new_tokens=4)
        eng.run_until_drained()
        assert r.done


class TestPageRecycling:
    def test_two_waves_bounded_hwm_and_clean_drain(self):
        cfg, model, params = build("tinyllama_1_1b")
        eng = ServingEngine(model, params, paged_scfg(64, pages=24))
        prompts = mixed_prompts(cfg, seed=9, lengths=(9, 5, 12, 7))

        def wave():
            reqs = [eng.submit(p, 4) for p in prompts]
            eng.run_until_drained()
            assert all(r.done for r in reqs)

        wave()
        hwm1 = eng.allocator.hwm
        assert 0 < hwm1 <= eng.allocator.usable
        wave()
        assert eng.allocator.hwm == hwm1, \
            "second wave grew the page HWM: pages are not being recycled"
        assert eng.allocator.in_use == 0
        assert not eng.block_tables.any()
        # satellite: the bucket-pad gather scratch is per-TICK, not
        # retained for the engine's lifetime
        assert eng._pad_stashes == {}

    def test_pad_stashes_released_after_drain_dense_too(self):
        cfg, model, params = build("tinyllama_1_1b")
        eng = ServingEngine(model, params, ServeConfig(
            max_batch=3, max_seq_len=64, eos_token=-1, prefill_chunk=3,
            min_chunk_bucket=4))
        reqs = [eng.submit(p, 4) for p in mixed_prompts(cfg, seed=4)]
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert eng._pad_stashes == {}


class TestPagedProgramBound:
    def test_chunk_program_lattice_survives_paging(self):
        """Paging threads one extra operand through forward_chunk; the
        (batch bucket, width) compiled-program set must not grow beyond
        the dense engine's on the same workload."""
        cfg, model, params = build("tinyllama_1_1b")
        prompts = mixed_prompts(cfg, seed=6, lengths=(3, 7, 5, 9, 11, 4))

        def programs(paged):
            scfg = paged_scfg(4) if paged else ServeConfig(
                max_batch=3, max_seq_len=64, eos_token=-1, prefill_chunk=4,
                min_chunk_bucket=4)
            eng = ServingEngine(model, params, scfg)
            for p in prompts:
                eng.submit(p, 3)
            eng.run_until_drained()
            return eng.chunk_programs

        assert programs(True) == programs(False)

    def test_paged_gauges_fold_into_profile_shard(self, tmp_path):
        cfg, model, params = build("tinyllama_1_1b")
        eng = ServingEngine(model, params, paged_scfg(
            64, profile_dir=str(tmp_path), profile_interval_ticks=1))
        for p in mixed_prompts(cfg, seed=8, lengths=(5, 9)):
            eng.submit(p, 4)
        eng.run_until_drained()
        eng.write_profile_shard()
        from repro.profile.store import ProfileStore
        edges = ProfileStore(str(tmp_path)).reduce().to_folded().edges
        apis = {k[2] for k in edges}
        for gauge in ("cache_pages_in_use", "cache_page_hwm",
                      "cache_pages_capacity"):
            assert gauge in apis, f"serve.{gauge} missing from shard"
