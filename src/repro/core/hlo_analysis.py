"""Loop-aware static analysis of optimized HLO — the roofline's data source.

XLA's HloCostAnalysis (and compiled.cost_analysis()) counts each while-loop
BODY ONCE, so for scan-over-layers models it under-reports FLOPs, bytes and
collective traffic by the trip count (verified empirically in
tests/test_hlo_analysis.py). This module re-analyzes the optimized HLO text
with loop multiplicity:

  1. split the module into computations, building a per-computation symbol
     table (%name -> shape; operands carry no inline types in optimized HLO),
  2. find every `while`, read its trip count from the condition computation
     (jax scans lower to `compare(iv, constant(N))`),
  3. propagate multipliers through the call graph (body/condition/calls/
     to_apply/branches — nested scans multiply),
  4. per computation count
       * dot FLOPs:   2 · prod(result dims) · prod(lhs contracting dims)
       * op IO bytes: result + operand bytes of buffer-level ops
       * collective wire bytes (ring model; replica-group axis attribution)
  5. total = Σ per-computation cost × multiplier.

This is the Scaler move transplanted: read the binary instead of
instrumenting the program — zero runtime overhead, exact static structure.
The paper reads .rela.plt; we read the HLO module.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .hlo_flows import (COLLECTIVE_KINDS, DTYPE_BYTES, _GROUPS_EXPLICIT_RE,
                        _GROUPS_IOTA_RE, _OPNAME_RE, _resolve_axis,
                        _resolve_component)

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{$")
_DEF = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
#: model scopes whose inner loops are Pallas-kernel stand-ins — their loop
#: bodies' buffers live in VMEM on TPU, not HBM; their HBM traffic is
#: accounted analytically by the XFA static layer (kernels/ops annotate_cost)
KERNEL_SCOPES = ("attention", "norm", "ssm", "mlstm", "slstm")

_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"%([\w\.\-]+)")

# buffer-level ops whose IO approximates HBM traffic in optimized HLO.
# Raw elementwise ops are EXCLUDED: on TPU they fuse; the CPU-backend HLO we
# analyze wraps them in kLoop `fusion` ops whose boundary IO we do count.
_BYTES_OPS = {
    "fusion", "dot", "custom-call", "copy", "reduce", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "pad", "concatenate",
    "slice", "transpose", "select-and-scatter", "sort",
    "convolution", "reverse", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute",
}


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() with the cross-version shape normalized:
    jax <= 0.4.x returns [dict] (one per program), newer jax returns dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return ca or {}


def _shape_info(type_str: str) -> Tuple[int, List[List[int]]]:
    """(total bytes, list of dim-lists) for a (possibly tuple) type string."""
    total = 0
    dims_list = []
    for m in _SHAPE.finditer(type_str):
        dtype, dims_s = m.group(1), m.group(2)
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES.get(dtype, 4)
        dims_list.append(dims)
    return total, dims_list


def _split_def(rhs: str) -> Tuple[str, str, str, str]:
    """rhs of '=' -> (result_type_str, op_kind, operand_str, attr_str)."""
    # op kind is the first lowercase word followed by '(' after the type
    m = re.search(r"\s([a-z][a-z0-9\-]*)\(", rhs)
    if not m:
        return rhs, "", "", ""
    kind = m.group(1)
    result_part = rhs[: m.start()]
    rest = rhs[m.end():]
    depth = 1
    i = 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    return result_part, kind, rest[: i - 1], rest[i:]


@dataclass
class CollectiveOp:
    kind: str
    wire_bytes: float
    axis: str
    component: str
    comp_name: str
    bytes_moved: float


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    symbols: Dict[str, Tuple[int, List[List[int]]]] = field(default_factory=dict)
    while_refs: List[Tuple[str, str]] = field(default_factory=list)
    call_refs: List[str] = field(default_factory=list)
    fusion_refs: List[str] = field(default_factory=list)
    kernel_bodies: set = field(default_factory=set)
    cond_consts: List[int] = field(default_factory=list)
    flops: float = 0.0
    io_bytes: float = 0.0
    collectives: List[CollectiveOp] = field(default_factory=list)
    fusion_only: bool = False          # set by compute_multipliers
    vmem_internal: bool = False        # inside a kernel-scope while loop


def parse_module(text: str, known_components: Sequence[str] = (),
                 mesh_axes: Optional[Dict[str, int]] = None
                 ) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    mesh_axes = mesh_axes or {}
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            h = _COMP_HEADER.match(line)
            if h:
                cur = Computation(name=h.group(2), is_entry=bool(h.group(1)))
                comps[cur.name] = cur
            continue
        if line == "}":
            cur = None
            continue
        d = _DEF.match(line)
        if not d:
            continue
        name, rhs = d.group(1), d.group(2)
        result_part, kind, operand_str, attr_str = _split_def(rhs)
        res_bytes, res_dims = _shape_info(result_part)
        cur.symbols[name] = (res_bytes, res_dims)

        if kind == "while":
            c = _COND.search(attr_str)
            b = _BODY.search(attr_str)
            if c and b:
                om = _OPNAME_RE.search(raw)
                scope = om.group(1) if om else ""
                kernel = any(f"/{ks}/" in scope or scope.endswith(f"/{ks}")
                             for ks in KERNEL_SCOPES)
                cur.while_refs.append((c.group(1), b.group(1)))
                if kernel:
                    cur.kernel_bodies.add(b.group(1))
                    cur.kernel_bodies.add(c.group(1))
            continue
        for cm in _CALLS.finditer(attr_str):
            # fusion-called computations are FUSED: their ops produce no
            # buffers (IO is the fusion op's boundary), but dots inside them
            # are real FLOPs -> track the ref kind.
            if kind == "fusion":
                cur.fusion_refs.append(cm.group(1))
            else:
                cur.call_refs.append(cm.group(1))
        bm = _BRANCHES.search(attr_str)
        if bm:
            cur.call_refs += [n.strip().lstrip("%") for n in
                              bm.group(1).split(",")]
        for im in _CONST_INT.finditer(rhs):
            cur.cond_consts.append(int(im.group(1)))

        operands = _OPERANDS.findall(operand_str)
        op_bytes_list = [cur.symbols.get(o, (0, []))[0] for o in operands]
        op_bytes = sum(op_bytes_list)

        if kind == "dot":
            lhs_dims = cur.symbols.get(operands[0], (0, [[]]))[1]
            lhs_dims = lhs_dims[0] if lhs_dims else []
            result_elems = 1
            for dl in res_dims:
                for dd in dl:
                    result_elems *= dd
            contract = 1
            cm2 = _LHS_CONTRACT.search(attr_str)
            if cm2 and cm2.group(1).strip():
                for idx in cm2.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
            cur.flops += 2.0 * result_elems * contract

        if kind in COLLECTIVE_KINDS or (kind.endswith("-start") and
                                        kind[:-6] in COLLECTIVE_KINDS):
            base = kind[:-6] if kind.endswith("-start") else kind
            group_size, group_stride = 1, 1
            gm = _GROUPS_IOTA_RE.search(attr_str)
            if gm:
                n_groups, g_size = int(gm.group(1)), int(gm.group(2))
                group_size = g_size
                group_stride = n_groups if gm.group(3) else 1
            else:
                gm2 = _GROUPS_EXPLICIT_RE.search(attr_str)
                if gm2:
                    ids = [int(x) for x in
                           gm2.group(1).replace(" ", "").split(",") if x]
                    group_size = len(ids)
                    group_stride = (ids[1] - ids[0]) if len(ids) > 1 else 1
            if base == "collective-permute":
                group_size = 2
            n = max(group_size, 1)
            f = (n - 1) / n if n > 1 else 0.0
            if base == "all-gather":
                moved = res_bytes
                wire = f * res_bytes
            elif base == "reduce-scatter":
                moved = op_bytes
                wire = f * op_bytes
            elif base == "all-reduce":
                moved = op_bytes
                wire = 2.0 * f * op_bytes
            elif base == "all-to-all":
                moved = op_bytes
                wire = f * op_bytes
            else:  # collective-permute
                moved = op_bytes
                wire = float(op_bytes)
            om = _OPNAME_RE.search(raw)
            op_name = om.group(1) if om else ""
            cur.collectives.append(CollectiveOp(
                kind=base, wire_bytes=wire,
                axis=_resolve_axis(group_size, group_stride, mesh_axes)
                if mesh_axes else f"size{group_size}",
                component=_resolve_component(op_name, known_components),
                comp_name=cur.name, bytes_moved=moved))

        if kind in _BYTES_OPS:
            cur.io_bytes += _op_io(kind, name, res_bytes, op_bytes_list)
    return comps


def _op_io(kind: str, op_name: str, res_bytes: int,
           op_bytes_list: List[int]) -> float:
    """HBM traffic model for one buffer-level op: 2 x result bytes
    (buffer written once + read ~once by its consumer).

    Counting full operand bytes per use would bill a buffer once per
    consumer and blow up 10-50x on CPU-backend HLO, whose fusion granularity
    is much finer than TPU's (measured on tinyllama train_4k — EXPERIMENTS.md
    §Perf iteration 0). Counting writes is fusion-invariant: every buffer
    that exists is written exactly once. Update-like ops alias their big
    operand in place and touch only the updated region (~ the non-buffer
    operands)."""
    total = sum(op_bytes_list)
    largest = max(op_bytes_list, default=0)
    tag = op_name if kind == "fusion" else kind
    if "dynamic-update-slice" in tag or "scatter" in tag:
        return 2.0 * (total - largest)
    return 2.0 * res_bytes


def trip_count(cond: Computation) -> int:
    """jax scan conditions compare the induction var with constant(N)."""
    return max(cond.cond_consts) if cond.cond_consts else 1


def compute_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry.name] = 1.0
    for _ in range(len(comps) + 2):
        changed = False
        for c in comps.values():
            m = mult.get(c.name, 0.0)
            if m == 0.0:
                continue
            for cond_name, body_name in c.while_refs:
                trips = trip_count(comps[cond_name]) \
                    if cond_name in comps else 1
                for target, factor in ((body_name, trips),
                                       (cond_name, trips + 1)):
                    if target in comps and mult[target] < m * factor:
                        mult[target] = m * factor
                        changed = True
            for name in c.call_refs + c.fusion_refs:
                if name in comps and mult[name] < m:
                    mult[name] = m
                    changed = True
        if not changed:
            break
    # mark computations reachable ONLY through fusion calls: FLOPs count,
    # buffer IO does not (the fusion boundary already accounted it)
    control_reach = set()
    entry2 = next((c for c in comps.values() if c.is_entry), None)
    frontier = [entry2.name] if entry2 else []
    while frontier:
        name = frontier.pop()
        if name in control_reach or name not in comps:
            continue
        control_reach.add(name)
        c = comps[name]
        for cond_name, body_name in c.while_refs:
            frontier += [cond_name, body_name]
        frontier += c.call_refs
    for name, c in comps.items():
        c.fusion_only = name not in control_reach
    # mark kernel-internal (VMEM) subtrees: bodies of while loops under a
    # kernel named_scope, and everything they reach
    kernel_roots = set()
    for c in comps.values():
        kernel_roots |= c.kernel_bodies
    frontier = list(kernel_roots)
    internal = set()
    while frontier:
        name = frontier.pop()
        if name in internal or name not in comps:
            continue
        internal.add(name)
        c = comps[name]
        for cond_name, body_name in c.while_refs:
            frontier += [cond_name, body_name]
        frontier += c.call_refs + c.fusion_refs
    for name, c in comps.items():
        c.vmem_internal = name in internal
    for name, v in mult.items():
        if v == 0.0:
            mult[name] = 1.0   # unreached (dead) computations: count once
    return mult


@dataclass
class ModuleCosts:
    flops: float                      # loop-aware dot FLOPs (per device)
    io_bytes: float                   # loop-aware buffer IO bytes (per device)
    wire_bytes: float                 # loop-aware collective wire bytes
    multipliers: Dict[str, float]
    flops_body_once: float
    by_kind_wire: Dict[str, float] = field(default_factory=dict)
    by_axis_wire: Dict[str, float] = field(default_factory=dict)
    by_component_wire: Dict[str, float] = field(default_factory=dict)
    collectives: List[Tuple[str, str, str, float, float]] = \
        field(default_factory=list)   # (kind, component, axis, wire, mult)
    n_collectives: int = 0


def analyze_module(text: str, known_components: Sequence[str] = (),
                   mesh_axes: Optional[Dict[str, int]] = None) -> ModuleCosts:
    comps = parse_module(text, known_components, mesh_axes)
    mult = compute_multipliers(comps)

    flops = sum(c.flops * mult[c.name] for c in comps.values())
    flops_once = sum(c.flops for c in comps.values())
    io_bytes = sum(c.io_bytes * mult[c.name] for c in comps.values()
                   if not (c.fusion_only or c.vmem_internal))

    wire = 0.0
    by_kind: Dict[str, float] = {}
    by_axis: Dict[str, float] = {}
    by_comp: Dict[str, float] = {}
    schedule = []
    n = 0
    for c in comps.values():
        m = mult[c.name]
        for col in c.collectives:
            wb = col.wire_bytes * m
            wire += wb
            n += 1
            by_kind[col.kind] = by_kind.get(col.kind, 0.0) + wb
            by_axis[col.axis] = by_axis.get(col.axis, 0.0) + wb
            by_comp[col.component] = by_comp.get(col.component, 0.0) + wb
            schedule.append((col.kind, col.component, col.axis,
                             col.wire_bytes, m))
    return ModuleCosts(flops=flops, io_bytes=io_bytes, wire_bytes=wire,
                       multipliers=mult, flops_body_once=flops_once,
                       by_kind_wire=by_kind, by_axis_wire=by_axis,
                       by_component_wire=by_comp, collectives=schedule,
                       n_collectives=n)
