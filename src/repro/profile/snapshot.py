"""Columnar profile snapshots — the on-disk form of a FoldedTable.

This module WRITES schema version 3 (current, SCHEMA_VERSION) and READS
schemas 1–3.  The writer is *minimal-schema*: it emits the LOWEST
version that represents the content — no histogram block and no
sampling rates is the exact schema-1 byte layout; histograms without
sampling rates is the exact schema-2 layout; the schema-3 layout (an
optional `sample_rate` column from the adaptive overhead governor,
core.sampler) appears only when at least one edge was actually
subsampled.  Old files stay readable by new readers, rate-less files
stay byte-identical to their v1/v2 goldens.  See docs/schema.md for
the full layout reference.

One snapshot file is a compressed npz holding:

  __header__        uint8 bytes of a json document: schema version, group,
                    free-form meta (host/pid/label/...), the interned string
                    table, the metric name list, and (v2) n_hist_buckets —
                    the SlotRegistry half of the serialization
  caller/component/api   int32 [N] indices into the string table (the
                    relation-aware (caller, callee, api) key, columnar)
  kind              int8  [N]
  count/total_ns/child_ns/min_ns/max_ns   int64 [N] aligned stat columns
  metric_values     float64 [M, N]
  metric_mask       bool    [M, N]  (presence — absent metric != 0.0 metric)
  hist              uint64 [N, HIST_BUCKETS] latency histograms — schema 2+
                    only; an all-zero row means "no distribution" for
                    that edge (core.histogram)
  sample_rate       float64 [N] effective timing-sample rate — schema 3
                    only; a 1.0 row means "fully sampled" for that edge
                    (counts are always exact; time columns of a row with
                    rate < 1.0 are unbiased scale-ups, core.sampler)

The columns are exactly core.folding.EdgeColumns, so loading a snapshot
drops straight into the vectorized merge path without re-boxing per-edge
EdgeStats objects.  Round-trip is lossless: FoldedTable -> snapshot ->
FoldedTable preserves every stat, kind, metric, metric-presence bit and
histogram bucket.
"""

from __future__ import annotations

import json
import os
import tempfile
import zipfile
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np
from numpy.lib import format as _npformat

from ..core.folding import EdgeColumns, FoldedTable, merge_columns
from ..core.histogram import HIST_BUCKETS

#: bump on any incompatible layout change; loaders reject newer majors.
#: v1: stat columns + metrics.  v2: adds the optional uint64 [N, B]
#: `hist` member (+ `n_hist_buckets` header key).  v3: adds the optional
#: float64 [N] `sample_rate` member.  The writer emits the LOWEST
#: version that represents the content (see module docstring).
SCHEMA_VERSION = 3

SNAPSHOT_SUFFIX = ".xfa.npz"

_HEADER_KEY = "__header__"

#: fixed zip member timestamp (the zip epoch) — snapshot bytes must be a
#: function of their CONTENT only, so identical profiles hash/compare equal
#: and the golden-file schema test can pin the v1 layout byte-for-byte.
_ZIP_EPOCH = (1980, 1, 1, 0, 0, 0)


def _write_npz(f, arrays: Dict[str, np.ndarray], compress: bool) -> None:
    """np.savez_compressed replacement with deterministic output: fixed
    member timestamps/attributes and caller-controlled member order.  The
    result is a regular npz that np.load reads unchanged."""
    method = zipfile.ZIP_DEFLATED if compress else zipfile.ZIP_STORED
    with zipfile.ZipFile(f, "w", method) as zf:
        for name, arr in arrays.items():
            zi = zipfile.ZipInfo(name + ".npy", date_time=_ZIP_EPOCH)
            zi.compress_type = method
            zi.external_attr = 0o644 << 16
            with zf.open(zi, "w") as member:
                _npformat.write_array(member, np.asanyarray(arr),
                                      allow_pickle=False)


@dataclass
class ProfileSnapshot:
    """A FoldedTable in columnar form + provenance metadata."""

    columns: EdgeColumns
    meta: Dict[str, Any] = field(default_factory=dict)
    schema: int = SCHEMA_VERSION

    # -- constructors -------------------------------------------------------
    @staticmethod
    def from_folded(folded: FoldedTable,
                    meta: Optional[Dict[str, Any]] = None) -> "ProfileSnapshot":
        return ProfileSnapshot(EdgeColumns.from_folded(folded),
                               meta=dict(meta or {}))

    @staticmethod
    def merge(snaps: Sequence["ProfileSnapshot"],
              meta: Optional[Dict[str, Any]] = None) -> "ProfileSnapshot":
        """Reduce N shards into one snapshot (columnar, order-insensitive)."""
        cols = merge_columns([s.columns for s in snaps])
        merged_meta: Dict[str, Any] = {
            "merged_from": [s.meta.get("label", "?") for s in snaps],
            "n_shards": len(snaps),
        }
        merged_meta.update(meta or {})
        return ProfileSnapshot(cols, meta=merged_meta)

    # -- views ----------------------------------------------------------------
    @property
    def group(self) -> str:
        return self.columns.group

    def to_folded(self) -> FoldedTable:
        return self.columns.to_folded()

    def __len__(self) -> int:
        return len(self.columns)

    # -- disk -----------------------------------------------------------------
    def save(self, path: str, compress: bool = True) -> str:
        """Atomic write (tmp + rename): periodic snapshotters overwrite their
        shard in place and a crashed writer never leaves a torn file.  The
        bytes are deterministic in the snapshot content (fixed zip metadata);
        `compress=False` additionally removes the zlib dependence, which is
        what checked-in golden/baseline files use."""
        cols = self.columns
        strings: Dict[str, int] = {}

        def intern(parts: List[str]) -> np.ndarray:
            return np.fromiter((strings.setdefault(s, len(strings))
                                for s in parts), dtype=np.int32,
                               count=len(parts))

        caller = intern([k[0] for k in cols.keys])
        component = intern([k[1] for k in cols.keys])
        api = intern([k[2] for k in cols.keys])
        # minimal-schema rule: bytes are a function of CONTENT — content
        # without histograms/rates is exactly a v1 file, without rates a
        # v2 file — old readers keep working and the v1/v2 goldens stay
        # pinned.  An all-1.0 rate column IS rate-less content (every
        # edge fully sampled), so merges that normalize back to full
        # sampling shed the column on disk.
        rates = cols.sample_rate
        if rates is not None and not (rates < 1.0).any():
            rates = None
        schema_out = 3 if rates is not None else \
            (2 if cols.hist is not None else 1)
        header = {
            "schema": schema_out,
            "group": cols.group,
            "meta": self.meta,
            "strings": list(strings),
            "metric_names": list(cols.metric_names),
            "n_edges": len(cols),
        }
        if cols.hist is not None:
            header["n_hist_buckets"] = int(cols.hist.shape[1])
        header_bytes = np.frombuffer(
            json.dumps(header, sort_keys=True).encode("utf-8"), dtype=np.uint8)
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                arrays = {
                    _HEADER_KEY: header_bytes,
                    "caller": caller, "component": component, "api": api,
                    "kind": cols.kind, "count": cols.count,
                    "total_ns": cols.total_ns, "child_ns": cols.child_ns,
                    "min_ns": cols.min_ns, "max_ns": cols.max_ns,
                    "metric_values": cols.metric_values,
                    "metric_mask": cols.metric_mask,
                }
                if cols.hist is not None:
                    arrays["hist"] = cols.hist
                if rates is not None:
                    arrays["sample_rate"] = rates.astype(np.float64)
                _write_npz(f, arrays, compress=compress)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path

    @staticmethod
    def load(path: str) -> "ProfileSnapshot":
        with np.load(path) as z:
            if _HEADER_KEY not in z:
                raise ValueError(f"{path}: not an XFA profile snapshot")
            header = json.loads(bytes(z[_HEADER_KEY]).decode("utf-8"))
            schema = int(header.get("schema", -1))
            if schema > SCHEMA_VERSION or schema < 1:
                raise ValueError(
                    f"{path}: snapshot schema {schema} not supported by this "
                    f"reader (supports <= {SCHEMA_VERSION})")
            strings = header["strings"]
            caller = z["caller"]
            component = z["component"]
            api = z["api"]
            keys = [(strings[c], strings[m], strings[a])
                    for c, m, a in zip(caller, component, api)]
            hist = None
            if "hist" in z.files:
                hist = z["hist"].astype(np.uint64)
                nb = int(header.get("n_hist_buckets", hist.shape[1]))
                if hist.shape != (len(keys), nb) or nb != HIST_BUCKETS:
                    raise ValueError(
                        f"{path}: hist block {hist.shape} does not match "
                        f"{len(keys)} edges x {HIST_BUCKETS} buckets")
            rate = None
            if "sample_rate" in z.files:
                rate = z["sample_rate"].astype(np.float64)
                if rate.shape != (len(keys),):
                    raise ValueError(
                        f"{path}: sample_rate column {rate.shape} does not "
                        f"match {len(keys)} edges")
            cols = EdgeColumns(
                keys=keys,
                count=z["count"].astype(np.int64),
                total_ns=z["total_ns"].astype(np.int64),
                child_ns=z["child_ns"].astype(np.int64),
                min_ns=z["min_ns"].astype(np.int64),
                max_ns=z["max_ns"].astype(np.int64),
                kind=z["kind"].astype(np.int8),
                metric_names=list(header["metric_names"]),
                metric_values=z["metric_values"].astype(np.float64),
                metric_mask=z["metric_mask"].astype(bool),
                group=header.get("group", "main"),
                hist=hist,
                sample_rate=rate,
            )
        if len(cols) != int(header.get("n_edges", len(cols))):
            raise ValueError(f"{path}: edge count mismatch vs header")
        return ProfileSnapshot(cols, meta=dict(header.get("meta", {})),
                               schema=schema)
