"""GPipe-style pipeline parallelism over a 'stage' mesh axis (shard_map).

For models deeper than TP+DP can feed (or when a pod's ICI topology favors
ring neighbors), layers split into S stages; M microbatches stream through
with the classic GPipe schedule: at tick t, stage s processes microbatch
t - s. Mapped onto jax:

  * stage s's layer parameters live on the ranks of stage s
    (in_specs P('stage', ...) over a [S, ...] stacked stage-param tree);
  * activations hop stages via ONE collective-permute per tick (ring
    neighbor traffic — the cheapest link pattern on a torus);
  * the schedule is a lax.scan over T = M + S - 1 ticks; bubbles are the
    standard (S-1)/(M+S-1) fraction and show up in the XFA device fold as
    wasted ticks (the 'Wait' pseudo-component of pipelining).

This is the forward pipeline (serving / building block). Training composes
it with jax.grad through the scan+permute (both differentiable); the
equivalence test covers fwd and grad-through-pipeline on a 4-stage mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map


def gpipe_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                stage_params: Any, microbatches: jax.Array, mesh: Mesh,
                *, axis: str = "stage") -> jax.Array:
    """Run `microbatches` [M, B, ...] through S pipeline stages.

    stage_fn(params_s, x) -> x must be shape-preserving; stage_params is a
    pytree whose leaves are stacked [S, ...]. Returns [M, B, ...] outputs
    (microbatch i = stage_{S-1}(...stage_0(mb_i))).
    """
    S = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    M = microbatches.shape[0]
    T = M + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    def per_stage(params_s, mbs):
        # params_s: this stage's params (leading stage dim stripped by
        # shard_map); mbs: [M, B, ...] (replicated across stages)
        s = jax.lax.axis_index(axis)
        params_local = jax.tree.map(lambda a: a[0], params_s)
        zero = jnp.zeros_like(mbs[0])

        def tick(carry, t):
            cur = carry                       # activation arriving this tick
            idx = t - s                       # microbatch this stage handles
            active = jnp.logical_and(idx >= 0, idx < M)
            # stage 0 ingests a fresh microbatch; others take the carry
            inp = jnp.where(s == 0, mbs[jnp.clip(t, 0, M - 1)], cur)
            out = stage_fn(params_local, inp)
            out = jnp.where(active, out, inp)  # bubbles pass through
            with jax.named_scope("pipeline"):
                nxt = jax.lax.ppermute(out, axis, perm)
            # the LAST stage's outs are the pipeline's results
            return nxt, out

        _, outs = jax.lax.scan(tick, zero, jnp.arange(T))   # [T, B, ...]
        # microbatch i leaves the last stage at tick i + (S-1)
        results = outs[S - 1:]                              # [M, B, ...]
        return results[None]                                # stage dim back

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P()),
        out_specs=P(axis),
        check_vma=False)
    stacked = fn(stage_params, microbatches)                # [S, M, B, ...]
    return stacked[-1]                                      # last stage's


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """The GPipe idle fraction — fed to the XFA 'Wait' attribution."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def split_stages(stacked_layer_params: Any, n_stages: int) -> Any:
    """[L, ...] stacked layer params -> [S, L/S, ...] per-stage stacks."""
    def re(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree.map(re, stacked_layer_params)
