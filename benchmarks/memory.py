"""Paper Table 5 analogue: memory is O(#edges), not O(#events).

Scaler: 15.5% memory overhead because Relation-Aware Data Folding never
appends. We fold a synthetic stream and compare the shadow-table bytes with
what an append-style event log (ltrace/perf model) would need, at several
stream lengths — the fold's slope over events must be ZERO."""

from __future__ import annotations

import sys

from repro.core import Tracer
from repro.core.folding import FoldedTable

EDGES = [("app", "glibc", f"api{i}") for i in range(64)] + \
        [("moe", "glibc", f"api{i}") for i in range(32)]

EVENT_BYTES = 32  # (caller_id, callee_id, api_id, t_start, t_end) packed


def run():
    rows = []
    t = Tracer()
    fns = {}
    for caller, comp, api in EDGES:
        slot = t.tables.registry.resolve(caller, comp, api)
        fns[(caller, comp, api)] = slot
    prev = None
    for n_events in (10_000, 100_000, 1_000_000):
        table = t.tables.table()
        for i in range(n_events if prev is None else n_events - prev):
            slot = fns[EDGES[i % len(EDGES)]]
            table.record(slot.slot, 100)
        prev = n_events
        fold_bytes = t.tables.nbytes()
        log_bytes = n_events * EVENT_BYTES
        rows.append((f"memory.fold_bytes@{n_events}", fold_bytes,
                     f"append log would be {log_bytes}"))
        rows.append((f"memory.ratio@{n_events}", log_bytes / fold_bytes,
                     "x smaller than a log"))
    # the paper's accuracy claim: the fold still has every edge
    folded = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
    assert len(folded) == len(EDGES), "fold lost edges!"
    rows.append(("memory.edges_preserved", len(folded), "relation-aware"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")
