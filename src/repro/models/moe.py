"""Mixture-of-Experts layer: top-k routing, capacity dispatch, EP all-to-all.

Two dispatch modes, equivalence-tested against each other:

  * 'a2a'   (production, default under a mesh): shard_map over the mesh with
            tokens sharded over (pod, data, MODEL) — i.e. the TP axis doubles
            as the expert-parallel axis, DeepSpeed-MoE style. Each rank
            routes its ~T/512 local tokens, sort+scatters them into a
            [E, C_loc, d] capacity buffer, exchanges buffers over the EP axis
            with jax.lax.all_to_all, runs its local expert shard's FFNs, and
            returns them by the inverse all-to-all. The a2a pair appears in
            the dry-run HLO under the 'moe' scope and feeds the roofline
            collective term.
  * 'dense' (no mesh / smoke tests): GShard one-hot dispatch-combine einsum,
            O(T·E·C) masks — fine at test scale, same semantics.

XFA integration: the layer emits *data-dependent* metrics into the device
fold table — per-expert load (tokens routed), dropped-token count, router
aux/z losses — the signals behind the paper's ferret (imbalance) case study,
which no static analysis can see.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.device_fold import DeviceFoldSpec, annotate_cost
from repro.parallel.axes import axis_size, get_runtime_mesh, shard
from repro.parallel.compat import shard_map

from .layers import Params, Runtime, _init, linear, pdtype

MOE_CALLER = "decoder"


def declare_moe_slots(spec: DeviceFoldSpec, cfg: ModelConfig) -> None:
    spec.declare(MOE_CALLER, "moe", "dispatch", "expert_load", cfg.n_experts)
    spec.declare(MOE_CALLER, "moe", "dispatch", "dropped_tokens")
    spec.declare(MOE_CALLER, "moe", "router", "aux_loss")
    spec.declare(MOE_CALLER, "moe", "router", "z_loss")
    spec.declare(MOE_CALLER, "moe", "dispatch", "count")


def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = pdtype(cfg)
    p: Dict[str, Any] = {
        "router": _init(ks[0], (d, e), dt, scale=d ** -0.5),
        "w_gate": _init(ks[1], (e, d, f), dt),
        "w_up": _init(ks[2], (e, d, f), dt),
        "w_down": _init(ks[3], (e, f, d), dt),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _init(sk[0], (d, fs), dt),
            "w_up": _init(sk[1], (d, fs), dt),
            "w_down": _init(sk[2], (fs, d), dt),
        }
    return {"moe": p}


def _router(router_w, x2: jax.Array, cfg: ModelConfig):
    """x2: [T, d] -> (gates [T,K] f32, idx [T,K], aux, z)."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)      # renormalize
    # Switch-style load-balance aux (over all K choices) + router z-loss
    E = probs.shape[-1]
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # [T,K,E]
    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)             # [E]
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e) / cfg.top_k
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, idx, aux, z


def _expert_ffn(w_gate, w_up, w_down, xb: jax.Array) -> jax.Array:
    """xb: [E_loc, C, d] -> [E_loc, C, d]; SwiGLU per expert."""
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate.astype(xb.dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, w_up.astype(xb.dtype))
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(xb.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(xb.dtype))


def _local_dispatch(x2, idx, E: int, C: int):
    """Sort+scatter capacity dispatch of local tokens.

    x2: [T, d]; idx: [T, K]. Returns (buf [E, C, d], combine meta,
    n_dropped)."""
    T, K = idx.shape
    flat_e = idx.reshape(-1)                                   # [TK]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    offsets = jnp.cumsum(counts) - counts                      # exclusive
    pos = jnp.arange(T * K) - offsets[sorted_e]                # rank in expert
    keep = pos < C
    n_dropped = jnp.sum(jnp.logical_not(keep))
    tok = order // K                                           # source token
    safe_e = jnp.where(keep, sorted_e, E)                      # OOB -> dropped
    safe_p = jnp.where(keep, pos, 0)
    buf = jnp.zeros((E + 1, C) + x2.shape[1:], x2.dtype)
    buf = buf.at[safe_e, safe_p].set(x2[tok], mode="drop")
    meta = (order, safe_e, safe_p, keep, tok)
    return buf[:E], meta, n_dropped


def _local_combine(yb, meta, gates, T: int):
    """yb: [E, C, d] -> [T, d] f32, weighted by gates [T, K]."""
    order, safe_e, safe_p, keep, tok = meta
    gathered = yb[jnp.minimum(safe_e, yb.shape[0] - 1), safe_p]  # [TK, d]
    g_flat = gates.reshape(-1)[order]
    w = jnp.where(keep, g_flat, 0.0).astype(jnp.float32)
    contrib = gathered.astype(jnp.float32) * w[:, None]
    out = jnp.zeros((T,) + yb.shape[2:], jnp.float32)
    return out.at[tok].add(contrib)


def _moe_local(weights, x2: jax.Array, *, cfg: ModelConfig, C: int,
               ep_axis: str, ep: int, n_token_shards: int):
    """Per-shard MoE body (inside shard_map). x2: [T_loc, d]."""
    router_w, w_gate, w_up, w_down = weights
    T = x2.shape[0]
    E = cfg.n_experts
    e_loc = E // ep
    gates, idx, aux, z = _router(router_w, x2, cfg)
    buf, meta, dropped = _local_dispatch(x2, idx, E, C)
    load = jnp.bincount(idx.reshape(-1), length=E).astype(jnp.float32)

    d = x2.shape[-1]
    bufr = buf.reshape(ep, e_loc, C, d)
    with jax.named_scope("moe_a2a_fwd"):
        recv = jax.lax.all_to_all(bufr, ep_axis, split_axis=0, concat_axis=0)
    xb = recv.transpose(1, 0, 2, 3).reshape(e_loc, ep * C, d)
    yb = _expert_ffn(w_gate, w_up, w_down, xb)
    ybr = yb.reshape(e_loc, ep, C, d).transpose(1, 0, 2, 3)
    with jax.named_scope("moe_a2a_bwd"):
        back = jax.lax.all_to_all(ybr, ep_axis, split_axis=0, concat_axis=0)
    yb_local = back.reshape(E, C, d)
    y = _local_combine(yb_local, meta, gates, T)

    # global fold metrics (replicated out_specs): sum/mean over all shards
    axes = tuple(ax for ax in ("pod", "data", "model"))
    load = _psum_over(load, axes)
    dropped = _psum_over(dropped.astype(jnp.float32), axes)
    aux = _psum_over(aux, axes) / n_token_shards
    z = _psum_over(z, axes) / n_token_shards
    return y.astype(x2.dtype), (load, dropped, aux, z)


def _psum_over(v, axes):
    for ax in axes:
        try:
            v = jax.lax.psum(v, ax)
        except NameError:
            pass
    return v


def _moe_dense(mp: Params, x2: jax.Array, cfg: ModelConfig, C: int):
    """GShard one-hot dispatch/combine (reference; O(T·E·C) masks)."""
    T, d = x2.shape
    E, K = cfg.n_experts, cfg.top_k
    gates, idx, aux, z = _router(mp["router"], x2, cfg)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # [T,K,E]
    flat = onehot.reshape(T * K, E)
    ranks = (jnp.cumsum(flat, axis=0) - flat).reshape(T, K, E)
    in_cap = (ranks < C).astype(jnp.float32) * onehot
    dropped = jnp.sum(onehot) - jnp.sum(in_cap)
    pos_oh = jax.nn.one_hot(
        jnp.sum(ranks * onehot, axis=-1).astype(jnp.int32), C,
        dtype=jnp.float32)                                      # [T,K,C]
    disp = jnp.einsum("tke,tkc->tec", in_cap, pos_oh)           # [T,E,C]
    comb = jnp.einsum("tk,tke,tkc->tec", gates, in_cap, pos_oh)
    xb = jnp.einsum("tec,td->ecd", disp, x2.astype(jnp.float32)
                    ).astype(x2.dtype)
    yb = _expert_ffn(mp["w_gate"], mp["w_up"], mp["w_down"], xb)
    y = jnp.einsum("tec,ecd->td", comb, yb.astype(jnp.float32))
    load = jnp.sum(onehot, axis=(0, 1))
    return y, (load, dropped, aux, z)


def moe(p: Params, x: jax.Array, rt: Runtime, table: jax.Array,
        mode: str = "auto") -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, updated fold table, aux loss)."""
    cfg = rt.cfg
    mp = p["moe"]
    B, S, d = x.shape
    T = B * S
    mesh = get_runtime_mesh()
    ep = axis_size("expert")
    use_a2a = (mode == "a2a") or (mode == "auto" and mesh is not None
                                  and ep > 1 and cfg.n_experts % ep == 0
                                  and T % (axis_size("batch") * ep) == 0)
    with jax.named_scope("moe"):
        x2 = x.reshape(T, d)
        if use_a2a:
            dp = axis_size("batch")
            n_shards = dp * ep
            t_loc = T // n_shards
            C = max(8, int(t_loc * cfg.top_k / cfg.n_experts
                           * cfg.capacity_factor))
            token_axes = tuple(a for a in ("pod", "data", "model")
                               if a in mesh.axis_names)
            fn = functools.partial(_moe_local, cfg=cfg, C=C, ep_axis="model",
                                   ep=ep, n_token_shards=n_shards)
            fn = shard_map(
                fn, mesh=mesh,
                in_specs=((P(), P("model"), P("model"), P("model")),
                          P(token_axes, None)),
                out_specs=(P(token_axes, None), (P(), P(), P(), P())),
                check_vma=False)
            y2, (load, dropped, aux, z) = fn(
                (mp["router"], mp["w_gate"], mp["w_up"], mp["w_down"]), x2)
        else:
            C = max(4, int(T * cfg.top_k / cfg.n_experts
                           * cfg.capacity_factor))
            y2, (load, dropped, aux, z) = _moe_dense(mp, x2, cfg, C)

        annotate_cost(MOE_CALLER, "moe", "expert_ffn",
                      flops=6.0 * T * cfg.top_k * d * cfg.moe_d_ff)

        y2 = y2.astype(x2.dtype)
        if cfg.n_shared_experts:
            with jax.named_scope("moe_shared"):
                sp = mp["shared"]
                g = jax.nn.silu(linear(sp["w_gate"], x2).astype(jnp.float32))
                u = linear(sp["w_up"], x2).astype(jnp.float32)
                y2 = y2 + linear(sp["w_down"], (g * u).astype(x2.dtype))
                annotate_cost(MOE_CALLER, "moe", "shared_ffn",
                              flops=6.0 * T * d * cfg.moe_d_ff
                              * cfg.n_shared_experts)

        # fold the data-dependent signals (stop_gradient: observability must
        # not perturb training)
        if rt.fold_spec is not None:
            sg = jax.lax.stop_gradient
            emit = rt.fold_spec.emit
            table = emit(table, MOE_CALLER, "moe", "dispatch", "expert_load",
                         sg(load))
            table = emit(table, MOE_CALLER, "moe", "dispatch",
                         "dropped_tokens", sg(dropped.astype(jnp.float32)))
            table = emit(table, MOE_CALLER, "moe", "router", "aux_loss",
                         sg(aux))
            table = emit(table, MOE_CALLER, "moe", "router", "z_loss", sg(z))
            table = emit(table, MOE_CALLER, "moe", "dispatch", "count", 1.0)
        y = y2.reshape(B, S, d)
        aux_total = (cfg.router_aux_weight * aux + 1e-4 * z).astype(jnp.float32)
        return shard(y, "batch", "seq", None), table, aux_total
