"""Continuous-batching serving subsystem tests.

The load-bearing invariant: mixed-length prompts admitted at STAGGERED
ticks into the pooled engine must produce TOKEN-IDENTICAL outputs to
per-request sequential decode — which only holds if every slot advances
at its own position (per-slot `pos: [B]`: rope angles, row-range cache
scatters and offset-causal masks all per-row).  Prefill and decode are
ONE positioned-chunk operation (`forward_chunk`) at different widths, so
the equivalence is checked at chunk widths {1, 3, bucket, whole-prompt}
— including bucket-padded chunks whose pad is masked in-model — for
every model family the engine serves (dense, moe/mla, hybrid, ssm; vlm
and audio prompts need patches/frames at submit, which the token-prompt
client API doesn't carry; their chunk equivalence lives in
test_models.py).  Cross-slot BATCHED prefill (TestBatchedPrefill) adds
the second equivalence axis: same-tick chunks of different slots
running as one multi-row forward_chunk must be token-identical to the
per-slot path (prefill_batch=1) and to sequential decode, and must
never bend strict FCFS.  Plus the scheduler (admission + continuation
budget), the bounded compiled-program guarantee (now (batch bucket,
width) pairs), the pooled sampler
(determinism under batching), the client API (background thread,
streaming callbacks, futures), EOS-on-first-token, truncation
accounting, and the serve latency phases folded into profile shards.
"""

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serving import (SamplingParams, Scheduler, ServingEngine,
                           sample_tokens)

SERVING_ARCHS = ["tinyllama_1_1b", "deepseek_v2_lite_16b", "zamba2_2_7b",
                 "xlstm_1_3b"]


def tiny(arch):
    """Extra-reduced smoke config: 2 layers, small vocab, drop-free MoE."""
    return dataclasses.replace(get_smoke(arch), n_layers=2, vocab=256,
                               capacity_factor=8.0)


def build(arch, seed=0):
    cfg = tiny(arch)
    model = build_model(cfg, impl="ref")
    return cfg, model, model.init(jax.random.key(seed))


def sequential_decode(model, params, prompt, max_new, max_seq_len=64,
                      eos=-1):
    """Reference: full single-request prefill + one-at-a-time decode,
    greedy, with the engine's EOS/max_new semantics."""
    cache = model.init_cache(1, max_seq_len)
    table = model.table()
    lg, cache, table = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, table, cache)
    toks = [int(jnp.argmax(lg[0]))]
    pos = len(prompt)
    while len(toks) < max_new and toks[-1] != eos:
        lg, cache, table = model.decode_step(
            params, jnp.asarray([toks[-1]], jnp.int32), table, cache,
            jnp.asarray([pos], jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def staggered_run(engine, prompts, max_new, sampling=None):
    """Submit mixed-length prompts at staggered ticks; drain; return reqs."""
    reqs = [engine.submit(prompts[0], max_new[0], sampling=sampling)]
    engine.step()
    engine.step()
    reqs.append(engine.submit(prompts[1], max_new[1], sampling=sampling))
    reqs.append(engine.submit(prompts[2], max_new[2], sampling=sampling))
    engine.step()
    reqs.append(engine.submit(prompts[3], max_new[3], sampling=sampling))
    engine.run_until_drained()
    return reqs


def mixed_prompts(cfg, seed=1, lengths=(3, 7, 5, 9)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lengths]


def chunked_prefill_decode(model, params, prompt, max_new, width,
                           max_seq_len=64, pad_to=None):
    """Reference driver for forward_chunk: feed the prompt in `width`-token
    chunks at the running cache offset (optionally bucket-padding each
    chunk to `pad_to` with the pad masked via `valid`), then greedy-decode
    through width-1 chunks."""
    cache = model.init_cache(1, max_seq_len)
    table = model.table()
    pos = 0
    for start in range(0, len(prompt), width):
        seg = prompt[start:start + width]
        n = len(seg)
        w = max(pad_to or n, n)
        padded = np.zeros((w,), np.int32)
        padded[:n] = seg
        lg, cache, table = model.forward_chunk(
            params, jnp.asarray(padded[None]), table, cache,
            jnp.asarray([pos], jnp.int32), jnp.asarray([n], jnp.int32))
        pos += n
    toks = [int(jnp.argmax(lg[0]))]
    while len(toks) < max_new:
        lg, cache, table = model.decode_step(
            params, jnp.asarray([toks[-1]], jnp.int32), table, cache,
            jnp.asarray([pos], jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


class TestContinuousBatchingEquivalence:
    # chunk=64: every prompt fits one admission chunk (all families);
    # chunk=3: prompts prefill through bucket-padded 3-token continuation
    # chunks at mixed slot depths — engine-level, covered for one
    # KV-cache family and the hybrid (SSM state + shared attention KV);
    # the other families' chunk math is pinned by the model-level width-
    # equivalence test below (keeps tier-1 wall time in check)
    @pytest.mark.parametrize("arch,chunk", [
        *[(a, 64) for a in SERVING_ARCHS],
        ("tinyllama_1_1b", 3), ("zamba2_2_7b", 3),
    ])
    def test_staggered_matches_sequential(self, arch, chunk):
        """Pooled per-slot-position serving == per-request sequential
        decode, token for token, with requests arriving mid-flight."""
        cfg, model, params = build(arch)
        prompts = mixed_prompts(cfg)
        max_new = [6, 5, 6, 4]
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=3, max_seq_len=64, eos_token=-1, prefill_chunk=chunk,
            min_chunk_bucket=4))
        reqs = staggered_run(engine, prompts, max_new)
        for r, p, n in zip(reqs, prompts, max_new):
            assert r.done
            assert r.output == sequential_decode(model, params, p, n), \
                f"{arch}: batched != sequential for prompt len {len(p)}"

    @pytest.mark.parametrize("arch", SERVING_ARCHS)
    def test_forward_chunk_width_equivalence(self, arch):
        """forward_chunk is width-invariant: feeding a prompt at widths
        {1, 3, bucket-padded 4, whole-prompt} produces token-identical
        greedy continuations to the sequential prefill+decode path.  The
        width-3-padded-to-4 case exercises the in-model pad mask (valid)
        every bucketed engine chunk relies on."""
        cfg, model, params = build(arch)
        prompt = mixed_prompts(cfg, seed=5, lengths=(9,))[0]
        ref = sequential_decode(model, params, prompt, 5)
        for width, pad_to in ((1, None), (3, None), (3, 4), (len(prompt),
                                                            None)):
            got = chunked_prefill_decode(model, params, prompt, 5, width,
                                         pad_to=pad_to)
            assert got == ref, (f"{arch}: width {width} (pad {pad_to}) "
                                f"!= sequential: {got} vs {ref}")

    @pytest.mark.parametrize("arch", ["tinyllama_1_1b", "xlstm_1_3b"])
    def test_chunked_prefill_matches_single_slot(self, arch):
        """In-model chunked prefill (2-token continuation chunks) is
        batch-composition independent: a crowded pool reproduces the
        single-slot engine exactly, chunk boundaries and all."""
        cfg, model, params = build(arch)
        prompts = mixed_prompts(cfg, seed=2, lengths=(5, 9, 4, 7))
        max_new = [5, 4, 6, 5]
        mk = lambda batch: ServingEngine(model, params, ServeConfig(
            max_batch=batch, max_seq_len=64, eos_token=-1, prefill_chunk=2))
        crowded = staggered_run(mk(3), prompts, max_new)
        for r, p, n in zip(crowded, prompts, max_new):
            solo = mk(1)
            ref = solo.submit(p, n)
            solo.run_until_drained()
            assert r.output == ref.output, f"{arch}: chunked prefill " \
                f"depends on batch composition (prompt len {len(p)})"

    def test_tail_chunk_one_reproduces_token_feed(self):
        """tail_chunk=1 (the legacy one-token-per-tick comparison mode)
        still produces sequential-identical tokens through the unified
        chunk path."""
        cfg, model, params = build("tinyllama_1_1b")
        prompts = mixed_prompts(cfg, seed=7, lengths=(11, 6, 9, 8))
        max_new = [4, 5, 4, 5]
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=3, max_seq_len=64, eos_token=-1, prefill_chunk=4,
            tail_chunk=1, min_chunk_bucket=1))
        reqs = staggered_run(engine, prompts, max_new)
        for r, p, n in zip(reqs, prompts, max_new):
            assert r.output == sequential_decode(model, params, p, n)

    def test_sampled_decode_is_batch_independent(self):
        """Sampling keys derive from (seed, position): a request's sampled
        continuation is identical batched or solo."""
        cfg, model, params = build("tinyllama_1_1b")
        prompts = mixed_prompts(cfg, seed=3)
        max_new = [6, 6, 6, 6]
        sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.9, seed=7)
        mk = lambda batch: ServingEngine(model, params, ServeConfig(
            max_batch=batch, max_seq_len=64, eos_token=-1, prefill_chunk=64))
        batched = staggered_run(mk(3), prompts, max_new, sampling=sp)
        for r, p, n in zip(batched, prompts, max_new):
            solo = mk(1)
            ref = solo.submit(p, n, sampling=sp)
            solo.run_until_drained()
            assert r.output == ref.output
            assert len(r.output) == n


class TestBatchedPrefill:
    """Cross-slot batched prefill: each tick's selected chunks group by
    compiled width and run as ONE multi-row forward_chunk (gathered
    stashes, per-row pos/valid, bucket-padded batch dim).  The invariant:
    batching changes HOW chunks execute, never WHAT tokens come out —
    batched runs must be token-identical to the per-slot path
    (prefill_batch=1) and to sequential per-request decode."""

    def mk(self, model, params, batch, **kw):
        base = dict(max_batch=4, max_seq_len=64, eos_token=-1,
                    prefill_chunk=8, min_chunk_bucket=4)
        base.update(kw)
        return ServingEngine(model, params,
                             ServeConfig(prefill_batch=batch, **base))

    @pytest.mark.parametrize("arch", SERVING_ARCHS)
    def test_concurrent_admissions_match_per_slot_and_sequential(self, arch):
        """Four same-tick admissions of mixed widths (two multi-chunk
        prompts): the batched engine groups them (one group bucket-padded
        B=3->4, plus continuation groups on later ticks) and must emit
        exactly the tokens the per-slot engine and sequential decode
        emit."""
        cfg, model, params = build(arch)
        prompts = mixed_prompts(cfg, seed=11, lengths=(3, 17, 5, 20))
        max_new = [5, 4, 5, 4]
        outs = {}
        for batch in (4, 1):
            engine = self.mk(model, params, batch)
            reqs = [engine.submit(p, n) for p, n in zip(prompts, max_new)]
            engine.run_until_drained()
            assert all(r.done for r in reqs)
            outs[batch] = [r.output for r in reqs]
            buckets = {b for b, _ in engine.chunk_programs}
            if batch > 1:   # batching actually engaged (multi-row groups)
                assert max(buckets) > 1, engine.chunk_programs
            else:           # prefill_batch=1 IS the per-slot path
                assert buckets == {1}, engine.chunk_programs
        assert outs[4] == outs[1], f"{arch}: batched != per-slot prefill"
        for out, p, n in zip(outs[4], prompts, max_new):
            assert out == sequential_decode(model, params, p, n), \
                f"{arch}: batched prefill != sequential (len {len(p)})"

    def test_staggered_mixed_width_ticks_match_per_slot(self):
        """Staggered arrivals where a tick mixes continuation chunks of
        older slots with fresh admissions at a DIFFERENT width: groups
        form per width, and outputs still match the per-slot path."""
        cfg, model, params = build("tinyllama_1_1b")
        prompts = mixed_prompts(cfg, seed=12, lengths=(19, 4, 18, 6))
        max_new = [4, 5, 4, 5]
        runs = {b: staggered_run(self.mk(model, params, b, tail_chunk=4),
                                 prompts, max_new) for b in (4, 1)}
        for rb, r1, p, n in zip(runs[4], runs[1], prompts, max_new):
            assert rb.output == r1.output
            assert rb.output == sequential_decode(model, params, p, n)

    def test_width_one_chunks_batch_across_slots(self):
        """Degenerate width-1 chunks (prefill_chunk=1, unit bucket) still
        batch across slots and stay sequential-identical — the finest
        grain the compiled-width lattice reaches."""
        cfg, model, params = build("tinyllama_1_1b")
        prompts = mixed_prompts(cfg, seed=13, lengths=(3, 5, 4))
        engine = self.mk(model, params, 4, prefill_chunk=1,
                         min_chunk_bucket=1)
        reqs = [engine.submit(p, 4) for p in prompts]
        engine.run_until_drained()
        assert any(b > 1 for b, _ in engine.chunk_programs), \
            engine.chunk_programs
        for r, p in zip(reqs, prompts):
            assert r.output == sequential_decode(model, params, p, 4)

    def test_bounded_chunk_programs(self):
        """The recompile hazard, now 2-D: many distinct prompt lengths
        under many admission patterns must stay on the O(log
        prefill_batch x log max_seq_len) lattice of (batch bucket, width)
        pairs — never one program per (group size, length)."""
        cfg, model, params = build("tinyllama_1_1b")
        rng = np.random.default_rng(14)
        engine = self.mk(model, params, 4, prefill_chunk=16,
                         min_chunk_bucket=8)
        lengths = list(range(3, 27, 2))          # 12 distinct prompt lengths
        for n in lengths:
            engine.submit(rng.integers(0, cfg.vocab, n).astype(np.int32), 2)
        done = engine.run_until_drained()
        assert len(done) == len(lengths)
        assert engine.batch_buckets() == [1, 2, 4]
        lattice = {(b, w) for b in (1, 2, 4) for w in (8, 16)}
        assert engine.chunk_programs <= lattice, engine.chunk_programs
        assert engine.chunk_widths <= {8, 16}

    def test_occupancy_gauge_folds_into_profile(self, tmp_path):
        """Every batched call folds prefill_batch_occupancy (percent of
        compiled rows that were real slots) — the flow-graph evidence
        that batching engages; mean must land in (0, 100]."""
        cfg, model, params = build("tinyllama_1_1b")
        run_dir = str(tmp_path / "serve-run")
        engine = self.mk(model, params, 4, profile_dir=run_dir)
        for p in mixed_prompts(cfg, seed=15, lengths=(6, 6, 7)):
            engine.submit(p, 2)
        engine.run_until_drained()
        from repro.profile import ProfileStore
        folded = ProfileStore(run_dir).reduce().to_folded()
        occ = [e for k, e in folded.edges.items()
               if k[2] == "prefill_batch_occupancy"]
        assert occ and occ[0].count >= 1
        mean = occ[0].total_ns / occ[0].count
        assert 0 < mean <= 100, mean

    def test_older_continuation_blocks_younger_admission_batch(self):
        """Strict-FCFS regression under batched plans: while an older
        slot still owes continuation chunks and the per-tick budget is
        exhausted, a FULL batch of younger admissions must keep waiting
        — grouping happens after selection, so batching must never let
        younger admissions ride along in the older slot's group."""
        cfg, model, params = build("tinyllama_1_1b")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=4, max_seq_len=64, eos_token=-1, prefill_chunk=4,
            prefill_budget_tokens=4, min_chunk_bucket=4, prefill_batch=4))
        old = engine.submit(mixed_prompts(cfg, seed=9, lengths=(20,))[0], 2)
        engine.step()              # admits old, prefills its first chunk
        assert old.admitted_at is not None
        youngers = [engine.submit(p, 2)
                    for p in mixed_prompts(cfg, seed=10, lengths=(4, 4, 4))]
        while engine.scheduler.slots[0].pending:
            assert all(r.admitted_at is None for r in youngers), \
                "younger admissions rode along with an older continuation"
            engine.step()
        engine.run_until_drained()
        assert old.done and all(r.done for r in youngers)


class TestEngineSemantics:
    def test_first_token_eos_finishes_immediately(self):
        """A request whose FIRST sampled token is EOS must finish at admit
        time, not decode max_new_tokens - 1 further ticks."""
        cfg, model, params = build("tinyllama_1_1b")
        prompt = mixed_prompts(cfg)[0]
        first = sequential_decode(model, params, prompt, 1)[0]
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=first, prefill_chunk=64))
        req = engine.submit(prompt, max_new_tokens=16)
        ticks_before = engine._ticks
        engine.run_until_drained()
        assert req.done and req.output == [first]
        # the pool never decoded for it: one tick observes the empty pool
        assert engine._ticks - ticks_before <= 1

    def test_bounded_compiled_chunk_widths(self):
        """The per-admission recompile hazard: distinct prompt lengths
        must NOT each compile their own prefill program.  With
        power-of-two bucketing (pad masked in-model via `valid`), 12
        distinct lengths share O(log max_seq_len) compiled widths; with
        bucketing off, every distinct length is its own program."""
        cfg, model, params = build("tinyllama_1_1b")
        rng = np.random.default_rng(4)
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=-1, prefill_chunk=32,
            min_chunk_bucket=8))
        lengths = list(range(3, 27, 2))          # 12 distinct prompt lengths
        for n in lengths:
            engine.submit(rng.integers(0, cfg.vocab, n).astype(np.int32), 2)
        done = engine.run_until_drained()
        assert len(done) == len(lengths)
        assert engine.chunk_widths <= {8, 16, 32}, engine.chunk_widths
        assert set(engine.chunk_buckets()) == {8, 16, 32}
        raw = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=-1, prefill_chunk=32,
            bucket_chunks=False))
        for n in lengths[:4]:
            raw.submit(rng.integers(0, cfg.vocab, n).astype(np.int32), 2)
        raw.run_until_drained()
        assert len(raw.chunk_widths) == 4

    def test_widths_stay_pow2_on_non_pow2_rows(self):
        """End-of-row chunks must bucket DOWN (consuming fewer tokens),
        never compile an exact remainder width: a non-power-of-two
        max_seq_len row with near-full prompts stays on power-of-two
        compiled widths."""
        cfg, model, params = build("tinyllama_1_1b")
        rng = np.random.default_rng(6)
        # prefill_chunk 35 on a 50-row: the admission bucket (64) always
        # overshoots the row and must bucket down to 32
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=50, eos_token=-1, prefill_chunk=35,
            min_chunk_bucket=4))
        for n in (47, 45, 43):                   # near-full, distinct tails
            req = engine.submit(
                rng.integers(0, cfg.vocab, n).astype(np.int32), 2)
        engine.run_until_drained()
        assert req.done
        assert all(w & (w - 1) == 0 for w in engine.chunk_widths), \
            engine.chunk_widths
        assert len(engine.chunk_widths) <= 4, engine.chunk_widths

    def test_malformed_prompt_rejected_per_request(self):
        """An empty or non-1-D prompt must raise at submit() — failing
        later inside _admit would kill the engine loop and fail every
        other live client."""
        cfg, model, params = build("tinyllama_1_1b")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=-1))
        with pytest.raises(ValueError, match="non-empty 1-D"):
            engine.submit(np.array([], np.int32), 3)
        with pytest.raises(ValueError, match="non-empty 1-D"):
            engine.submit(np.zeros((2, 3), np.int32), 3)
        # the engine is still healthy for well-formed requests
        req = engine.submit(mixed_prompts(cfg)[0], max_new_tokens=2)
        engine.run_until_drained()
        assert req.done and len(req.output) == 2

    def test_truncated_prompt_flagged_and_counted(self):
        cfg, model, params = build("tinyllama_1_1b")
        from repro.core.tracer import TRACER
        from repro.profile import tracer_folded
        before = sum(
            e.count for k, e in tracer_folded().edges.items()
            if k[2] == "truncated_prompt")
        rng = np.random.default_rng(0)
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=1, max_seq_len=32, eos_token=-1))
        req = engine.submit(rng.integers(0, cfg.vocab, 40), max_new_tokens=4)
        engine.run_until_drained()
        assert req.done and req.truncated
        # prompt was cut to fit the cache row alongside max_new_tokens
        assert len(req.output) == 4
        after = sum(
            e.count for k, e in tracer_folded().edges.items()
            if k[2] == "truncated_prompt")
        assert after == before + 1

    def test_oversized_max_new_clamped_to_cache_row(self):
        """max_new_tokens >= max_seq_len must not let a slot's pos run off
        the end of its cache row (writes would silently clamp and corrupt
        the newest position); the engine caps the generation budget."""
        cfg, model, params = build("tinyllama_1_1b")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=1, max_seq_len=16, eos_token=-1))
        prompt = mixed_prompts(cfg)[0][:5]
        req = engine.submit(prompt, max_new_tokens=64)
        engine.run_until_drained()
        assert req.done and req.truncated
        # prompt clamped to 1 token (limit = max(1, 16 - 64 - 1)), then
        # generation capped to the row's remaining capacity
        assert len(req.output) == 15
        slot_positions = [s.pos for s in engine.scheduler.slots]
        assert max(slot_positions) <= 16

    def test_background_thread_streams_and_futures(self):
        cfg, model, params = build("tinyllama_1_1b")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=-1)).start()
        try:
            streamed = []
            lock = threading.Lock()

            def on_token(req, tok):
                with lock:
                    streamed.append(tok)

            prompts = mixed_prompts(cfg)
            h1 = engine.submit(prompts[0], 5, on_token=on_token)
            h2 = engine.submit(prompts[1], 4)
            assert h1.result(timeout=60).done
            assert h2.result(timeout=60).done
            assert streamed == h1.output
            assert h1.output == sequential_decode(model, params,
                                                  prompts[0], 5)
        finally:
            engine.stop()
        # a second start() resumes service on the same pool
        engine.start()
        try:
            h3 = engine.submit(mixed_prompts(cfg)[2], 3)
            assert h3.result(timeout=60).done and len(h3.output) == 3
        finally:
            engine.stop()

    @staticmethod
    def _break_decode(engine):
        """Inject a mid-loop failure (malformed prompts no longer reach
        the loop — submit rejects them — so the decode step is the
        injection point for loop-failure semantics)."""
        def boom(*a, **k):
            raise RuntimeError("injected decode failure")
        engine._decode = boom

    def test_engine_failure_does_not_strand_clients(self):
        """An error inside the serve loop must surface on result(), not
        silently kill the daemon thread while clients block forever."""
        cfg, model, params = build("tinyllama_1_1b")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=-1)).start()
        self._break_decode(engine)
        try:
            bad = engine.submit(mixed_prompts(cfg)[0], 4)
            with pytest.raises(RuntimeError):
                bad.result(timeout=60)
            assert bad.error is not None
            # the dead engine rejects instead of enqueueing into a void
            with pytest.raises(RuntimeError):
                engine.submit(np.zeros((3,), np.int32), 2)
        finally:
            engine.stop()

    def test_sync_mode_failure_wakes_waiters_too(self):
        """The closed-loop driver shares the background loop's guarantee:
        an engine error marks every live request failed before raising."""
        cfg, model, params = build("tinyllama_1_1b")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=-1))
        self._break_decode(engine)
        bad = engine.submit(mixed_prompts(cfg)[0], 4)
        with pytest.raises(Exception):
            engine.step()
        assert bad.error is not None and bad._done_event.is_set()
        with pytest.raises(RuntimeError):
            engine.submit(np.zeros((3,), np.int32), 2)

    def test_zero_max_new_tokens_rejected(self):
        cfg, model, params = build("tinyllama_1_1b")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=1, max_seq_len=64))
        with pytest.raises(ValueError):
            engine.submit(np.zeros((3,), np.int32), max_new_tokens=0)

    def test_serve_phases_fold_into_profile_shard(self, tmp_path):
        cfg, model, params = build("tinyllama_1_1b")
        run_dir = str(tmp_path / "serve-run")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=-1,
            profile_dir=run_dir))
        for p in mixed_prompts(cfg)[:3]:
            engine.submit(p, 4)
        done = engine.run_until_drained()
        assert len(done) == 3
        for r in done:
            assert r.queue_wait_s is not None and r.queue_wait_s >= 0
            assert r.ttft_s is not None and r.ttft_s > 0
            assert r.e2e_s is not None and r.e2e_s >= r.ttft_s
        from repro.profile import ProfileStore, RunRegistry
        folded = ProfileStore(run_dir).reduce().to_folded()
        apis = {k[2] for k in folded.edges}
        for phase in ("queue_wait", "ttft", "decode_token", "e2e",
                      "prefill_request", "prefill_chunk", "decode_tick"):
            assert phase in apis, f"missing serve phase {phase}"
        per_req = {k[2]: e for k, e in folded.edges.items()
                   if k[1] == "serve"}
        assert per_req["ttft"].count >= 3
        assert per_req["e2e"].count >= 3
        assert per_req["decode_token"].count \
            >= sum(len(r.output) for r in done) - 3  # first tokens at admit
        # the run is discoverable the way fleets query serving replicas
        runs = RunRegistry(str(tmp_path)).query(kind="serve")
        assert len(runs) == 1 and runs[0].config == cfg.name


class TestWorkload:
    def test_run_workload_closed_and_stats(self):
        from repro.serving import latency_stats, run_workload
        cfg, model, params = build("tinyllama_1_1b")
        engine = ServingEngine(model, params, ServeConfig(
            max_batch=2, max_seq_len=64, eos_token=-1))
        import time
        t0 = time.monotonic()
        done = run_workload(engine, mixed_prompts(cfg)[:3], 4, mode="closed")
        s = latency_stats(done, time.monotonic() - t0)
        assert s["requests"] == 3 and s["tokens"] == 12
        assert s["throughput_tok_s"] > 0
        assert 0 <= s["queue_wait_mean_s"] <= s["ttft_mean_s"]
        assert s["decode_s_per_tok"] > 0 and s["truncated"] == 0
        with pytest.raises(ValueError):
            run_workload(engine, [], 4, mode="bogus")


class TestScheduler:
    def mk(self, **kw):
        scfg = ServeConfig(max_batch=4, max_seq_len=64, **kw)
        return Scheduler(scfg)

    class Req:
        def __init__(self, n):
            self.prompt = np.zeros((n,), np.int32)

    def test_budget_caps_admissions_per_tick(self):
        sched = self.mk(prefill_chunk=8, prefill_budget_tokens=8)
        for n in (6, 6, 6):
            sched.add(self.Req(n))
        first = sched.schedule()
        assert len(first) == 1           # 6 + 6 would blow the 8-token budget
        sched.bind(first[0][0], first[0][1], pos=6, pending=())
        assert len(sched.schedule()) == 1

    def test_budget_charges_truncated_length(self):
        """A prompt that will be truncated to fit its cache row must be
        billed for the tokens actually prefilled, not its raw length."""
        sched = self.mk(prefill_chunk=512, prefill_budget_tokens=60)
        class Req:
            def __init__(self, n, max_new):
                self.prompt = np.zeros((n,), np.int32)
                self.max_new_tokens = max_new
        # raw len 10_000, truncated to 64 - 16 - 1 = 47 tokens
        assert sched.admit_cost(Req(10_000, 16)) == 47
        sched.add(Req(10_000, 16))
        sched.add(Req(8, 4))
        picked = sched.schedule()
        assert len(picked) == 2          # 47 + 8 fits the 60-token budget

    def test_head_of_line_long_prompt_never_starves(self):
        sched = self.mk(prefill_chunk=64, prefill_budget_tokens=8)
        sched.add(self.Req(40))          # cost 40 > budget 8
        picked = sched.schedule()
        assert len(picked) == 1          # admitted anyway (first of the tick)

    def test_fcfs_into_free_slots(self):
        sched = self.mk(prefill_chunk=8)
        reqs = [self.Req(4) for _ in range(6)]
        for r in reqs:
            sched.add(r)
        picked = sched.schedule()
        assert [r for _, r in picked] == reqs[:4]   # pool size caps at 4
        assert sched.has_waiting()

    def test_continuation_chunks_share_the_budget(self):
        """Mid-prefill slots advance by tail_chunk-sized chunks under the
        SAME per-tick budget admissions draw from; admissions only see
        the leftover (continuations belong to older requests) and wait
        entirely when an older continuation was deferred."""
        sched = self.mk(prefill_chunk=8, prefill_budget_tokens=10)
        sched.bind(0, self.Req(20), pos=8, pending=range(12))
        sched.bind(1, self.Req(13), pos=8, pending=range(5))
        plan, deferred = sched.continuation_plan()
        assert plan == [(0, 8)]       # 8 + 5 would blow the 10-token budget
        assert deferred               # slot 1 got nothing: admissions wait
        sched.add(self.Req(6))
        assert sched.schedule(spent=8) == []        # leftover can't fit 6
        assert len(sched.schedule()) == 1           # fresh tick: admits

    def test_oversized_continuation_is_not_a_barrier(self):
        """A mid-prefill chunk too big for the leftover budget is skipped,
        not a wall: a smaller OLDER-than-waiting chunk behind it still
        runs this tick (and the skip is reported as deferred)."""
        sched = self.mk(prefill_chunk=8, prefill_budget_tokens=10)
        sched.bind(0, self.Req(20), pos=8, pending=range(8))
        sched.bind(1, self.Req(20), pos=8, pending=range(8))
        sched.bind(2, self.Req(13), pos=8, pending=range(2))
        plan, deferred = sched.continuation_plan()
        assert plan == [(0, 8), (2, 2)] and deferred

    def test_continuation_order_is_admission_fcfs(self):
        sched = self.mk(prefill_chunk=4)
        sched.bind(2, self.Req(9), pos=4, pending=range(5))    # older
        sched.bind(0, self.Req(9), pos=4, pending=range(5))    # newer
        plan, deferred = sched.continuation_plan()
        assert [i for i, _ in plan] == [2, 0] and not deferred

    def test_first_continuation_never_starves(self):
        sched = self.mk(prefill_chunk=16, prefill_budget_tokens=4)
        sched.bind(0, self.Req(40), pos=16, pending=range(24))
        plan, deferred = sched.continuation_plan()
        assert plan == [(0, 16)] and not deferred   # first always fits

    def test_tail_chunk_defaults_to_prefill_chunk(self):
        assert self.mk(prefill_chunk=8).tail_chunk == 8
        assert self.mk(prefill_chunk=8, tail_chunk=1).tail_chunk == 1


class TestPooledSampler:
    def test_greedy_and_degenerate_knobs_match_argmax(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
        am = np.asarray(jnp.argmax(logits, -1))
        B = 4
        vec = lambda x, dt=np.float32: jnp.asarray(np.full((B,), x, dt))
        seed = jnp.zeros((B,), jnp.uint32)
        step = jnp.arange(B, dtype=jnp.int32)
        greedy = sample_tokens(logits, vec(0.0), vec(0, np.int32),
                               vec(1.0), seed, step)
        topk1 = sample_tokens(logits, vec(1.3), vec(1, np.int32),
                              vec(1.0), seed, step)
        topp0 = sample_tokens(logits, vec(1.3), vec(0, np.int32),
                              vec(1e-9), seed, step)
        np.testing.assert_array_equal(np.asarray(greedy), am)
        np.testing.assert_array_equal(np.asarray(topk1), am)
        np.testing.assert_array_equal(np.asarray(topp0), am)

    def test_seed_and_step_determine_tokens(self):
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
        B = 3
        vec = lambda x, dt=np.float32: jnp.asarray(np.full((B,), x, dt))
        args = (logits, vec(0.9), vec(8, np.int32), vec(0.95))
        step = jnp.asarray([4, 4, 9], jnp.int32)
        a = sample_tokens(*args, jnp.asarray([1, 1, 1], jnp.uint32), step)
        b = sample_tokens(*args, jnp.asarray([1, 1, 1], jnp.uint32), step)
        c = sample_tokens(*args, jnp.asarray([1, 2, 1], jnp.uint32), step)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # row 0 and 1 share logits distribution shapes but differ by seed
        assert not np.array_equal(np.asarray(a), np.asarray(c)) \
            or np.asarray(a)[1] == np.asarray(c)[1]

    def test_top_k_restricts_support(self):
        rng = np.random.default_rng(2)
        logits = jnp.asarray(rng.standard_normal((1, 64)), jnp.float32)
        top4 = set(np.asarray(jnp.argsort(logits[0])[-4:]))
        for s in range(24):
            tok = sample_tokens(logits, jnp.asarray([1.5], jnp.float32),
                                jnp.asarray([4], jnp.int32),
                                jnp.asarray([1.0], jnp.float32),
                                jnp.asarray([s], jnp.uint32),
                                jnp.asarray([0], jnp.int32))
            assert int(tok[0]) in top4
