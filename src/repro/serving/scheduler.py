"""Slot admission + chunked-prefill budgeting for the serving engine.

The scheduler owns the WAITING side of continuous batching: the FCFS
queue of submitted requests, the fixed slot pool's occupancy bookkeeping
(which request holds which cache row, at what depth, with how much
prompt left to feed), and the per-tick prefill plan.

Admission is iteration-level (vLLM-style): any tick with free slots may
admit, bounded by a chunked-prefill token budget so a burst of long
prompts cannot stall slots that are already decoding (Sarathi-style
prefill/decode interference control).  Prefill is IN-MODEL chunked: the
admission chunk and every continuation chunk of a longer prompt's tail
run through the same positioned `forward_chunk` step at the slot's cache
offset, up to `prefill_chunk` (continuations: `tail_chunk`) tokens per
step — one code path from first prompt token to pooled decode.

Fairness: strict FCFS.  Continuation chunks belong to requests admitted
BEFORE anything still waiting, so each tick plans continuations first
(oldest admission first), then admissions with whatever budget remains.
The budget never reorders the queue, and the first prefill step of a
tick always fits, so one huge prompt is delayed (by the budget) but
never starved — and neither is a long tail mid-prefill.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, List, Optional, Tuple

import numpy as np

from repro.configs.base import ServeConfig


@dataclasses.dataclass
class Slot:
    """One row of the batched cache pool."""
    request: Optional[object] = None   # serving.engine.Request (duck-typed)
    pos: int = 0                       # next cache position to write
    pending: Deque[int] = dataclasses.field(default_factory=deque)
    seq: int = 0                       # admission order (continuation FCFS)
    stash: Any = None                  # batch=1 cache pytree while prefilling

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def prefilling(self) -> bool:
        """Still owed prompt chunks (not yet in the pooled decode)."""
        return self.request is not None and bool(self.pending)


class Scheduler:
    """Iteration-level admission + chunk planning over a fixed slot pool."""

    def __init__(self, scfg: ServeConfig) -> None:
        self.scfg = scfg
        self.waiting: Deque = deque()
        self.slots: List[Slot] = [Slot() for _ in range(scfg.max_batch)]
        self._admit_seq = 0

    # -- queue side ---------------------------------------------------------
    def add(self, req) -> None:
        self.waiting.append(req)

    def has_waiting(self) -> bool:
        return bool(self.waiting)

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.active())

    # -- pool side ----------------------------------------------------------
    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    def decoding(self) -> List[int]:
        """Slots past prefill: they join the pooled decode tick."""
        return [i for i, s in enumerate(self.slots)
                if s.request is not None and not s.pending]

    def prefilling_slots(self) -> List[int]:
        """Slots owed continuation chunks, oldest admission first."""
        out = [i for i, s in enumerate(self.slots) if s.prefilling]
        return sorted(out, key=lambda i: self.slots[i].seq)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def admit_cost(self, req) -> int:
        """Prefill tokens the ADMISSION chunk will actually consume —
        after the engine's truncation to fit the cache row (charging the
        raw prompt length would overbill truncated requests and block
        cheap neighbours for no real work)."""
        limit = self.scfg.max_seq_len \
            - getattr(req, "max_new_tokens", 0) - 1
        plen = min(len(req.prompt), max(limit, 1))
        chunk = self.scfg.prefill_chunk or plen
        return max(1, min(plen, chunk))

    @property
    def tail_chunk(self) -> int:
        """Continuation chunk width (tokens per forward_chunk step)."""
        return self.scfg.tail_chunk or self.scfg.prefill_chunk or 1

    def continuation_plan(self) -> Tuple[List[Tuple[int, int]], bool]:
        """((slot_idx, n_tokens) continuation chunks for this tick,
        deferred?): every mid-prefill slot advances by up to `tail_chunk`
        tokens, oldest admission first, under the per-tick prefill token
        budget.  The first chunk of the tick always fits (a long tail can
        be slowed by the budget, never starved); an oversized chunk is
        skipped, not a barrier, so smaller chunks of LATER-admitted
        (but still older-than-any-waiting) slots may consume the
        leftover.  `deferred` reports whether any mid-prefill slot got
        nothing — admissions must then wait a tick (every mid-prefill
        request predates everything in the waiting queue)."""
        budget = self.scfg.prefill_budget_tokens
        out: List[Tuple[int, int]] = []
        spent = 0
        deferred = False
        for idx in self.prefilling_slots():
            n = min(len(self.slots[idx].pending), self.tail_chunk)
            if out and budget and spent + n > budget:
                deferred = True
                continue
            out.append((idx, n))
            spent += n
        return out, deferred

    def schedule(self, spent: int = 0) -> List[Tuple[int, object]]:
        """Admissions for this tick: FCFS into free slots under the
        prefill token budget.  `spent` is what this tick's continuation
        chunks already consumed — waiting requests arrived after every
        mid-prefill request, so they only see the leftover budget.  The
        first prefill step of a tick (spent == 0, nothing admitted yet)
        always fits regardless of cost (no starvation of long prompts)."""
        budget = self.scfg.prefill_budget_tokens
        out: List[Tuple[int, object]] = []
        free = self.free_slots()
        while free and self.waiting:
            cost = self.admit_cost(self.waiting[0])
            if (out or spent) and budget and spent + cost > budget:
                break
            out.append((free.pop(0), self.waiting.popleft()))
            spent += cost
        return out

    def bind(self, idx: int, req, pos: int, pending, stash: Any = None
             ) -> None:
        """Occupy slot `idx`: cache holds `pos` tokens, `pending` is the
        not-yet-prefilled prompt remainder (fed through forward_chunk
        steps), `stash` the batch=1 cache being filled until the prompt
        completes and scatters into the pool."""
        self._admit_seq += 1
        self.slots[idx] = Slot(request=req, pos=pos, pending=deque(pending),
                               seq=self._admit_seq, stash=stash)

    def release(self, idx: int) -> None:
        self.slots[idx] = Slot()

    def pos_vector(self) -> np.ndarray:
        """[max_batch] int32 per-slot cache depths (free slots at 0)."""
        return np.asarray([s.pos for s in self.slots], np.int32)
