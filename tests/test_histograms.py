"""Latency histograms (schema v2): bucket mapping, percentile read-out,
tracer feed, and the merge algebra.

The load-bearing property mirrors the fold-split invariant: histograms
of any split of a sample stream merge (bucket-wise add) to exactly the
histogram of the whole stream, in any order — so shard merges never
move a percentile."""

import numpy as np
import pytest

from repro.core import Tracer
from repro.core.folding import EdgeColumns, EdgeStats, FoldedTable
from repro.core.histogram import (BUCKET_EDGES, HIST_BUCKETS, bucket_index,
                                  hist_of, jitter_ns, percentile_ns)

MS = 1_000_000


class TestBucketMapping:
    def test_every_duration_lands_in_exactly_one_bucket(self):
        for d in (1, 2, 3, 4, 5, 7, 8, 1000, 10**6, 10**9, (1 << 40) - 1):
            b = bucket_index(d)
            assert 0 <= b < HIST_BUCKETS
            assert BUCKET_EDGES[b] <= d < BUCKET_EDGES[b + 1], d

    def test_out_of_range_clamps(self):
        assert bucket_index(0) == 0
        assert bucket_index(-5) == 0
        assert bucket_index(1 << 50) == HIST_BUCKETS - 1

    def test_monotone(self):
        ds = [1, 2, 3, 10, 100, 10**4, 10**7, 10**10, (1 << 40) - 1]
        bs = [bucket_index(d) for d in ds]
        assert bs == sorted(bs)

    def test_relative_width_bound(self):
        # 4 sub-buckets per octave: bucket width <= 25% of its lower edge
        # (from the second octave up; the first octave is exact integers)
        w = np.diff(BUCKET_EDGES)[8:]
        assert (w / BUCKET_EDGES[8:-1] <= 0.25 + 1e-9).all()


class TestPercentiles:
    def test_empty_and_none_read_zero(self):
        assert percentile_ns(None, 0.99) == 0.0
        assert percentile_ns(np.zeros(HIST_BUCKETS, np.uint64), 0.5) == 0.0
        assert jitter_ns(None) == 0.0

    def test_percentiles_within_bucket_resolution(self):
        samples = [10 * MS] * 95 + [80 * MS] * 5
        h = hist_of(samples)
        assert int(h.sum()) == 100
        assert percentile_ns(h, 0.50) == pytest.approx(10 * MS, rel=0.3)
        assert percentile_ns(h, 0.99) == pytest.approx(80 * MS, rel=0.3)
        assert jitter_ns(h) == pytest.approx(70 * MS, rel=0.35)

    def test_percentile_is_monotone_in_q(self):
        h = hist_of([3, 17, 900, 10**6, 10**6, 5 * 10**7])
        ps = [percentile_ns(h, q) for q in (0.01, 0.25, 0.5, 0.9, 0.999)]
        assert ps == sorted(ps)


def tracer_fold(t):
    return FoldedTable.merge_all(FoldedTable.from_set(t.tables))


class TestTracerFeed:
    def test_record_duration_feeds_hist(self):
        t = Tracer()
        for _ in range(4):
            t.record_duration("serve", "e2e", 12 * MS)
        e = tracer_fold(t).edges[("app", "serve", "e2e")]
        assert e.hist is not None and int(e.hist.sum()) == 4
        assert e.p50_ns == pytest.approx(12 * MS, rel=0.3)

    def test_gauges_and_brackets_stay_histless(self):
        t = Tracer()
        t.record_gauge("serve", "queue_depth", 7.0)

        @t.api("glibc")
        def read():
            pass

        read()
        folded = tracer_fold(t)
        assert len(folded)
        for e in folded.edges.values():
            assert e.hist is None


class TestMergeAlgebra:
    def test_stats_merge_adds_buckets(self):
        a = EdgeStats(count=2, total_ns=20, min_ns=10, max_ns=10,
                      hist=hist_of([10, 10]))
        b = EdgeStats(count=1, total_ns=30, min_ns=30, max_ns=30,
                      hist=hist_of([30]))
        m = a.merge(b)
        assert np.array_equal(m.hist, hist_of([10, 10, 30]))
        # hist-less side contributes zero buckets, never erases the other
        m2 = a.merge(EdgeStats(count=1, total_ns=5, min_ns=5, max_ns=5))
        assert np.array_equal(m2.hist, a.hist)

    def test_columns_roundtrip_preserves_hists(self):
        t = FoldedTable({
            ("app", "serve", "e2e"): EdgeStats(
                count=3, total_ns=60, min_ns=10, max_ns=30,
                hist=hist_of([10, 20, 30])),
            ("app", "glibc", "read"): EdgeStats(
                count=1, total_ns=9, min_ns=9, max_ns=9),
        })
        back = EdgeColumns.from_folded(t).to_folded()
        from conftest import assert_tables_equal
        assert_tables_equal(back, t)
