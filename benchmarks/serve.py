"""Serving throughput + TTFT benchmark on a tiny config (CPU-lane safe).

Drives the continuous-batching engine — open-loop Poisson arrivals on
the background serving thread by default (TTFT and queue wait are only
meaningful under an arrival process), or closed-loop with --mode closed
— and emits name,value CSV rows like the other benchmarks:

  serve.requests / serve.tokens / serve.wall_s
  serve.throughput_tok_s
  serve.ttft_mean_ms / serve.ttft_p95_ms
  serve.queue_wait_mean_ms
  serve.decode_ms_per_tok
  serve.prefill_tokens / serve.prefill_s / serve.prefill_tok_s
  serve.decode_tok_s
  serve.compiled_chunk_widths
  serve.e2e_p50_ms / serve.e2e_p95_ms / serve.e2e_p99_ms
  serve.e2e_jitter_ms  (p99 - p50)
  serve.deadline_tracked / serve.deadline_missed / serve.slo_miss_rate

The percentile columns read the schema-v2 log-bucket latency histogram
folded on the `serve.e2e` edge (delta over the timed window, warmup
excluded) — the same counters `report` renders and the slo-violation
detector gates on.  --deadline-ms arms per-request deadline tracking in
the engine; --slo-p99-ms N exits nonzero when the measured e2e p99
exceeds N (the serve-bench CI lane runs both).

The prefill/decode split reads the XFA `serve.prefill_chunk` and
`serve.decode_token` duration folds — the same edges `diagnose` uses to
see prefill/decode interference — so the benchmark numbers and the flow
graph agree by construction.

--long-prompts draws prompts of ~max_seq/2 tokens (many multiples of
--prefill-chunk): the in-model chunked-prefill stress case.  With
--compare-tail-feed the same workload runs AGAIN with tail_chunk=1 — the
legacy one-token-per-tick tail feed reproduced through the unified chunk
path — and emits serve.ttft_mean_ms_tail_feed next to the chunked
number; --assert-ttft-improves exits nonzero unless the chunked path
wins (the serve-bench CI lane runs exactly that).

--concurrent-admissions N is the CROSS-SLOT BATCHED PREFILL scenario: N
simultaneous long prompts submitted up front (closed loop, pool sized to
hold them all), so every tick's prefill chunks batch into multi-row
forward_chunk calls.  It emits serve.compiled_chunk_programs (the
(batch bucket, width) program count) and
serve.prefill_batch_occupancy_pct next to the usual rows.  With
--compare-per-slot-prefill the same workload runs AGAIN at
prefill_batch=1 (per-slot batch=1 prefill through the same code path)
and emits serve.prefill_tok_s_per_slot + serve.prefill_batch_speedup_x;
--assert-batched-prefill-improves RATIO exits nonzero unless batched
prefill throughput is at least RATIO x the per-slot number, and
--assert-max-chunk-programs N bounds the compiled-program count (the
serve-bench CI lane runs all three).

With --profile-dir the run registers in the run registry (kind=serve)
and writes its XFA shard there, so

  python -m repro.profile query DIR --kind serve
  python -m repro.profile report DIR --component serve

work against the benchmark's output — the serve-bench CI lane asserts
exactly that round trip.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ServeConfig
from repro.serving import (SamplingParams, ServingEngine, latency_stats,
                           run_workload)


def tiny_cfg(arch: str):
    """2-layer reduction of the smoke config: benchmark the ENGINE, not
    the model."""
    return dataclasses.replace(get_smoke(arch), n_layers=2, vocab=512)


def _phase_ns(apis) -> dict:
    """Total folded nanoseconds + counts for the given serve-phase APIs."""
    from repro.profile import tracer_folded
    out = {a: [0, 0.0] for a in apis}
    for (_, comp, api), e in tracer_folded().edges.items():
        if comp == "serve" and api in out:
            out[api][0] += e.count
            out[api][1] += e.total_ns
    return out


def _phase_hists(apis) -> dict:
    """Summed latency histograms (schema v2) for the given serve APIs.

    None for an API with no folded histogram yet — percentile columns
    then read 0.0, same convention as the report view."""
    from repro.profile import tracer_folded
    out = {a: None for a in apis}
    for (_, comp, api), e in tracer_folded().edges.items():
        if comp == "serve" and api in out and e.hist is not None:
            out[api] = e.hist.copy() if out[api] is None \
                else out[api] + e.hist
    return out


def _hist_delta(before, after):
    """after - before for cumulative bucket counts (None-aware)."""
    if after is None:
        return None
    if before is None:
        return after
    d = after.astype(np.int64) - before.astype(np.int64)
    return np.maximum(d, 0).astype(np.uint64)


def make_prompts(args, cfg, rng) -> list:
    if args.long_prompts:
        # many multiples of prefill_chunk: the chunked-prefill stress case
        lo, hi = args.max_seq // 2, args.max_seq // 2 + args.max_seq // 8
    else:
        lo, hi = 4, max(5, args.max_seq // 4)
    return [rng.integers(0, cfg.vocab, int(rng.integers(lo, hi)))
            for _ in range(args.requests)]


def run(args, tail_chunk: int = 0, min_bucket: int = 0,
        prefill_batch: int = 0) -> dict:
    from repro.models import build_model
    cfg = tiny_cfg(args.arch)
    model = build_model(cfg, impl="ref")
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, ServeConfig(
        max_batch=args.max_batch, max_seq_len=args.max_seq,
        prefill_chunk=args.prefill_chunk,
        tail_chunk=tail_chunk,
        min_chunk_bucket=min_bucket or 8,
        prefill_batch=prefill_batch or args.prefill_batch,
        prefill_budget_tokens=args.prefill_budget,
        eos_token=-1,
        deadline_ms=args.deadline_ms,
        profile_dir=args.profile_dir,
        profile_interval_ticks=64,
        profile_label="serve-bench",
        profile_meta=(("bench", "serve"),)))
    sampling = SamplingParams(temperature=args.temperature, seed=1)
    rng = np.random.default_rng(0)
    prompts = make_prompts(args, cfg, rng)

    # warmup: compile the pooled decode/sampler AND every chunk bucket
    # the engine can schedule (admission, continuation and remainder
    # chunks all land on one of engine.chunk_buckets()) outside the timed
    # window — warming only the longest prompt's chunk sequence would
    # leave other prompts' remainder buckets to compile inside the timed
    # run and skew the TTFT comparison
    limit = args.max_seq - args.max_new - 2
    for w in engine.chunk_buckets() or [args.prefill_chunk]:
        engine.submit(rng.integers(0, cfg.vocab, min(w, limit)), 2,
                      sampling=sampling)
        engine.run_until_drained()
    engine.completed.clear()
    # ... and every (batch bucket, width) pair batched prefill can
    # schedule: concurrent admissions would otherwise compile the
    # multi-row programs inside the timed window, billing XLA compiles
    # as prefill time in exactly the comparison this benchmark makes
    engine.warm_chunk_programs()

    before = _phase_ns(("prefill_chunk", "decode_token",
                        "prefill_batch_occupancy"))
    hist_before = _phase_hists(("e2e",))
    t0 = time.monotonic()
    done = run_workload(engine, prompts, args.max_new, mode=args.mode,
                        rate=args.rate, rng=rng, sampling=sampling)
    s = latency_stats(done, time.monotonic() - t0)
    after = _phase_ns(("prefill_chunk", "decode_token",
                       "prefill_batch_occupancy"))
    hist_after = _phase_hists(("e2e",))
    if not s["requests"] or "ttft_mean_s" not in s:
        # reachable diagnostic BEFORE any stats key is touched
        raise SystemExit("degenerate serve run: no requests completed")
    prefill_tokens = int(sum(len(r.prompt) for r in done))
    prefill_s = (after["prefill_chunk"][1] - before["prefill_chunk"][1]) / 1e9
    decode_n = after["decode_token"][0] - before["decode_token"][0]
    decode_s = (after["decode_token"][1] - before["decode_token"][1]) / 1e9
    # e2e tail latency from the run's histogram delta (warmup excluded):
    # the same log-bucket counters `report` and the slo-violation
    # detector read, so the CSV and the flow graph agree by construction
    from repro.core.histogram import jitter_ns, percentile_ns
    e2e = _hist_delta(hist_before["e2e"], hist_after["e2e"])
    tracked = [r for r in done if r.deadline_missed is not None]
    missed = sum(1 for r in tracked if r.deadline_missed)
    # mean batched-prefill occupancy over the timed window (the gauge
    # folds value sums through the duration columns)
    occ_n = after["prefill_batch_occupancy"][0] \
        - before["prefill_batch_occupancy"][0]
    occ_sum = after["prefill_batch_occupancy"][1] \
        - before["prefill_batch_occupancy"][1]
    return {
        "serve.requests": int(s["requests"]),
        "serve.tokens": int(s["tokens"]),
        "serve.wall_s": round(s["wall_s"], 4),
        "serve.throughput_tok_s": round(s["throughput_tok_s"], 2),
        "serve.ttft_mean_ms": round(s["ttft_mean_s"] * 1e3, 3),
        "serve.ttft_p95_ms": round(s["ttft_p95_s"] * 1e3, 3),
        "serve.queue_wait_mean_ms": round(s["queue_wait_mean_s"] * 1e3, 3),
        "serve.decode_ms_per_tok": round(s["decode_s_per_tok"] * 1e3, 3),
        "serve.prefill_tokens": prefill_tokens,
        "serve.prefill_s": round(prefill_s, 4),
        "serve.prefill_tok_s": round(prefill_tokens / prefill_s, 2)
        if prefill_s > 0 else 0.0,
        "serve.decode_tok_s": round(decode_n / decode_s, 2)
        if decode_s > 0 else 0.0,
        "serve.compiled_chunk_widths": len(engine.chunk_widths),
        "serve.compiled_chunk_programs": len(engine.chunk_programs),
        "serve.prefill_batch_occupancy_pct": round(occ_sum / occ_n, 1)
        if occ_n else 0.0,
        "serve.e2e_p50_ms": round(percentile_ns(e2e, 0.50) / 1e6, 3),
        "serve.e2e_p95_ms": round(percentile_ns(e2e, 0.95) / 1e6, 3),
        "serve.e2e_p99_ms": round(percentile_ns(e2e, 0.99) / 1e6, 3),
        "serve.e2e_jitter_ms": round(jitter_ns(e2e) / 1e6, 3),
        "serve.deadline_tracked": len(tracked),
        "serve.deadline_missed": missed,
        "serve.slo_miss_rate": round(missed / len(tracked), 4)
        if tracked else 0.0,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--mode", choices=("open", "closed"), default="open")
    ap.add_argument("--rate", type=float, default=16.0,
                    help="open-loop mean arrival rate, requests/s")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--prefill-budget", type=int, default=64)
    ap.add_argument("--prefill-batch", type=int, default=8,
                    help="max slots whose same-width prefill chunks batch "
                         "into one forward_chunk call per tick (1: "
                         "per-slot batch=1 prefill)")
    ap.add_argument("--concurrent-admissions", type=int, default=0,
                    metavar="N",
                    help="cross-slot batched prefill scenario: N "
                         "simultaneous long prompts, closed loop, pool "
                         "sized to hold them all (overrides --requests/"
                         "--mode/--long-prompts and raises --max-batch "
                         "to N)")
    ap.add_argument("--compare-per-slot-prefill", action="store_true",
                    help="re-run the workload with prefill_batch=1 "
                         "(per-slot batch=1 prefill through the same code "
                         "path) and emit serve.prefill_tok_s_per_slot + "
                         "serve.prefill_batch_speedup_x")
    ap.add_argument("--assert-batched-prefill-improves", type=float,
                    default=0.0, metavar="RATIO",
                    help="with --compare-per-slot-prefill: exit nonzero "
                         "unless batched prefill throughput >= RATIO x "
                         "the per-slot number")
    ap.add_argument("--assert-max-chunk-programs", type=int, default=0,
                    metavar="N",
                    help="exit nonzero if the batched run compiled more "
                         "than N (batch bucket, width) prefill programs")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--long-prompts", action="store_true",
                    help="prompts of ~max_seq/2 tokens (many chunks each): "
                         "the chunked-prefill stress scenario")
    ap.add_argument("--compare-tail-feed", action="store_true",
                    help="re-run the workload with tail_chunk=1 (legacy "
                         "one-token-per-tick tail feed) and emit its TTFT "
                         "as serve.ttft_mean_ms_tail_feed")
    ap.add_argument("--assert-ttft-improves", action="store_true",
                    help="with --compare-tail-feed: exit nonzero unless "
                         "chunked TTFT beats the tail-feed TTFT")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request e2e deadline fed to the engine "
                         "(ServeConfig.deadline_ms); emits deadline-miss "
                         "counts + serve.slo_miss_rate, and arms the "
                         "slo-violation detector on the profile shard")
    ap.add_argument("--slo-p99-ms", type=float, default=0.0,
                    help="exit nonzero if the measured e2e p99 (from the "
                         "run's latency histogram) exceeds this bound")
    ap.add_argument("--profile-dir", default="",
                    help="register the run + write its XFA shard here")
    ap.add_argument("-o", "--output", default="",
                    help="also write the CSV rows to this file")
    args = ap.parse_args()
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.assert_ttft_improves and not args.compare_tail_feed:
        ap.error("--assert-ttft-improves requires --compare-tail-feed")
    if args.assert_batched_prefill_improves \
            and not args.compare_per_slot_prefill:
        ap.error("--assert-batched-prefill-improves requires "
                 "--compare-per-slot-prefill")
    if args.concurrent_admissions:
        # all prompts in flight at once: every tick's prefill chunks can
        # batch, and the per-slot rerun serializes the same work
        args.requests = args.concurrent_admissions
        args.mode = "closed"
        args.long_prompts = True
        args.max_batch = max(args.max_batch, args.concurrent_admissions)

    rows = run(args)
    if args.compare_per_slot_prefill:
        # same workload, same code path, groups capped at one row each
        ps_args = argparse.Namespace(**{**vars(args), "profile_dir": ""})
        per_slot = run(ps_args, prefill_batch=1)
        rows["serve.prefill_tok_s_per_slot"] = \
            per_slot["serve.prefill_tok_s"]
        rows["serve.prefill_batch_speedup_x"] = round(
            rows["serve.prefill_tok_s"]
            / max(per_slot["serve.prefill_tok_s"], 1e-9), 2)
    if args.compare_tail_feed:
        # same workload through the SAME unified code path, continuation
        # width forced to 1 token/tick, per-slot batch=1 calls, and no
        # bucket padding — the historical feed reproduced exactly, not
        # billed for pad or granted cross-slot batching it never had
        tail_args = argparse.Namespace(**{**vars(args), "profile_dir": ""})
        feed = run(tail_args, tail_chunk=1, min_bucket=1, prefill_batch=1)
        rows["serve.ttft_mean_ms_tail_feed"] = feed["serve.ttft_mean_ms"]
        rows["serve.ttft_p95_ms_tail_feed"] = feed["serve.ttft_p95_ms"]
    lines = ["name,value"] + [f"{k},{v}" for k, v in rows.items()]
    out = "\n".join(lines)
    print(out)
    if args.output:
        with open(args.output, "w") as f:
            f.write(out + "\n")
    if args.assert_ttft_improves:
        chunked = rows["serve.ttft_mean_ms"]
        legacy_ttft = rows["serve.ttft_mean_ms_tail_feed"]
        if chunked >= legacy_ttft:
            print(f"FAIL: chunked prefill TTFT {chunked}ms did not beat "
                  f"the one-token-per-tick tail feed {legacy_ttft}ms",
                  file=sys.stderr)
            return 1
        print(f"chunked prefill TTFT {chunked}ms beats tail feed "
              f"{legacy_ttft}ms ({legacy_ttft / max(chunked, 1e-9):.1f}x)",
              file=sys.stderr)
    if args.assert_batched_prefill_improves:
        speedup = rows["serve.prefill_batch_speedup_x"]
        target = args.assert_batched_prefill_improves
        if speedup < target:
            print(f"FAIL: batched prefill speedup {speedup}x below the "
                  f"required {target}x "
                  f"({rows['serve.prefill_tok_s']} vs "
                  f"{rows['serve.prefill_tok_s_per_slot']} tok/s)",
                  file=sys.stderr)
            return 1
        print(f"batched prefill {rows['serve.prefill_tok_s']} tok/s = "
              f"{speedup}x per-slot "
              f"{rows['serve.prefill_tok_s_per_slot']} tok/s "
              f"(>= {target}x required)", file=sys.stderr)
    if args.assert_max_chunk_programs:
        progs = rows["serve.compiled_chunk_programs"]
        if progs > args.assert_max_chunk_programs:
            print(f"FAIL: {progs} compiled (batch, width) prefill "
                  f"programs exceed the --assert-max-chunk-programs "
                  f"{args.assert_max_chunk_programs} bound",
                  file=sys.stderr)
            return 1
        print(f"{progs} compiled (batch, width) prefill programs within "
              f"the {args.assert_max_chunk_programs} bound",
              file=sys.stderr)
    if args.slo_p99_ms > 0:
        p99 = rows["serve.e2e_p99_ms"]
        if p99 > args.slo_p99_ms:
            print(f"FAIL: e2e p99 {p99}ms exceeds --slo-p99-ms "
                  f"{args.slo_p99_ms}ms", file=sys.stderr)
            return 1
        print(f"e2e p99 {p99}ms within --slo-p99-ms {args.slo_p99_ms}ms "
              f"bound", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
