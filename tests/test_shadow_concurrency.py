"""Concurrency stress for ShadowTableSet: thread churn ≫ the retire-sweep
threshold while readers snapshot concurrently.

The hazard being guarded: the retire sweep absorbs dead threads' tables
into per-group accumulators *in place*, while `tables()` hands copies out
to reader threads.  Any double-absorb, ident-reuse overwrite, or torn
retired-accumulator read shows up as a conservation failure — the total
count/total_ns over all tables must equal exactly what the worker threads
recorded.
"""

import threading

import numpy as np

from repro.core.shadow import ShadowTableSet


def _total(tables, slot_count):
    """(sum of count, sum of total_ns) across a tables() snapshot."""
    c = t = 0
    for tab in tables:
        n = min(tab.capacity, slot_count)
        c += int(tab.count[:n].sum())
        t += int(tab.total_ns[:n].sum())
    return c, t


class TestRetireSweepConservation:
    N_THREADS = 6 * ShadowTableSet.RETIRE_SWEEP_THRESHOLD  # 192: many sweeps
    EVENTS_PER_THREAD = 40

    def test_churn_with_concurrent_snapshots_conserves_totals(self):
        s = ShadowTableSet()
        # a handful of slots shared by all threads (registry is global)
        slots = [s.registry.resolve("app", "worker", f"api{i}").slot
                 for i in range(4)]
        dur = 7  # fixed per-event duration: expected totals are exact

        def work(idx: int) -> None:
            # half the threads tag an explicit group (named pools), half
            # keep the thread-name default ("unnamed" churn) — both retire
            # paths (per-group accumulator vs pooled 'retired') are hit
            t = s.table(group="pool" if idx % 2 == 0 else None)
            for j in range(self.EVENTS_PER_THREAD):
                t.record(slots[(idx + j) % len(slots)], dur)

        stop = threading.Event()
        snapshot_errors = []
        want_events = self.N_THREADS * self.EVENTS_PER_THREAD

        def reader() -> None:
            # hammer tables() (copy-under-lock) while churn sweeps retire
            # tables in place.  Mid-run the only safe invariants are
            # monotonicity (events are only ever added; sweeps move them
            # between tables under the lock) and the global upper bound —
            # a double-absorb would overshoot, a lost table would make the
            # totals drop.
            last_c = last_t = 0
            while not stop.is_set():
                try:
                    c, t = _total(s.tables(), len(slots))
                    assert c >= last_c and t >= last_t, "totals went down"
                    assert c <= want_events and t <= want_events * dur
                    last_c, last_t = c, t
                except Exception as e:  # pragma: no cover - failure path
                    snapshot_errors.append(e)
                    return

        readers = [threading.Thread(target=reader, daemon=True)
                   for _ in range(3)]
        for r in readers:
            r.start()

        # spawn in waves and join each wave so later table() registrations
        # find plenty of dead tables: every wave crosses the sweep threshold
        wave = 16
        idx = 0
        for _ in range(self.N_THREADS // wave):
            ts = [threading.Thread(target=work, args=(idx + k,))
                  for k in range(wave)]
            idx += wave
            for t in ts:
                t.start()
            for t in ts:
                t.join(timeout=30)
                assert not t.is_alive()

        stop.set()
        for r in readers:
            r.join(timeout=30)
            assert not r.is_alive()
        assert not snapshot_errors, snapshot_errors[0]

        got_count, got_ns = _total(s.tables(), len(slots))
        assert got_count == want_events          # no loss, no double-count
        assert got_ns == want_events * dur
        # churn actually got folded away: the table list stays bounded by
        # the sweep threshold + one un-swept wave + the two retired
        # accumulators ('pool', 'retired') — not all 192 worker tables
        assert len(s.tables()) <= \
            ShadowTableSet.RETIRE_SWEEP_THRESHOLD + wave + 2

    def test_sweep_pools_unnamed_and_keeps_named_groups(self):
        s = ShadowTableSet()
        slot = s.registry.resolve("app", "worker", "api").slot

        def work(group):
            s.table(group=group).record_count(slot, 1)

        n = ShadowTableSet.RETIRE_SWEEP_THRESHOLD + 8
        for i in range(n):
            th = threading.Thread(
                target=work, args=("stage0" if i % 2 else None,))
            th.start()
            th.join(timeout=30)
        # force one more registration -> sweep of all the dead tables above
        s.table()
        groups = {t.group for t in s.tables()}
        assert "stage0" in groups      # explicit groups keep their identity
        assert "retired" in groups     # unnamed churn pools into 'retired'
        total = sum(int(t.count[slot]) for t in s.tables()
                    if t.capacity > slot)
        assert total == n