"""Paper Table 4 analogue: event throughput of the fold.

Scaler folds 62.9M API invocations/second at 20% overhead. We measure:
  * host layer: instrumented-call throughput (calls/s through @xfa.api)
  * host layer, counting-only mode (the paper's timing-off knob)
  * raw shadow-table record() throughput (the table itself)
  * device layer: fold emissions/s executed inside a jitted step
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Tracer
from repro.core.device_fold import DeviceFoldSpec
from repro.core.shadow import ShadowTable


def host_call_throughput(n: int = 200_000, timing: bool = True) -> float:
    t = Tracer()
    t.timing = timing

    @t.api("libx")
    def f():
        return None

    t0 = time.perf_counter_ns()
    for _ in range(n):
        f()
    dt = (time.perf_counter_ns() - t0) / 1e9
    return n / dt


def shadow_record_throughput(n: int = 1_000_000) -> float:
    st = ShadowTable()
    t0 = time.perf_counter_ns()
    for i in range(n):
        st.record(3, 100)
    dt = (time.perf_counter_ns() - t0) / 1e9
    return n / dt


def device_fold_throughput(n_slots: int = 64, iters: int = 1000) -> float:
    spec = DeviceFoldSpec()
    for i in range(n_slots):
        spec.declare("app", "moe", "dispatch", f"m{i}")
    spec.freeze()

    @jax.jit
    def step(table):
        for i in range(n_slots):
            table = spec.emit(table, "app", "moe", "dispatch", f"m{i}", 1.0)
        return table

    table = spec.init_table()
    table = step(table)
    jax.block_until_ready(table)
    t0 = time.perf_counter_ns()
    for _ in range(iters):
        table = step(table)
    jax.block_until_ready(table)
    dt = (time.perf_counter_ns() - t0) / 1e9
    return n_slots * iters / dt


def run():
    return [
        ("events.host_traced_per_s", host_call_throughput(timing=True),
         "paper: 62.9e6/s total across 80 threads"),
        ("events.host_count_only_per_s", host_call_throughput(timing=False),
         "timing off (paper's counting mode)"),
        ("events.shadow_record_per_s", shadow_record_throughput(),
         "raw table hot path"),
        ("events.device_emit_per_s", device_fold_throughput(),
         "in-graph fold emissions"),
    ]


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.0f},{note}")
