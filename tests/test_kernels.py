"""Per-kernel validation: Pallas (interpret mode) and chunked-jnp variants
against the pure-jnp oracles, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def arr(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# --------------------------------------------------------- flash attention --
ATTN_SHAPES = [
    # B, Hq, Hkv, Sq, Sk, D
    (1, 1, 1, 128, 128, 32),
    (2, 4, 2, 128, 128, 64),
    (2, 8, 1, 256, 256, 32),    # MQA
    (1, 6, 2, 128, 256, 32),    # cross/decode-ish Sq < Sk
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_pallas(shape, dtype, causal):
    B, Hq, Hkv, Sq, Sk, D = shape
    if causal and Sq != Sk:
        pytest.skip("causal offset covered separately")
    q, k, v = arr(B, Hq, Sq, D, dtype=dtype), arr(B, Hkv, Sk, D, dtype=dtype), \
        arr(B, Hkv, Sk, D, dtype=dtype)
    got = ops.attention(q, k, v, causal=causal, impl="pallas", interpret=True)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("block_k", [32, 64, 128])
def test_attention_chunked_blocks(block_k):
    q, k, v = arr(2, 4, 128, 32), arr(2, 2, 128, 32), arr(2, 2, 128, 32)
    got = ref.attention_chunked(q, k, v, causal=True, block_k=block_k)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_attention_chunked_flash_backward():
    q, k, v = arr(2, 4, 128, 16), arr(2, 2, 128, 16), arr(2, 2, 128, 16)

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.attention(q, k, v, causal=True)))

    def loss_chunk(q, k, v):
        return jnp.sum(jnp.sin(
            ref.attention_chunked(q, k, v, causal=True, block_k=32)))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_chk = jax.grad(loss_chunk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_chk):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-4)


def test_attention_softcap():
    q, k, v = arr(1, 2, 64, 16), arr(1, 2, 64, 16), arr(1, 2, 64, 16)
    got = ops.attention(q, k, v, causal=True, logit_softcap=30.0,
                        impl="pallas", interpret=True)
    want = ref.attention(q, k, v, causal=True, logit_softcap=30.0)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# --------------------------------------------------------- decode attention --
DEC_SHAPES = [(1, 1, 1, 128, 32), (2, 4, 2, 256, 64), (2, 8, 1, 512, 32)]


@pytest.mark.parametrize("shape", DEC_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_pallas(shape, dtype):
    B, Hq, Hkv, S, D = shape
    q = arr(B, Hq, D, dtype=dtype)
    k, v = arr(B, Hkv, S, D, dtype=dtype), arr(B, Hkv, S, D, dtype=dtype)
    kv_len = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    got = ops.decode_attention(q, k, v, kv_len=kv_len, impl="pallas",
                               interpret=True)
    want = ref.decode_attention(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


# ---------------------------------------------------------- chunk attention --
CHUNK_SHAPES = [
    # B, Hq, Hkv, T, S, D
    (1, 1, 1, 4, 128, 32),
    (2, 4, 2, 8, 256, 64),
    (2, 8, 1, 16, 512, 32),    # MQA, multi-block cache
]


@pytest.mark.parametrize("shape", CHUNK_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_attention_pallas(shape, dtype):
    """Offset-causal positioned-chunk kernel vs the oracle at mixed
    per-row offsets (each row's chunk lands at its own cache depth)."""
    B, Hq, Hkv, T, S, D = shape
    q = arr(B, Hq, T, D, dtype=dtype)
    k, v = arr(B, Hkv, S, D, dtype=dtype), arr(B, Hkv, S, D, dtype=dtype)
    pos = jnp.asarray(RNG.integers(0, S - T + 1, B), jnp.int32)
    got = ops.chunk_attention(q, k, v, pos=pos, impl="pallas",
                              interpret=True)
    want = ref.chunk_attention(q, k, v, pos=pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_chunk_attention_width1_is_decode():
    """T == 1 at offset pos must match decode attention with
    kv_len = pos + 1 — prefill and decode are one operation."""
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    q = arr(B, Hq, 1, D)
    k, v = arr(B, Hkv, S, D), arr(B, Hkv, S, D)
    pos = jnp.asarray([5, 77], jnp.int32)
    chunk = ref.chunk_attention(q, k, v, pos=pos)
    dec = ref.decode_attention(q[:, :, 0], k, v, kv_len=pos + 1)
    np.testing.assert_allclose(chunk[:, :, 0], dec, atol=2e-5, rtol=2e-5)


def test_chunk_attention_blocked_matches_oracle():
    q, k, v = arr(2, 4, 8, 32), arr(2, 2, 256, 32), arr(2, 2, 256, 32)
    pos = jnp.asarray([3, 200], jnp.int32)
    got = ref.chunk_attention_blocked(q, k, v, pos=pos, block_k=64)
    want = ref.chunk_attention(q, k, v, pos=pos)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_chunk_attention_ignores_stale_cache_past_frontier():
    """Columns beyond pos + t must get exactly-zero mass: poisoning them
    with huge values may not change the output (a serving slot's row
    holds a neighbour request's stale K/V past its own frontier)."""
    B, Hq, Hkv, T, S, D = 1, 2, 2, 4, 64, 16
    q = arr(B, Hq, T, D)
    k, v = arr(B, Hkv, S, D), arr(B, Hkv, S, D)
    pos = jnp.asarray([10], jnp.int32)
    clean = ref.chunk_attention(q, k, v, pos=pos)
    k_bad = k.at[:, :, 20:].set(1e4)
    v_bad = v.at[:, :, 20:].set(-1e4)
    poisoned = ref.chunk_attention(q, k_bad, v_bad, pos=pos)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(poisoned))


def test_decode_attention_residuals_combine():
    """Split-K: shard the KV, merge partials == unsharded decode."""
    B, Hq, Hkv, S, D = 2, 4, 2, 256, 32
    q = arr(B, Hq, D)
    k, v = arr(B, Hkv, S, D), arr(B, Hkv, S, D)
    full = ref.decode_attention(q, k, v)
    n_shards = 4
    o_parts, m_parts, l_parts = [], [], []
    for i in range(n_shards):
        sl = slice(i * S // n_shards, (i + 1) * S // n_shards)
        o, (m, l) = ref.decode_attention(q, k[:, :, sl], v[:, :, sl],
                                         return_residuals=True)
        o_parts.append(o)
        m_parts.append(m)
        l_parts.append(l)
    merged = ref.combine_decode_partials(
        jnp.stack(o_parts), jnp.stack(m_parts), jnp.stack(l_parts))
    np.testing.assert_allclose(merged, full, atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------- rmsnorm --
@pytest.mark.parametrize("rows,d", [(1, 64), (37, 128), (256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas(rows, d, dtype):
    x, w = arr(rows, d, dtype=dtype), arr(d, dtype=dtype)
    got = ops.rmsnorm(x, w, impl="pallas", interpret=True)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_rmsnorm_add_pallas():
    x, r, w = arr(64, 128), arr(64, 128), arr(128)
    y1, s1 = ops.rmsnorm_add(x, r, w, impl="pallas", interpret=True)
    y2, s2 = ops.rmsnorm_add(x, r, w, impl="ref")
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(s1, s2, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- ssd scan --
SSD_SHAPES = [(1, 64, 1, 16, 8, 32), (2, 128, 3, 32, 16, 32),
              (1, 96, 2, 16, 8, 32)]  # B, L, H, P, N, chunk


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_chunked_vs_naive(shape):
    B, L, H, P, N, chunk = shape
    x = arr(B, L, H, P)
    dt = jnp.abs(arr(B, L, H)) * 0.1
    a = -jnp.abs(arr(H))
    b, c = arr(B, L, N), arr(B, L, N)
    y1, h1 = ref.ssd_naive(x, dt, a, b, c)
    y2, h2 = ref.ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(h1, h2, atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("shape", SSD_SHAPES[:2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas(shape, dtype):
    B, L, H, P, N, chunk = shape
    x = arr(B, L, H, P, dtype=dtype)
    dt = jnp.abs(arr(B, L, H)) * 0.1
    a = -jnp.abs(arr(H))
    b, c = arr(B, L, N, dtype=dtype), arr(B, L, N, dtype=dtype)
    y1, h1 = ref.ssd_naive(x, dt, a, b, c)
    y2, h2 = ops.ssd_scan(x, dt, a, b, c, chunk=chunk, impl="pallas",
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=tol(dtype), rtol=20 * tol(dtype))
    np.testing.assert_allclose(h1, h2, atol=tol(dtype), rtol=20 * tol(dtype))


def test_ssd_pad_to_chunk():
    """ops.ssd_scan pads L to a chunk multiple without changing results."""
    B, L, H, P, N = 1, 50, 2, 8, 4
    x = arr(B, L, H, P)
    dt = jnp.abs(arr(B, L, H)) * 0.1
    a = -jnp.abs(arr(H))
    b, c = arr(B, L, N), arr(B, L, N)
    y1, h1 = ref.ssd_naive(x, dt, a, b, c)
    y2, h2 = ops.ssd_scan(x, dt, a, b, c, chunk=16, impl="ref")
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(h1, h2, atol=2e-5, rtol=2e-4)


def test_ssd_state_handoff():
    """Final state from a prefix + ssd_naive(h0=...) == full run."""
    B, L, H, P, N = 1, 64, 2, 8, 4
    x = arr(B, L, H, P)
    dt = jnp.abs(arr(B, L, H)) * 0.1
    a = -jnp.abs(arr(H))
    b, c = arr(B, L, N), arr(B, L, N)
    y_full, h_full = ref.ssd_naive(x, dt, a, b, c)
    _, h_half = ref.ssd_chunked(x[:, :32], dt[:, :32], a, b[:, :32],
                                c[:, :32], chunk=16)
    y2, h2 = ref.ssd_naive(x[:, 32:], dt[:, 32:], a, b[:, 32:], c[:, 32:],
                           h0=h_half)
    np.testing.assert_allclose(y_full[:, 32:], y2, atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(h_full, h2, atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_ssd_h0_resume_matches_full_run(impl):
    """ops.ssd_scan(h0=...) — the chunked-prefill resume path — run over
    two half-prompts equals one full-prompt scan, for both the oracle and
    the Pallas kernel (interpret mode)."""
    B, L, H, P, N = 2, 64, 2, 16, 8
    x = arr(B, L, H, P)
    dt = jnp.abs(arr(B, L, H)) * 0.1
    a = -jnp.abs(arr(H))
    b, c = arr(B, L, N), arr(B, L, N)
    kw = dict(chunk=16, impl=impl)
    if impl == "pallas":
        kw["interpret"] = True
    y_full, h_full = ops.ssd_scan(x, dt, a, b, c, **kw)
    y1, h1 = ops.ssd_scan(x[:, :32], dt[:, :32], a, b[:, :32], c[:, :32],
                          **kw)
    y2, h2 = ops.ssd_scan(x[:, 32:], dt[:, 32:], a, b[:, 32:], c[:, 32:],
                          h0=h1, **kw)
    np.testing.assert_allclose(y_full[:, 32:], y2, atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(h_full, h2, atol=2e-5, rtol=2e-4)
