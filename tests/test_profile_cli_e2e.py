"""End-to-end CLI tests: `python -m repro.profile ...` as real OS processes.

Everything the README advertises is exercised the way an operator (or CI)
runs it — argv in, stdout/exit-code out: report, merge, diff (exit 1 on an
injected regression, 0 otherwise), query (exit 1 on no match), gc, and
timeline.  The fixtures build run dirs through the public writer API so
the subprocesses see exactly what trainers/serving replicas leave behind.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.core.folding import fold_event_log
from repro.profile import (ProfileSnapshot, ProfileStore, RetentionPolicy,
                           register_run)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

EVENTS = [
    ("app", "glibc", "read", 18), ("app", "glibc", "write", 35),
    ("app", "alloc", "malloc", 10), ("moe", "pthread", "lock", 900),
]


def run_cli(*args, timeout=120):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.profile", *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.fixture()
def registry(tmp_path):
    """Two registered runs: 'train' (3-deep ring, 4x2 mesh) + 'serve'."""
    train = tmp_path / "train"
    store = ProfileStore(str(train))
    for i in range(1, 4):
        store.write_shard(fold_event_log(EVENTS * i), label="train-r0",
                          meta={"step": i})
    register_run(str(train), config="tinyllama_1_1b", arch="dense",
                 mesh_shape="4x2", label="train-r0", kind="train")

    serve = tmp_path / "serve"
    ProfileStore(str(serve)).write_shard(fold_event_log(EVENTS),
                                         label="serve-0")
    register_run(str(serve), config="qwen3_14b", arch="dense",
                 mesh_shape=(8,), label="serve-0", kind="serve")
    return tmp_path


class TestReportMergeCLI:
    def test_report_renders_views(self, registry):
        p = run_cli("report", registry / "train")
        assert p.returncode == 0, p.stderr
        assert "Component view: app" in p.stdout
        assert "Flow matrix" in p.stdout

    def test_report_json(self, registry):
        p = run_cli("report", registry / "train", "--json")
        assert p.returncode == 0, p.stderr
        doc = json.loads(p.stdout)
        assert doc["meta"]["label"] == "train-r0"
        assert len(doc["edges"]) == len(fold_event_log(EVENTS))

    def test_merge_reduces_newest_per_shard(self, registry, tmp_path):
        out = tmp_path / "merged.xfa.npz"
        p = run_cli("merge", registry / "train", registry / "serve",
                    "-o", out)
        assert p.returncode == 0, p.stderr
        merged = ProfileSnapshot.load(str(out)).to_folded()
        # newest train ring entry (EVENTS*3) + the serve shard (EVENTS*1):
        # older ring entries must NOT be double-counted
        assert merged.edges[("app", "glibc", "read")].count == 4

    def test_report_missing_dir_fails(self, tmp_path):
        p = run_cli("report", tmp_path / "nope")
        assert p.returncode != 0


class TestDiffCLI:
    def test_exit_codes_gate_regressions(self, registry, tmp_path):
        base = tmp_path / "base.xfa.npz"
        slow = tmp_path / "slow.xfa.npz"
        t = fold_event_log(EVENTS)
        ProfileSnapshot.from_folded(t).save(str(base))
        t.edges[("app", "glibc", "write")].total_ns *= 3   # injected 3x
        ProfileSnapshot.from_folded(t).save(str(slow))

        clean = run_cli("diff", base, base, "--threshold", "0.5")
        assert clean.returncode == 0, clean.stderr
        assert "0 regressed" in clean.stdout

        hot = run_cli("diff", base, slow, "--threshold", "0.5")
        assert hot.returncode == 1, hot.stderr
        assert "REG" in hot.stdout and "glibc.write" in hot.stdout

    def test_diff_run_dir_uses_newest_snapshot(self, registry, tmp_path):
        """diff against a run DIR reduces it first — and a new ring entry
        with more folded work is a regression the gate catches."""
        base = tmp_path / "base.xfa.npz"
        ProfileSnapshot.from_folded(fold_event_log(EVENTS)).save(str(base))
        p = run_cli("diff", base, registry / "train", "--threshold", "0.5")
        assert p.returncode == 1   # newest ring entry folded EVENTS*3


class TestQueryCLI:
    def test_filters_and_exit_codes(self, registry):
        p = run_cli("query", registry, "--config", "tinyllama_1_1b",
                    "--mesh", "4x2", "--label", "train-*")
        assert p.returncode == 0, p.stderr
        assert "train" in p.stdout and "serve" not in p.stdout

        none = run_cli("query", registry, "--label", "nope")
        assert none.returncode == 1            # grep-like: no match -> 1
        assert none.stdout.strip() == ""

    def test_json_output_carries_manifest(self, registry):
        p = run_cli("query", registry, "--kind", "serve", "--json")
        assert p.returncode == 0, p.stderr
        [run] = json.loads(p.stdout)
        assert run["config"] == "qwen3_14b"
        assert run["mesh_shape"] == [8]
        assert run["run_dir"].endswith("serve")

    def test_where_predicate(self, registry):
        p = run_cli("query", registry, "--where", "arch=dense")
        assert p.returncode == 0
        assert len(p.stdout.strip().splitlines()) == 2

    def test_malformed_where_is_a_usage_error(self, registry):
        p = run_cli("query", registry, "--where", "archdense")
        assert p.returncode == 2               # argparse usage error
        assert "KEY=VALUE" in p.stderr


class TestGcCLI:
    def test_gc_enforces_keep_last_across_runs(self, registry):
        train_store = ProfileStore(str(registry / "train"))
        assert len(train_store.snapshot_paths()) == 3
        p = run_cli("gc", registry, "--keep-last", "1")
        assert p.returncode == 0, p.stderr
        assert "deleted 2 snapshot(s)" in p.stdout
        # newest ring entry + manifest survive; reduce still works
        assert len(train_store.snapshot_paths()) == 1
        assert os.path.exists(registry / "train" / "manifest.json")
        assert train_store.reduce().to_folded().edges[
            ("app", "glibc", "read")].count == 3

    def test_gc_dry_run_keeps_everything(self, registry):
        p = run_cli("gc", registry, "--keep-last", "1", "--dry-run",
                    "--json")
        assert p.returncode == 0, p.stderr
        doc = json.loads(p.stdout)
        assert doc["dry_run"] is True
        assert sum(len(v) for v in doc["deleted"].values()) == 2
        assert len(ProfileStore(str(registry / "train"))
                   .snapshot_paths()) == 3


class TestTimelineCLI:
    def test_renders_deltas_across_ring(self, registry):
        p = run_cli("timeline", registry / "train", "--field", "count")
        assert p.returncode == 0, p.stderr
        assert "3 snapshots" in p.stdout
        assert "app -> glibc.read" in p.stdout
        assert "+1" in p.stdout                # per-interval delta columns

    def test_json_and_empty_exit_code(self, registry, tmp_path):
        p = run_cli("timeline", registry / "train", "--json",
                    "--field", "count")
        assert p.returncode == 0, p.stderr
        [tl] = json.loads(p.stdout)
        assert tl["edges"]["app -> glibc.read"]["deltas"] == [1.0, 1.0, 1.0]
        # a dir with no multi-entry ring renders nothing -> exit 1
        empty = run_cli("timeline", tmp_path)
        assert empty.returncode == 1


class TestCIBaselineLane:
    """The non-blocking CI profile-diff lane, run here as a gating test:
    the synthetic workload must regenerate the checked-in baseline and
    diff clean; injected slowdowns/new edges must trip the gate."""

    BASELINE = os.path.join(os.path.dirname(__file__), "data",
                            "ci_baseline.xfa.npz")
    SCRIPT = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "baseline_profile.py")

    def _gen(self, out, *extra):
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.run(
            [sys.executable, self.SCRIPT, "-o", str(out), *extra],
            capture_output=True, text=True, timeout=120, env=env)

    def test_workload_reproduces_checked_in_baseline(self, tmp_path):
        cand = tmp_path / "cand.xfa.npz"
        p = self._gen(cand)
        assert p.returncode == 0, p.stderr
        with open(self.BASELINE, "rb") as a, open(cand, "rb") as b:
            assert a.read() == b.read(), \
                "baseline drifted: regenerate tests/data/ci_baseline" \
                ".xfa.npz deliberately (see benchmarks/baseline_profile.py)"
        d = run_cli("diff", self.BASELINE, cand, "--threshold", "0.25")
        assert d.returncode == 0, d.stdout + d.stderr

    def test_injected_regression_trips_the_lane(self, tmp_path):
        slow = tmp_path / "slow.xfa.npz"
        assert self._gen(slow, "--scale", "1.6").returncode == 0
        assert run_cli("diff", self.BASELINE, slow,
                       "--threshold", "0.25").returncode == 1
        new_edge = tmp_path / "new.xfa.npz"
        assert self._gen(new_edge, "--extra-edge").returncode == 0
        assert run_cli("diff", self.BASELINE, new_edge,
                       "--threshold", "0.25").returncode == 1


class TestWriterRetentionE2E:
    def test_concurrent_style_writers_stay_bounded(self, tmp_path):
        """Many refreshes through the public writer with a tight policy:
        the run dir footprint stays bounded and the newest fold wins."""
        store = ProfileStore(str(tmp_path),
                             retention=RetentionPolicy(keep_last=2))
        for i in range(1, 8):
            store.write_shard(fold_event_log(EVENTS * i), label="w")
        assert len(store.snapshot_paths()) == 2
        p = run_cli("report", tmp_path, "--json")
        assert p.returncode == 0, p.stderr
        doc = json.loads(p.stdout)
        read = [e for e in doc["edges"]
                if (e["caller"], e["component"], e["api"])
                == ("app", "glibc", "read")]
        assert read[0]["count"] == 7