"""§Roofline report: aggregate the dry-run artifacts into the per-cell table.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits the
markdown table for EXPERIMENTS.md: three terms in seconds, dominant term,
MODEL_FLOPS ratio, roofline fraction, bytes/device — per (arch × shape ×
mesh). Also ranks cells for the perf loop (worst fraction / most
collective-bound)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ART = "artifacts/dryrun"


def load_records(tag: str = "") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("skipped"):
            continue
        if (r.get("tag") or "") != tag:
            continue
        r["_file"] = os.path.basename(path)
        out.append(r)
    return out


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.1f}"


def table(records: List[dict], mesh_filter: Optional[str] = None) -> str:
    rows = []
    head = ("| cell | mesh | compute ms | memory ms | collective ms | "
            "dominant | HBM GiB/dev | useful ratio | roofline frac |")
    sep = "|" + "---|" * 9
    for r in records:
        mesh = "x".join(str(s) for s in r["mesh"]["shape"])
        if mesh_filter and mesh != mesh_filter:
            continue
        ro = r["roofline"]
        mem_gib = r["memory_analysis"]["temp_bytes"] / 2 ** 30
        rows.append(
            f"| {r['cell']} | {mesh} | {fmt_ms(ro['compute_s'])} | "
            f"{fmt_ms(ro['memory_s'])} | {fmt_ms(ro['collective_s'])} | "
            f"{ro['dominant'].replace('_s','')} | {mem_gib:.1f} | "
            f"{ro['useful_flops_ratio']:.2f} | "
            f"{ro['roofline_fraction']:.3f} |")
    return "\n".join([head, sep] + rows)


def pick_hillclimb_cells(records: List[dict]) -> Dict[str, dict]:
    """worst roofline fraction (train), most collective-bound, most
    representative (the XFA-instrumented MoE a2a cell)."""
    single = [r for r in records if len(r["mesh"]["shape"]) == 2]
    train = [r for r in single if "train" in r["cell"]]
    worst = min(train, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(single, key=lambda r: r["roofline"]["collective_s"]
               / max(sum((r["roofline"]["compute_s"],
                          r["roofline"]["memory_s"],
                          r["roofline"]["collective_s"])), 1e-12))
    rep = next((r for r in train if "deepseek" in r["cell"]), worst)
    return {"worst_fraction": worst, "most_collective": coll,
            "paper_representative": rep}


def compare(base_dir: str = "artifacts/dryrun_baseline") -> str:
    """Before/after table: paper-faithful baseline vs optimized train cells."""
    import glob as g
    base = {}
    for path in sorted(g.glob(os.path.join(base_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if not r.get("skipped") and not r.get("tag"):
            mesh = "x".join(str(s) for s in r["mesh"]["shape"])
            base[(r["cell"], mesh)] = r
    cur = {}
    for r in load_records():
        mesh = "x".join(str(s) for s in r["mesh"]["shape"])
        cur[(r["cell"], mesh)] = r
    rows = ["| cell | mesh | coll. before ms | after ms | frac before | "
            "after |", "|" + "---|" * 6]
    for key in sorted(base):
        if key not in cur or "train" not in key[0]:
            continue
        b, c = base[key], cur[key]
        rows.append(
            f"| {key[0]} | {key[1]} | "
            f"{b['roofline']['collective_s']*1e3:.0f} | "
            f"{c['roofline']['collective_s']*1e3:.0f} | "
            f"{b['roofline']['roofline_fraction']:.3f} | "
            f"{c['roofline']['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main() -> None:
    records = load_records()
    print("## Roofline — single pod (16x16 = 256 chips)\n")
    print(table(records, "16x16"))
    print("\n## Roofline — multi-pod (2x16x16 = 512 chips)\n")
    print(table(records, "2x16x16"))
    if os.path.isdir("artifacts/dryrun_baseline"):
        print("\n## Train cells: paper-faithful baseline vs optimized\n")
        print(compare())
    picks = pick_hillclimb_cells(records)
    print("\n## Hillclimb picks")
    for why, r in picks.items():
        print(f"- {why}: {r['cell']} "
              f"(frac={r['roofline']['roofline_fraction']:.3f}, "
              f"dominant={r['roofline']['dominant']})")


if __name__ == "__main__":
    main()
