"""repro.core — Cross Flow Analysis (XFA) for distributed JAX systems.

The paper's contribution (Scaler, ASE'24), adapted from x86/ELF binaries to
the TPU/JAX stack. Three layers:

  L1 host   tracer.py + shadow.py   @xfa.api boundaries, Universal Shadow
                                    Table slots, per-thread lock-free folds
  L2 device device_fold.py          in-graph fixed-shape fold accumulators
  L3 static hlo_flows.py            collective flows read from compiled HLO

folding.py is the shared Relation-Aware Data Folding algebra; views.py the
component/API views; attribution.py the serial/parallel/wait logic;
session.py ties a run together.
"""

from .shadow import (APP_COMPONENT, KIND_CALL, KIND_WAIT, ShadowTable,
                     ShadowTableSet, SlotInfo, SlotRegistry)
from .folding import EdgeStats, FoldedTable, fold_event_log
from .tracer import (TRACER, Tracer, api, count_event, current_component,
                     reset, scope, set_enabled, set_thread_group, set_timing,
                     wait, wrap)
from .device_fold import (STATIC_COSTS, DeviceFoldSpec, annotate_cost,
                          scan_multiplier)
from .hlo_flows import (CollectiveFlow, CollectiveSummary,
                        find_redundant_gathers, parse_collective_flows)
from .attribution import (ImbalanceReport, attribute_parallel,
                          attribute_serial, combine_phases, expert_imbalance,
                          imbalance_report, wait_split)
from .views import (View, ViewRow, api_view, api_view_by_caller,
                    component_view, flow_matrix, metric_view,
                    render_flow_matrix)
from .session import KNOWN_COMPONENTS, XFAReport, XFASession
