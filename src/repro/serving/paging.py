"""Page allocator for the paged KV-cache pool.

The serving cache is a fixed arena of `n_pages` pages of `page_size` rows
(page 0 reserved as scratch — bucket-pad and past-frontier writes resolve
there and are masked on read), plus one block table per slot mapping
virtual page -> arena page.  This module owns the page *accounting*; the
engine owns the tables and the device arrays.

Admission is reservation-based: the scheduler's page gate calls
`try_reserve(uid, pages_needed(rows))` with the request's WORST-CASE row
count (prompt + max_new - 1) before granting a slot, and the engine then
draws pages lazily via `grant` as the slot's frontier crosses page
boundaries.  Because a grant can never exceed its reservation, the free
list cannot underflow mid-flight — admission is the only place that can
say no, which is what makes page exhaustion back-pressure (a queue the
diagnose plane can watch) instead of a mid-decode deadlock.
"""

from __future__ import annotations

import threading
from typing import Dict, List


class PageAllocator:
    """Reservation-then-grant page accounting (thread-safe).

    Physical pages move free -> granted(uid) -> free; reservations are a
    pure counter (committed pages a uid may still draw).  `in_use` counts
    granted pages only — it is the real footprint the
    `serve.cache_pages_in_use` gauge reports; `hwm` is its high-water
    mark, the number a right-sized arena actually needs.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("paged cache needs >= 2 pages "
                             "(page 0 is reserved scratch)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        # page 0 is the scratch page: never granted, so an all-zero block
        # table row means "nothing allocated" and stray writes are inert
        self._free: List[int] = list(range(1, n_pages))
        self._granted: Dict[object, List[int]] = {}
        self._reserved: Dict[object, int] = {}
        self._lock = threading.Lock()
        self.hwm = 0

    # ------------------------------------------------------------ queries --
    @property
    def usable(self) -> int:
        """Pages that can ever be granted (arena minus the scratch page)."""
        return self.n_pages - 1

    @property
    def in_use(self) -> int:
        with self._lock:
            return self.usable - len(self._free)

    def pages_needed(self, rows: int) -> int:
        """Pages covering `rows` cache rows (>= 1 so every slot owns one)."""
        return max(1, -(-int(rows) // self.page_size))

    # ------------------------------------------------------- reservations --
    def try_reserve(self, uid, pages: int) -> bool:
        """Commit `pages` to `uid` if the pool can honour it; False = the
        caller must wait (FCFS back-pressure).  Committed = granted +
        outstanding reservations, so several admits in one scheduling pass
        cannot oversubscribe the arena."""
        with self._lock:
            committed = (self.usable - len(self._free)
                         + sum(self._reserved.values()))
            if committed + pages > self.usable:
                return False
            self._reserved[uid] = self._reserved.get(uid, 0) + int(pages)
            return True

    def cancel(self, uid) -> None:
        """Drop an unused reservation (admission rollback path)."""
        with self._lock:
            self._reserved.pop(uid, None)

    # -------------------------------------------------------------- pages --
    def grant(self, uid, pages: int) -> List[int]:
        """Draw `pages` physical pages against uid's reservation; returns
        the page ids (the engine writes them into the slot's block
        table).  Raises if the reservation is exhausted — that is a
        caller bug (reserve must cover the worst case), not a wait."""
        if pages <= 0:
            return []
        with self._lock:
            held = self._reserved.get(uid, 0)
            if pages > held:
                raise RuntimeError(
                    f"page grant over-draws reservation: uid={uid!r} "
                    f"wants {pages}, holds {held}")
            # reservation accounting guarantees the free list covers this
            got = [self._free.pop() for _ in range(pages)]
            self._reserved[uid] = held - pages
            self._granted.setdefault(uid, []).extend(got)
            self.hwm = max(self.hwm, self.usable - len(self._free))
            return got

    def release(self, uid) -> int:
        """Recycle all of uid's pages and drop any leftover reservation.
        Returns the number of physical pages freed."""
        with self._lock:
            pages = self._granted.pop(uid, [])
            self._free.extend(pages)
            self._reserved.pop(uid, None)
            return len(pages)
