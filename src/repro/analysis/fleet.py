"""Fleet-level diagnosis — ranking findings across runs and hosts.

A collector spool (profile/collector.py) turns one registry root into a
fleet view: every run dir may now hold shards from SEVERAL hosts, with
host-qualified stems (`host/shard`) keeping two hosts' same-named rank-0
rings apart.  This module is the analysis layer over that: it diagnoses
every selected run with the existing detector set, adds cross-host
detectors that the single-run context cannot express, and ranks the
union so `diagnose --fleet` answers "which host, in which run, is
hurting the fleet" in one report.

Cross-host detection mirrors RankImbalance but one level up: per-HOST
merged graphs (all of one host's shards reduced) are the comparable
subgraphs, so a straggler *host* shows up even when its individual
ranks are internally balanced.  Cross-run ranking reuses each run's
Diagnosis verbatim — findings are tagged with (run_id, host) and sorted
by the same (severity, detector, subject) key, then grouped by
(severity, detector, host) for the JSON report.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .detectors import SEVERITIES, Finding, severity_rank
from .diagnose import Diagnosis, _is_run_dir, diagnose
from .graph import FlowGraph


def stem_host(stem: str, meta: Optional[Dict[str, Any]] = None) -> str:
    """The host a shard stem belongs to.

    Spooled stems are host-qualified (`host/shard` — collector layout);
    flat local stems fall back to the snapshot's recorded `host` meta
    (store.write_shard records it), then to the hostname embedded in the
    `label-host-pid` stem convention, then to '-'."""
    if "/" in stem:
        return stem.split("/", 1)[0]
    if meta and meta.get("host"):
        return str(meta["host"])
    parts = stem.rsplit("-", 2)
    if len(parts) == 3 and parts[2].isdigit():
        return parts[1]
    return "-"


def host_graphs(run_dir: str) -> Dict[str, FlowGraph]:
    """Per-host merged graphs of one run: host -> FlowGraph reducing the
    newest ring entry of every shard that host wrote.  These are the
    comparable units for cross-host straggler detection — a whole host
    that runs hot is visible here even when its own ranks agree with
    each other."""
    from ..profile.snapshot import ProfileSnapshot
    from ..profile.store import ProfileStore
    by_host: Dict[str, List[ProfileSnapshot]] = {}
    for stem, ring in sorted(ProfileStore(run_dir).shards().items()):
        snap = ProfileSnapshot.load(ring[-1][1])
        if "merged_from" in snap.meta:
            continue
        by_host.setdefault(stem_host(stem, snap.meta), []).append(snap)
    out: Dict[str, FlowGraph] = {}
    for host, snaps in sorted(by_host.items()):
        merged = snaps[0] if len(snaps) == 1 \
            else ProfileSnapshot.merge(snaps, meta={"host": host})
        out[host] = FlowGraph.from_snapshot(merged)
    return out


def fleet_straggler_findings(hosts: Dict[str, FlowGraph], *,
                             warn_rel: float = 0.25,
                             crit_rel: float = 0.5,
                             min_hosts: int = 2,
                             min_total_ns: int = 1_000_000) -> List[Finding]:
    """Cross-host rank-imbalance: the host whose merged graph folded the
    most time, measured against the fleet mean, localized to the
    component with the widest per-host spread (same math as the
    rank-imbalance detector, with hosts as the comparable shards)."""
    if len(hosts) < min_hosts:
        return []
    totals = {h: g.total_ns() for h, g in sorted(hosts.items())}
    mean = sum(totals.values()) / len(totals)
    if mean < min_total_ns:
        return []
    straggler = max(sorted(totals), key=lambda h: totals[h])
    rel = (totals[straggler] - mean) / mean if mean else 0.0
    if rel < warn_rel:
        return []
    comps = sorted({c for g in hosts.values() for c in g.components()})
    spread = {}
    for c in comps:
        per = [hosts[h].nodes[c].in_total_ns if c in hosts[h].nodes else 0
               for h in sorted(hosts)]
        spread[c] = max(per) - min(per)
    culprit = max(comps, key=lambda c: (spread[c], c)) if comps else ""
    return [Finding(
        "fleet-straggler",
        "crit" if rel >= crit_rel else "warn",
        f"host:{straggler}",
        f"host '{straggler}' folded {totals[straggler] / 1e6:.2f}ms, "
        f"{100.0 * rel:.0f}% above the {len(totals)}-host mean "
        f"({mean / 1e6:.2f}ms); widest spread in component '{culprit}'",
        evidence={"rel_above_mean": rel, "host_total_ns": totals,
                  "mean_ns": mean, "widest_component": culprit})]


def fleet_run_outlier_findings(run_totals: Dict[str, int], *,
                               warn_rel: float = 0.5,
                               crit_rel: float = 1.0,
                               min_runs: int = 3,
                               min_total_ns: int = 1_000_000
                               ) -> List[Finding]:
    """Cross-RUN outlier: with three or more comparable runs of one
    config, a run whose merged total sits far above the mean of the
    others is flagged — the fleet-level 'this launch is not like the
    rest' signal that no single-run detector can produce."""
    if len(run_totals) < min_runs:
        return []
    mean = sum(run_totals.values()) / len(run_totals)
    if mean < min_total_ns:
        return []
    out = []
    for run_id in sorted(run_totals):
        rel = (run_totals[run_id] - mean) / mean if mean else 0.0
        if rel < warn_rel:
            continue
        out.append(Finding(
            "fleet-run-outlier",
            "crit" if rel >= crit_rel else "warn",
            f"run:{run_id}",
            f"run '{run_id}' folded {run_totals[run_id] / 1e6:.2f}ms, "
            f"{100.0 * rel:.0f}% above the {len(run_totals)}-run mean "
            f"({mean / 1e6:.2f}ms)",
            evidence={"rel_above_mean": rel, "run_total_ns": run_totals,
                      "mean_ns": mean}))
    return out


def finding_host(f: Finding) -> str:
    """Best-effort host attribution of a finding for report grouping:
    `host:` subjects name it directly, `shard:` subjects carry it when
    the stem is host-qualified; everything else groups under '-'."""
    if f.subject.startswith("host:"):
        return f.subject.split(":", 1)[1]
    if f.subject.startswith("shard:"):
        stem = f.subject.split(":", 1)[1]
        if "/" in stem:
            return stem.split("/", 1)[0]
    return "-"


@dataclass
class FleetDiagnosis:
    """Findings from every selected run, ranked and grouped fleet-wide."""

    root: str
    runs: List[Diagnosis] = field(default_factory=list)
    fleet_findings: List[Tuple[str, Finding]] = field(default_factory=list)
    hosts_by_run: Dict[str, List[str]] = field(default_factory=dict)
    config: Optional[str] = None
    run_pattern: Optional[str] = None

    def ranked(self) -> List[Tuple[str, Finding]]:
        """(run_id, finding) pairs, fleet findings and per-run findings
        together, by the shared (severity, detector, subject) key."""
        rows = list(self.fleet_findings)
        for d in self.runs:
            run_id = os.path.basename(os.path.normpath(d.run_dir))
            rows.extend((run_id, f) for f in d.findings)
        rows.sort(key=lambda rf: rf[1].sort_key() + (rf[0],))
        return rows

    def counts(self) -> Dict[str, int]:
        c = {s: 0 for s in SEVERITIES}
        for _run, f in self.ranked():
            c[f.severity] += 1
        return c

    def worst(self) -> Optional[str]:
        return max((f.severity for _r, f in self.ranked()),
                   key=severity_rank, default=None)

    def should_fail(self, fail_on: Optional[str]) -> bool:
        if not fail_on or fail_on == "none":
            return False
        bar = severity_rank(fail_on)
        return any(severity_rank(f.severity) >= bar
                   for _r, f in self.ranked())

    def groups(self) -> List[Dict[str, Any]]:
        """Findings grouped by (severity, detector, host), most severe
        group first — the JSON report's spine."""
        grouped: Dict[Tuple[str, str, str], List[Dict[str, Any]]] = {}
        for run_id, f in self.ranked():
            key = (f.severity, f.detector, finding_host(f))
            grouped.setdefault(key, []).append(
                dict(f.to_json(), run=run_id))
        out = []
        for (sev, det, host) in sorted(
                grouped, key=lambda k: (-severity_rank(k[0]), k[1], k[2])):
            out.append({"severity": sev, "detector": det, "host": host,
                        "findings": grouped[(sev, det, host)]})
        return out

    def to_json(self) -> dict:
        return {
            "root": self.root,
            "config": self.config,
            "run_pattern": self.run_pattern,
            "runs": [{"run_dir": d.run_dir,
                      "hosts": self.hosts_by_run.get(
                          os.path.basename(os.path.normpath(d.run_dir)), []),
                      "counts": d.counts(),
                      "graph": dict(d.graph_stats)} for d in self.runs],
            "counts": self.counts(),
            "groups": self.groups(),
        }

    def render(self, top: int = 50) -> str:
        c = self.counts()
        n_hosts = len({h for hs in self.hosts_by_run.values() for h in hs})
        lines = [
            f"fleet diagnosis: {self.root}"
            + (f" (config={self.config})" if self.config else "")
            + (f" (run={self.run_pattern})" if self.run_pattern else ""),
            f"  {len(self.runs)} run(s), {n_hosts} host(s); findings: "
            f"{c['crit']} crit, {c['warn']} warn, {c['info']} info",
        ]
        rows = self.ranked()
        for run_id, f in rows[:top]:
            lines.append(f"  [{f.severity.upper():4s}] {run_id} "
                         f"{f.detector}: {f.message}")
        if len(rows) > top:
            lines.append(f"  ... ({len(rows) - top} more)")
        if not rows:
            lines.append("  no findings — every run looks healthy to every "
                         "detector")
        return "\n".join(lines)


def diagnose_fleet(root: str, *, config: Optional[str] = None,
                   run: Optional[str] = None,
                   thresholds_path: Optional[str] = None,
                   overrides: Optional[Dict[str, Dict]] = None,
                   detector_config: Optional[str] = None) -> FleetDiagnosis:
    """Diagnose every registered run under `root` (filtered by `config`
    and/or a `run` id/label glob), add cross-host and cross-run fleet
    findings, and rank the union.

    Unlike single-run `diagnose`, selection is a QUERY, not a find —
    matching several runs is the point.  A root that is itself a run dir
    degrades to a one-run fleet (cross-host detection still applies if
    its shards are host-qualified)."""
    import fnmatch
    run_dirs: List[str]
    if _is_run_dir(root):
        run_dirs = [root]
    else:
        from ..profile.index import RunRegistry
        manifests = RunRegistry(root).query(config=config)
        if run:
            manifests = [m for m in manifests
                         if fnmatch.fnmatchcase(m.run_id, run)
                         or fnmatch.fnmatchcase(m.label, run)
                         or fnmatch.fnmatchcase(m.config, run)]
        run_dirs = [m.run_dir for m in manifests
                    if _is_run_dir(m.run_dir)]
        if not run_dirs:
            what = [f"config={config!r}" if config else "",
                    f"run={run!r}" if run else ""]
            sel = " ".join(w for w in what if w) or "any run"
            raise LookupError(
                f"no registered run with snapshots under {root!r} "
                f"matches {sel}")
    fleet = FleetDiagnosis(root=os.path.abspath(root), config=config,
                           run_pattern=run)
    run_totals: Dict[str, int] = {}
    for run_dir in run_dirs:
        d = diagnose(run_dir, thresholds_path=thresholds_path,
                     overrides=overrides, detector_config=detector_config)
        fleet.runs.append(d)
        run_id = os.path.basename(os.path.normpath(run_dir))
        hosts = host_graphs(run_dir)
        fleet.hosts_by_run[run_id] = sorted(hosts)
        fleet.fleet_findings.extend(
            (run_id, f) for f in fleet_straggler_findings(hosts))
        run_totals[run_id] = sum(g.total_ns() for g in hosts.values())
    fleet.fleet_findings.extend(
        ("*", f) for f in fleet_run_outlier_findings(run_totals))
    return fleet
