"""Blockwise causal GQA flash attention — Pallas TPU kernel.

TPU adaptation of FlashAttention [arXiv:2205.14135 / 2307.08691]: instead of
a CUDA thread-block tiling we tile for the MXU/VMEM hierarchy —

  grid = (B, Hq, Sq/BQ, Sk/BK), kv-block dim innermost and 'arbitrary'
  (sequential) so the online-softmax accumulators live in VMEM scratch across
  kv iterations; batch/head/q-block dims are 'parallel'.

  q block   [BQ, D]  VMEM   (revisited for every kv block — Mosaic pipelines)
  k,v block [BK, D]  VMEM   (GQA: index_map folds q-head -> kv-head, so MQA
                             kv=1 never replicates KV into VMEM)
  acc       [BQ, D]  f32 scratch; m, l [BQ, 128] f32 scratch (TPU wants the
                             minor dim lane-shaped; col 0 is the live value)

Causal skipping: kv blocks strictly above the diagonal contribute nothing;
`pl.when` skips their FLOPs (the grid itself is not pruned — Mosaic requires
a static grid; the skipped iterations cost only the (tiny) bounds check).

Block sizes default to 128x128: the MXU is 128x128 and the f32 VMEM working
set (BQ*D acc + 2*BK*D kv + BQ*BK scores) stays < 1 MB for D<=256.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  logit_softcap: float, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: kv block strictly above the diagonal -> no contribution
    needed = (not causal) or (ik * block_k <= iq * block_q + block_q - 1)
    run = jnp.bool_(True) if not causal else (
        ik * block_k <= iq * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)                 # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)                 # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)

        m_prev = m_ref[:, 0]                                # [BQ]
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                     # [BQ, BK]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        # guard fully-masked rows (can only happen with q_offset padding)
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: Optional[float] = None,
                    logit_softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False
                    ) -> jax.Array:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D]; returns [B, Hq, Sq, D]."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    scale = sm_scale if sm_scale is not None else D ** -0.5

    kernel = functools.partial(
        _flash_kernel, sm_scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, logit_softcap=logit_softcap, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="xfa_flash_attention",
    )(q, k, v)
