"""xlstm-1.3b — mLSTM (matrix memory, chunkwise-parallel) + sLSTM blocks
[arXiv:2405.04517]. d_ff=0: projection factor lives inside the blocks.
48 blocks = 6 super-blocks of (7 mLSTM + 1 sLSTM)."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=8, mlstm_proj_factor=2.0, ssm_chunk=128,
).validate()


def smoke():
    return reduced(CONFIG, d_ff=0, slstm_every=2, n_heads=2, n_kv_heads=2)
