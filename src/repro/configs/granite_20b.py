"""granite-20b — dense code LM, llama-style, MQA (kv=1) [arXiv:2405.04324]."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    mlp_gated=False,
).validate()


def smoke():
    return reduced(CONFIG, n_kv_heads=1)
