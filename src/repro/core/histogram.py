"""Bounded log-bucket latency histograms (the per-edge sketch behind p99).

Scaler's folds keep count/total/min/max — enough for means, blind to
tails.  This module defines the fixed bucket layout that turns a folded
edge into a latency *distribution* at a bounded cost: HDR-style
linear-within-octave buckets, ``HIST_SUB`` sub-buckets per power of two
over octaves ``0..HIST_MAX_EXP-1`` (1 ns up to ~18 minutes), giving a
constant ``HIST_BUCKETS`` uint64 counters per edge (~1.25 KiB) and a
relative resolution of ``1/HIST_SUB`` within every octave.  That is the
lightweight-monitoring bargain (ScALPEL): no raw samples, no dynamic
allocation, and merge is an exact element-wise add — associative,
commutative, and loss-free, so shard merges and ring differencing keep
working on distributions exactly as they do on counters.

Bucket ``b`` covers ``[bucket_lo(b), bucket_hi(b))`` in integer
nanoseconds; durations are clamped into ``[1, 2**HIST_MAX_EXP - 1]``
before bucketing, so every recorded event lands in exactly one bucket
and ``hist.sum() == number of recorded events``.

Percentile read-out interpolates linearly inside the crossed bucket
(midpoint error is bounded by half the bucket width, i.e. ~12.5%
relative for HIST_SUB=4).  Jitter follows CORTEX's percentile-delta
convention: ``jitter = p99 - p50``.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

#: linear sub-buckets per power-of-two octave (resolution = 1/HIST_SUB)
HIST_SUB = 4
#: number of octaves covered; max representable duration is 2**HIST_MAX_EXP-1
HIST_MAX_EXP = 40
#: total bucket count — the fixed width of every per-edge histogram row
HIST_BUCKETS = HIST_SUB * HIST_MAX_EXP

_MAX_NS = (1 << HIST_MAX_EXP) - 1


def bucket_index(dur_ns: int) -> int:
    """Bucket for an integer duration; pure integer math, no floats.

    ``e = bit_length - 1`` is the octave; the sub-bucket is the top two
    fractional bits of the mantissa, so the formula is
    ``HIST_SUB*e + (HIST_SUB*d >> e) - HIST_SUB``.
    """
    d = int(dur_ns)
    if d < 1:
        d = 1
    elif d > _MAX_NS:
        d = _MAX_NS
    e = d.bit_length() - 1
    return HIST_SUB * e + ((HIST_SUB * d) >> e) - HIST_SUB


def _edges() -> np.ndarray:
    """Lower edge of every bucket plus the final upper bound,
    shape [HIST_BUCKETS + 1], float64 ns."""
    out = np.empty(HIST_BUCKETS + 1, dtype=np.float64)
    for e in range(HIST_MAX_EXP):
        base = float(1 << e)
        for s in range(HIST_SUB):
            out[HIST_SUB * e + s] = base * (1.0 + s / HIST_SUB)
    out[HIST_BUCKETS] = float(1 << HIST_MAX_EXP)
    return out

#: bucket boundaries in ns: bucket b covers [BUCKET_EDGES[b], BUCKET_EDGES[b+1])
BUCKET_EDGES = _edges()
BUCKET_EDGES.setflags(write=False)


def new_hist(n: int = 1) -> np.ndarray:
    """Zeroed histogram block: shape [n, HIST_BUCKETS], uint64."""
    return np.zeros((n, HIST_BUCKETS), dtype=np.uint64)


def hist_of(durations_ns: Iterable[int]) -> np.ndarray:
    """Histogram of a duration sample, shape [HIST_BUCKETS] uint64.
    Convenience for tests/benchmarks — the hot path buckets inline."""
    h = np.zeros(HIST_BUCKETS, dtype=np.uint64)
    for d in durations_ns:
        h[bucket_index(d)] += 1
    return h


def percentile_ns(hist: Optional[np.ndarray], q: float) -> float:
    """q-th quantile (q in [0, 1]) of a single histogram row, in ns.

    Returns 0.0 for a missing or empty histogram.  Finds the bucket where
    the cumulative count crosses ``q * total`` and interpolates linearly
    within it, so p50 of a single-bucket histogram lands mid-bucket
    rather than on an edge.
    """
    if hist is None:
        return 0.0
    h = np.asarray(hist, dtype=np.float64).ravel()
    total = float(h.sum())
    if total <= 0.0:
        return 0.0
    rank = q * total
    cum = np.cumsum(h)
    b = int(np.searchsorted(cum, rank, side="left"))
    if b >= HIST_BUCKETS:
        b = HIST_BUCKETS - 1
    # skip leading empty buckets searchsorted may land on when rank == 0
    while h[b] == 0.0 and b < HIST_BUCKETS - 1:
        b += 1
    prev = cum[b] - h[b]
    frac = (rank - prev) / h[b] if h[b] > 0.0 else 0.0
    frac = min(max(frac, 0.0), 1.0)
    lo, hi = BUCKET_EDGES[b], BUCKET_EDGES[b + 1]
    return float(lo + frac * (hi - lo))


def percentiles_ns(hist: Optional[np.ndarray],
                   qs: Sequence[float] = (0.50, 0.95, 0.99)) -> tuple:
    """Vector of quantiles for one histogram row (0.0s when empty)."""
    return tuple(percentile_ns(hist, q) for q in qs)


def jitter_ns(hist: Optional[np.ndarray]) -> float:
    """Tail jitter as a percentile delta: p99 - p50 (CORTEX convention)."""
    p50, _, p99 = percentiles_ns(hist)
    return p99 - p50
