"""Fault tolerance: heartbeats, failure simulation, elastic re-mesh,
straggler mitigation. Host-level logic, simulated multi-host (this box has
one process; at 1000+ nodes the same objects run per-host with the heartbeat
store backed by the cluster's kv-store, as documented in DESIGN.md).

Design points for 1000+ nodes:
  * HeartbeatMonitor is O(#hosts) memory and O(1) per beat (a slot write in
    a preallocated array — the Universal Shadow Table pattern applied to
    liveness; XFA and FT share the fold-don't-log philosophy).
  * Elastic re-mesh: on failure, survivors re-form the largest mesh that
    preserves the model axis (TP cannot shrink without resharding weights
    across a different factorization) and shrink the data axis; training
    resumes from the last checkpoint with per-leaf device_put against the
    new sharding (ckpt.manager.restore(shardings=...)).
  * Straggler mitigation reads per-host step-time folds (XFA host layer) and
    flags hosts whose median step exceeds k x fleet median; the driver can
    then drop them from the mesh proactively (same path as a failure).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import tracer as xfa


class HeartbeatMonitor:
    """Preallocated last-beat slots per host; misses -> declared dead."""

    def __init__(self, n_hosts: int, timeout_s: float = 5.0) -> None:
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self._last = np.full(n_hosts, time.monotonic(), dtype=np.float64)
        self._failed = np.zeros(n_hosts, dtype=bool)

    def beat(self, host: int, at: Optional[float] = None) -> None:
        self._last[host] = time.monotonic() if at is None else at

    def inject_failure(self, host: int) -> None:
        """Test/chaos hook: host stops beating AND is marked immediately."""
        self._failed[host] = True

    def check(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        late = (now - self._last) > self.timeout_s
        self._failed |= late
        return [int(i) for i in np.nonzero(self._failed)[0]]

    def alive(self) -> List[int]:
        dead = set(self.check())
        return [i for i in range(self.n_hosts) if i not in dead]


@dataclass
class MeshPlan:
    """A (possibly shrunk) mesh proposal after failures."""
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    hosts: List[int]
    lost_fraction: float

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def elastic_remesh(alive_hosts: Sequence[int], devices_per_host: int,
                   model_axis: int, axes: Tuple[str, ...] = ("data", "model"),
                   pod_axis: int = 1) -> MeshPlan:
    """Largest mesh over survivors that preserves the model (TP) axis.

    The data axis absorbs the shrink (DP is stateless across replicas given
    ZeRO-1 state is re-sharded at restore). With a pod axis, whole pods are
    dropped if partially dead (ICI within a pod is all-or-nothing)."""
    total = len(alive_hosts) * devices_per_host
    if total < model_axis:
        raise RuntimeError(
            f"cannot preserve model axis {model_axis} with {total} devices")
    data_axis = total // model_axis
    used_hosts = list(alive_hosts)
    shape: Tuple[int, ...]
    if "pod" in axes:
        data_axis = data_axis // pod_axis
        shape = (pod_axis, data_axis, model_axis)
    else:
        shape = (data_axis, model_axis)
    lost = 1.0 - (data_axis * model_axis * (pod_axis if "pod" in axes else 1)
                  ) / max(total, 1)
    return MeshPlan(shape=shape, axes=axes, hosts=used_hosts,
                    lost_fraction=max(lost, 0.0))


@dataclass
class StragglerReport:
    per_host_ms: Dict[int, float]
    median_ms: float
    stragglers: List[int]
    threshold: float


class StragglerDetector:
    """Folds per-host step times (no log — a [hosts] running summary)."""

    def __init__(self, n_hosts: int, window: int = 32,
                 threshold: float = 1.5) -> None:
        self.n_hosts = n_hosts
        self.threshold = threshold
        self._sums = np.zeros(n_hosts)
        self._counts = np.zeros(n_hosts)

    def observe(self, host: int, step_ms: float) -> None:
        self._sums[host] += step_ms
        self._counts[host] += 1

    def report(self) -> StragglerReport:
        means = np.divide(self._sums, np.maximum(self._counts, 1))
        active = means[self._counts > 0]
        med = float(np.median(active)) if active.size else 0.0
        stragglers = [int(i) for i in range(self.n_hosts)
                      if self._counts[i] > 0
                      and means[i] > self.threshold * med > 0]
        return StragglerReport(
            per_host_ms={int(i): float(means[i]) for i in
                         range(self.n_hosts) if self._counts[i] > 0},
            median_ms=med, stragglers=stragglers,
            threshold=self.threshold)


class SimulatedCluster:
    """N simulated hosts driving one shared step function — the test double
    for the multi-host runtime. Each host is a thread: beats, steps (with an
    injectable delay = straggler), and can be killed (= failure)."""

    def __init__(self, n_hosts: int, monitor: HeartbeatMonitor,
                 step_fn: Callable[[int, int], None],
                 delays_s: Optional[Dict[int, float]] = None) -> None:
        self.monitor = monitor
        self.step_fn = step_fn
        self.delays = delays_s or {}
        self.n_hosts = n_hosts
        self._kill = [threading.Event() for _ in range(n_hosts)]
        self._threads: List[threading.Thread] = []
        self.detector = StragglerDetector(n_hosts)

    def _run(self, host: int, n_steps: int) -> None:
        xfa.set_thread_group(f"host{host}")
        for step in range(n_steps):
            if self._kill[host].is_set():
                return
            t0 = time.monotonic()
            if host in self.delays:
                time.sleep(self.delays[host])
            self.step_fn(host, step)
            self.monitor.beat(host)
            self.detector.observe(host, (time.monotonic() - t0) * 1e3)

    def start(self, n_steps: int) -> None:
        self._threads = [
            threading.Thread(target=self._run, args=(h, n_steps),
                             daemon=True, name=f"host-{h}")
            for h in range(self.n_hosts)]
        for t in self._threads:
            t.start()

    def kill(self, host: int) -> None:
        self._kill[host].set()
        self.monitor.inject_failure(host)

    def join(self, timeout: float = 30.0) -> None:
        for t in self._threads:
            t.join(timeout=timeout)
