"""Flash-decode (split-K) + positioned-chunk attention — Pallas TPU kernels.

Decode attention is memory-bound: one query row vs a [S, D] KV cache. The
kernel streams KV blocks through VMEM with the online-softmax carried in
scratch (grid kv dim 'arbitrary'), never materializing the [S] score row in
HBM. The q "row" is padded to 8 sublanes to satisfy TPU tiling; all q-heads
of one kv-head are processed together so GQA reuses each KV block g times
from VMEM (arithmetic intensity ×g).

Distributed split-K happens ABOVE the kernel: parallel/context.py shards S
across the mesh, each shard runs this kernel with return-style (o, m, l)
residuals computed from its local range, and the partials merge with
ref.combine_decode_partials after one small all-gather.

`chunk_attention` generalizes the same streaming structure from one query
row to a T-token chunk at per-row cache offsets (in-model chunked prefill):
the mask becomes OFFSET-CAUSAL — query t of batch row b sees cache columns
<= pos[b] + t — and the per-row early exit skips KV blocks past
pos[b] + T, so a slot resuming at depth 40 never streams its neighbour's
32k-deep cache.  T == 1 with kv_len = pos + 1 is exactly decode attention.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params

NEG_INF = -1e30
LANES = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
                   acc_ref, m_ref, l_ref, *,
                   sm_scale: float, block_k: int, num_kv_blocks: int,
                   with_residuals: bool):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]  # [1]-blocked per batch row (SMEM scalar)

    # per-row early exit: this row is done once ik*block_k passes ITS
    # length — other rows of the same call keep streaming their blocks
    @pl.when(ik * block_k < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)                  # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G, BK]
        cols = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)
        if with_residuals:
            m_out_ref[0, 0] = m_ref[...].astype(m_out_ref.dtype)
            l_out_ref[0, 0] = l_ref[...].astype(l_out_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     kv_len: Optional[jax.Array] = None,
                     sm_scale: Optional[float] = None,
                     block_k: int = 512, interpret: bool = False,
                     return_residuals: bool = False):
    """q: [B, Hq, D]; k, v: [B, Hkv, S, D] -> [B, Hq, D].

    kv_len: [B] int32 PER-ROW valid lengths (None = full S).  Under
    continuous batching every serving slot decodes at its own depth, so
    rows of one call carry arbitrary mixed lengths: the kernel reads each
    row's length from SMEM, skips whole KV blocks past it (`pl.when` on
    the arbitrary grid dim — a row at depth 100 does not pay for a
    neighbour at 32k), and masks the partial block with a per-column
    iota compare.  A fully-masked row (kv_len == 0, e.g. an empty pool
    slot) short-circuits every block; the l == 0 guard in _finalize
    yields zeros instead of 0/0 NaNs.  return_residuals=True additionally
    returns (m, l): [B, Hq] for distributed split-K merge."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    scale = sm_scale if sm_scale is not None else D ** -0.5
    if kv_len is None:
        kv_len = jnp.full((B,), S, jnp.int32)

    # group q heads by kv head: [B, Hkv, G, D]
    qg = q.reshape(B, Hkv, g, D)

    kernel = functools.partial(
        _decode_kernel, sm_scale=scale, block_k=block_k, num_kv_blocks=nk,
        with_residuals=return_residuals)

    out_shapes = [
        jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        jax.ShapeDtypeStruct((B, Hkv, g, LANES), jnp.float32),
        jax.ShapeDtypeStruct((B, Hkv, g, LANES), jnp.float32),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, g, D), lambda b, h, ik: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, g, LANES), lambda b, h, ik: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, g, LANES), lambda b, h, ik: (b, h, 0, 0)),
    ]

    o, m, l = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((g, D), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="xfa_decode_attention",
    )(kv_len, qg, k, v)

    o = o.reshape(B, Hq, D)
    if return_residuals:
        return o, (m[..., 0].reshape(B, Hq), l[..., 0].reshape(B, Hq))
    return o


def _chunk_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, sm_scale: float, block_k: int, num_kv_blocks: int,
                  chunk: int):
    """Offset-causal flash over the cache for one (batch, kv-head) pair.

    q block is [G*T, D] — all q heads of the kv head × the whole chunk —
    laid out (g, t) row-major so row r's query index is r % T; its column
    limit is pos + r % T (the row's own absolute position)."""
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]  # [1]-blocked per batch row (SMEM scalar)

    # per-row early exit: no query of this chunk reaches past pos + T - 1
    @pl.when(ik * block_k < pos + chunk)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # [G*T, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)                  # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos + rows % chunk, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def _decode_paged_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *,
                         sm_scale: float, page_size: int, num_pages: int):
    """Paged flash-decode body: identical online softmax to _decode_kernel,
    but the KV grid dimension walks BLOCK-TABLE SLOTS — the BlockSpec
    index map already dereferenced bt_ref[b, ik] (scalar prefetch), so
    k_ref/v_ref hold page `block_table[b, ik]` of the arena.  Ungranted
    slots point at the reserved scratch page 0; the kv_len column mask
    gives those columns exactly-zero softmax mass."""
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[b]

    @pl.when(ik * page_size < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [PS, D]
        v = v_ref[0, 0].astype(jnp.float32)                  # [PS, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = ik * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == num_pages - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def decode_attention_paged(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, *, block_table: jax.Array,
                           kv_len: jax.Array,
                           sm_scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q: [B, Hq, D]; k_pages, v_pages: [P, Hkv, page_size, D] arena;
    block_table: [B, NB] int32 page ids; kv_len: [B] -> [B, Hq, D].

    The block table and per-row lengths ride as SCALAR-PREFETCH operands
    (pltpu.PrefetchScalarGridSpec): they are resident before the body
    runs, so the k/v BlockSpec index maps dereference bt_ref[b, ik] to
    DMA exactly the page each (row, kv-slot) grid point needs — the
    kernel streams a slot's own pages and nothing else, and a row at
    depth 100 never touches a neighbour's 32k-deep allocation.  The
    per-row early exit additionally skips whole slots past kv_len (the
    scratch-page fetch for those slots is dead DMA, never compute)."""
    B, Hq, D = q.shape
    P, Hkv, ps, _ = k_pages.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    NB = block_table.shape[1]
    scale = sm_scale if sm_scale is not None else D ** -0.5

    qg = q.reshape(B, Hkv, g, D)
    kernel = functools.partial(
        _decode_paged_kernel, sm_scale=scale, page_size=ps, num_pages=NB)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, NB),
        in_specs=[
            pl.BlockSpec((1, 1, g, D),
                         lambda b, h, ik, len_ref, bt_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, ik, len_ref, bt_ref:
                         (bt_ref[b, ik], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, ik, len_ref, bt_ref:
                         (bt_ref[b, ik], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, D), lambda b, h, ik, len_ref, bt_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, D), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="xfa_decode_attention_paged",
    )(jnp.asarray(kv_len, jnp.int32), jnp.asarray(block_table, jnp.int32),
      qg, k_pages, v_pages)
    return o.reshape(B, Hq, D)


def _chunk_paged_kernel(pos_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, *,
                        sm_scale: float, page_size: int, num_pages: int,
                        chunk: int):
    """Paged offset-causal chunk body (see _chunk_kernel): q rows are
    (g, t) row-major, column limit pos + r % chunk; the KV grid walks
    block-table slots with the page id prefetched into the BlockSpec."""
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]

    # per-row early exit: no query of this chunk reaches past pos + T - 1
    @pl.when(ik * page_size < pos + chunk)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # [G*T, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [PS, D]
        v = v_ref[0, 0].astype(jnp.float32)                  # [PS, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = ik * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos + rows % chunk, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == num_pages - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def chunk_attention_paged(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, *, block_table: jax.Array,
                          pos: jax.Array, sm_scale: Optional[float] = None,
                          interpret: bool = False) -> jax.Array:
    """q: [B, Hq, T, D] chunk queries; k_pages, v_pages:
    [P, Hkv, page_size, D] arena; block_table: [B, NB]; pos: [B]
    -> [B, Hq, T, D].

    The paged generalization of chunk_attention: the chunk's own K/V was
    already scattered through the block table at virtual rows
    [pos, pos+T), and query t of row b attends virtual columns
    <= pos[b] + t.  Block-table slots are this kernel's KV blocks —
    slots past a row's pos + T early-exit exactly like dense KV blocks
    do, so the mixed-depth serving property is preserved page-granular."""
    B, Hq, T, D = q.shape
    P, Hkv, ps, _ = k_pages.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    NB = block_table.shape[1]
    scale = sm_scale if sm_scale is not None else D ** -0.5

    qg = q.reshape(B, Hkv, g, T, D).reshape(B, Hkv, g * T, D)
    kernel = functools.partial(
        _chunk_paged_kernel, sm_scale=scale, page_size=ps, num_pages=NB,
        chunk=T)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, NB),
        in_specs=[
            pl.BlockSpec((1, 1, g * T, D),
                         lambda b, h, ik, pos_ref, bt_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, ik, pos_ref, bt_ref:
                         (bt_ref[b, ik], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, ik, pos_ref, bt_ref:
                         (bt_ref[b, ik], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g * T, D),
            lambda b, h, ik, pos_ref, bt_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * T, D), jnp.float32),
            pltpu.VMEM((g * T, LANES), jnp.float32),
            pltpu.VMEM((g * T, LANES), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g * T, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="xfa_chunk_attention_paged",
    )(jnp.asarray(pos, jnp.int32), jnp.asarray(block_table, jnp.int32),
      qg, k_pages, v_pages)
    return o.reshape(B, Hq, T, D)


def chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    pos: jax.Array, sm_scale: Optional[float] = None,
                    block_k: int = 512, interpret: bool = False):
    """q: [B, Hq, T, D] chunk queries; k, v: [B, Hkv, S, D] full cache;
    pos: [B] int32 per-row offsets -> [B, Hq, T, D].

    Query t of row b attends cache columns <= pos[b] + t — the
    offset-causal mask of in-model chunked prefill: the chunk's own K/V
    was just scattered at [pos, pos+T) and everything before pos is prior
    cache content, so one compiled call serves serving slots resuming
    their prompts at arbitrary mixed depths."""
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    scale = sm_scale if sm_scale is not None else D ** -0.5
    pos = jnp.asarray(pos, jnp.int32)

    # group q heads by kv head and flatten (g, T) into kernel rows
    qg = q.reshape(B, Hkv, g, T, D).reshape(B, Hkv, g * T, D)

    kernel = functools.partial(
        _chunk_kernel, sm_scale=scale, block_k=block_k, num_kv_blocks=nk,
        chunk=T)

    o = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g * T, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * T, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g * T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * T, D), jnp.float32),
            pltpu.VMEM((g * T, LANES), jnp.float32),
            pltpu.VMEM((g * T, LANES), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="xfa_chunk_attention",
    )(pos, qg, k, v)

    return o.reshape(B, Hq, T, D)
