"""jit'd public wrappers for the Pallas kernels, with impl dispatch.

impl='auto'   -> Pallas kernel on TPU, pure-jnp reference elsewhere (CPU CI)
impl='pallas' -> Pallas kernel (interpret=True off-TPU: Python-executed, used
                 by the allclose test sweeps)
impl='ref'    -> pure-jnp oracle (ref.py)

Every wrapper registers its analytic FLOPs/bytes with the XFA static-cost
layer (core.device_fold.annotate_cost) under the component that calls it —
kernels are cross-flow callees like any library API in the paper.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.device_fold import annotate_cost
from repro.core import tracer as xfa

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import mamba_scan as _ssd
from . import ref
from . import rmsnorm as _rms


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def _bytes(*arrs) -> float:
    return float(sum(a.size * a.dtype.itemsize for a in arrs))


def attention(q, k, v, *, causal: bool = True,
              sm_scale: Optional[float] = None, logit_softcap: float = 0.0,
              impl: str = "auto", interpret: Optional[bool] = None,
              component: str = "attention") -> jax.Array:
    B, Hq, Sq, D = q.shape
    Sk = k.shape[2]
    flops = 4.0 * B * Hq * Sq * Sk * D * (0.5 if causal and Sq == Sk else 1.0)
    annotate_cost(xfa.current_component(), component, "flash_attention",
                  flops=flops, bytes=_bytes(q, k, v) * 2)
    mode = _resolve(impl)
    if mode == "ref":
        return ref.attention(q, k, v, causal=causal, sm_scale=sm_scale,
                             logit_softcap=logit_softcap,
                             q_offset=Sk - Sq if causal else 0)
    if mode == "chunked":
        # flash-pattern jnp path: used by the dry-run (Mosaic cannot lower on
        # the CPU backend) — same FLOPs/live-memory shape as the kernel
        return ref.attention_chunked(q, k, v, causal=causal,
                                     sm_scale=sm_scale,
                                     logit_softcap=logit_softcap,
                                     q_offset=Sk - Sq if causal else 0)
    itp = (not _on_tpu()) if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                               logit_softcap=logit_softcap, interpret=itp)


def decode_attention(q, k, v, *, kv_len=None, sm_scale=None,
                     impl: str = "auto", interpret: Optional[bool] = None,
                     return_residuals: bool = False,
                     component: str = "attention"):
    B, Hq, D = q.shape
    S = k.shape[2]
    annotate_cost(xfa.current_component(), component, "decode_attention",
                  flops=4.0 * B * Hq * S * D, bytes=_bytes(k, v))
    mode = _resolve(impl)
    if mode in ("ref", "chunked"):
        return ref.decode_attention(q, k, v, kv_len=kv_len, sm_scale=sm_scale,
                                    return_residuals=return_residuals)
    itp = (not _on_tpu()) if interpret is None else interpret
    return _dec.decode_attention(q, k, v, kv_len=kv_len, sm_scale=sm_scale,
                                 interpret=itp,
                                 return_residuals=return_residuals)


def chunk_attention(q, k, v, *, pos, sm_scale=None, impl: str = "auto",
                    interpret: Optional[bool] = None,
                    component: str = "attention") -> jax.Array:
    """Positioned-chunk attention: q [B, Hq, T, D] at per-row cache
    offsets pos [B]; k, v [B, Hkv, S, D] the full cache (this chunk's
    rows already scattered at [pos, pos+T)).  Query t of row b attends
    columns <= pos[b] + t — the offset-causal mask that makes prefill and
    decode the same operation at different widths."""
    B, Hq, T, D = q.shape
    S = k.shape[2]
    annotate_cost(xfa.current_component(), component, "chunk_attention",
                  flops=4.0 * B * Hq * T * S * D, bytes=_bytes(k, v))
    mode = _resolve(impl)
    if mode == "ref":
        return ref.chunk_attention(q, k, v, pos=pos, sm_scale=sm_scale)
    if mode == "chunked":
        # flash-pattern jnp path for the dry-run: O(T·block_k) live scores,
        # same footprint shape as the kernel
        return ref.chunk_attention_blocked(q, k, v, pos=pos,
                                           sm_scale=sm_scale)
    itp = (not _on_tpu()) if interpret is None else interpret
    return _dec.chunk_attention(q, k, v, pos=pos, sm_scale=sm_scale,
                                interpret=itp)


def decode_attention_paged(q, k_pages, v_pages, *, block_table, kv_len,
                           sm_scale=None, impl: str = "auto",
                           interpret: Optional[bool] = None,
                           component: str = "attention") -> jax.Array:
    """Paged single-token decode: q [B, Hq, D] against a page arena
    k_pages/v_pages [P, Hkv, page_size, D] addressed through block_table
    [B, NB] (int32 page ids; unassigned slots point at the reserved
    scratch page 0 and are masked by kv_len [B])."""
    B, Hq, D = q.shape
    P, _, ps, _ = k_pages.shape
    NB = block_table.shape[1]
    # cost model charges the VISIBLE prefix, not the arena: each row
    # streams at most NB pages of its own table
    annotate_cost(xfa.current_component(), component, "decode_attention_paged",
                  flops=4.0 * B * Hq * NB * ps * D,
                  bytes=2.0 * B * NB * ps * D * k_pages.dtype.itemsize)
    mode = _resolve(impl)
    if mode in ("ref", "chunked"):
        return ref.decode_attention_paged(q, k_pages, v_pages,
                                          block_table=block_table,
                                          kv_len=kv_len, sm_scale=sm_scale)
    itp = (not _on_tpu()) if interpret is None else interpret
    return _dec.decode_attention_paged(q, k_pages, v_pages,
                                       block_table=block_table,
                                       kv_len=kv_len, sm_scale=sm_scale,
                                       interpret=itp)


def chunk_attention_paged(q, k_pages, v_pages, *, block_table, pos,
                          sm_scale=None, impl: str = "auto",
                          interpret: Optional[bool] = None,
                          component: str = "attention") -> jax.Array:
    """Paged positioned-chunk attention: q [B, Hq, T, D] at per-row
    offsets pos [B]; KV lives in the page arena [P, Hkv, page_size, D]
    and each row's visible prefix is gathered through block_table
    [B, NB].  Same offset-causal mask as chunk_attention — the paged
    pool changes where rows live, never what a query sees."""
    B, Hq, T, D = q.shape
    P, _, ps, _ = k_pages.shape
    NB = block_table.shape[1]
    annotate_cost(xfa.current_component(), component, "chunk_attention_paged",
                  flops=4.0 * B * Hq * T * NB * ps * D,
                  bytes=2.0 * B * NB * ps * D * k_pages.dtype.itemsize)
    mode = _resolve(impl)
    if mode == "ref":
        return ref.chunk_attention_paged(q, k_pages, v_pages,
                                         block_table=block_table,
                                         pos=pos, sm_scale=sm_scale)
    if mode == "chunked":
        # blocked-jnp dry-run path: one page of live scores at a time,
        # same footprint shape as the Pallas kernel
        return ref.chunk_attention_paged_blocked(q, k_pages, v_pages,
                                                 block_table=block_table,
                                                 pos=pos, sm_scale=sm_scale)
    itp = (not _on_tpu()) if interpret is None else interpret
    return _dec.chunk_attention_paged(q, k_pages, v_pages,
                                      block_table=block_table,
                                      pos=pos, sm_scale=sm_scale,
                                      interpret=itp)


def rmsnorm(x, w, *, eps: float = 1e-5, impl: str = "auto",
            interpret: Optional[bool] = None,
            component: str = "norm") -> jax.Array:
    annotate_cost(xfa.current_component(), component, "rmsnorm",
                  flops=4.0 * x.size, bytes=2.0 * _bytes(x))
    mode = _resolve(impl)
    if mode in ("ref", "chunked"):
        return ref.rmsnorm(x, w, eps=eps)
    itp = (not _on_tpu()) if interpret is None else interpret
    return _rms.rmsnorm(x, w, eps=eps, interpret=itp)


def rmsnorm_add(x, residual, w, *, eps: float = 1e-5, impl: str = "auto",
                interpret: Optional[bool] = None, component: str = "norm"):
    annotate_cost(xfa.current_component(), component, "rmsnorm_add",
                  flops=5.0 * x.size, bytes=3.0 * _bytes(x))
    mode = _resolve(impl)
    if mode in ("ref", "chunked"):
        s = x + residual
        return ref.rmsnorm(s, w, eps=eps), s
    itp = (not _on_tpu()) if interpret is None else interpret
    return _rms.rmsnorm_add(x, residual, w, eps=eps, interpret=itp)


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, h0=None,
             impl: str = "auto", interpret: Optional[bool] = None,
             component: str = "ssm"):
    """Mamba2 SSD: x [B,L,H,P], dt [B,L,H], a [H], b/c [B,L,N];
    h0 [B,H,N,P] carried state (None = fresh sequence) — chunked prefill
    resumes the recurrence exactly where the previous chunk stopped.
    Returns (y [B,L,H,P], h_final [B,H,N,P])."""
    B, L, H, P = x.shape
    N = b.shape[-1]
    # 2 matmul pairs of [T,T]x[T,*] per chunk ~ 6*B*H*L*chunk*(N+P) flops
    annotate_cost(xfa.current_component(), component, "ssd_scan",
                  flops=float(6 * B * H * L * chunk * (N + P)),
                  bytes=_bytes(x, dt, b, c) * 2)
    mode = _resolve(impl)
    # pad L to a chunk multiple: dt=0 rows decay by exp(0)=1 and inject 0,
    # so state and valid outputs are untouched
    pad = (-L) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, pad if i == 1 else 0)
                                   for i in range(a.ndim)])
        x, dt, b, c = zp(x), zp(dt), zp(b), zp(c)
    if mode in ("ref", "chunked"):
        y, h = ref.ssd_chunked(x, dt, a, b, c, chunk=chunk, h0=h0)
    else:
        itp = (not _on_tpu()) if interpret is None else interpret
        dtf = dt.astype(jnp.float32)
        dtx = (dtf[..., None] * x.astype(jnp.float32)).astype(x.dtype)
        ldec = a.astype(jnp.float32)[None, None, :] * dtf    # [B, L, H]
        # to head-major layout for plain-slice BlockSpecs
        dtx = jnp.moveaxis(dtx, 2, 1)                        # [B, H, L, P]
        ldec = jnp.moveaxis(ldec, 2, 1)                      # [B, H, L]
        y, h = _ssd.ssd_scan(dtx, ldec, b, c, chunk=chunk, h0=h0,
                             interpret=itp)
        y = jnp.moveaxis(y, 1, 2)
    if pad:
        y = y[:, :L]
    return y, h
