"""Fleet profile plane: transport framing, publisher/collector spool,
fault paths, host-qualified identity, and `diagnose --fleet`.

The transport's whole contract is fault tolerance: deltas only, resume
from the collector's ack state, rejects on checksum mismatch, and a
spool that never holds a torn file.  These tests exercise each clause
in-process (scripted sockets against a live threaded collector) and
then end-to-end as three real OS processes (2 publishers + 1 collector
-> merge -> diagnose --fleet flags the injected straggler host).
"""

import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time

import pytest

from repro.core.folding import fold_event_log
from repro.profile import (Collector, FleetPublisher, ProfileStore,
                           RetentionPolicy, RunRegistry, frame_checksum,
                           parse_addr, recv_frame, register_run, send_frame,
                           set_host_label)
from repro.profile.transport import PROTO_VERSION, Disconnect, FrameError

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

EVENTS = [
    ("app", "runtime", "step", 3_000_000),
    ("app", "runtime", "step", 3_000_000),
    ("app", "io", "load", 1_000_000),
    ("moe", "pthread", "lock", 500_000),
]


@pytest.fixture(autouse=True)
def _reset_host_label():
    yield
    set_host_label(None)


def build_ring(run_dir, host, n=3, scale=1.0, label="trainer"):
    """A registered run dir with an n-deep ring written as `host`."""
    set_host_label(host)
    register_run(str(run_dir), config="fleetcfg", kind="train", label=host)
    store = ProfileStore(str(run_dir))
    table = fold_event_log(EVENTS).scale_time(scale)
    for _ in range(n):
        store.write_shard(table, label=label)
    set_host_label(None)
    return store


def spool_files(spool):
    out = []
    for root, _dirs, files in os.walk(str(spool)):
        out.extend(os.path.join(root, f) for f in files)
    return sorted(out)


# -- wire framing ----------------------------------------------------------

class TestFraming:
    def test_roundtrip(self):
        a, b = socket.socketpair()
        try:
            payload = b"x" * 1000
            send_frame(a, {"type": "snapshot", "seq": 7}, payload)
            header, got = recv_frame(b)
            assert header["type"] == "snapshot"
            assert header["seq"] == 7
            assert header["length"] == len(payload)
            assert header["sha256"] == frame_checksum(payload)
            assert got == payload
        finally:
            a.close()
            b.close()

    def test_empty_payload_frame(self):
        a, b = socket.socketpair()
        try:
            send_frame(a, {"type": "bye"})
            header, got = recv_frame(b)
            assert header == {"type": "bye", "length": 0}
            assert got == b""
        finally:
            a.close()
            b.close()

    def test_eof_between_frames_is_disconnect(self):
        a, b = socket.socketpair()
        a.close()
        try:
            with pytest.raises(Disconnect):
                recv_frame(b)
        finally:
            b.close()

    def test_eof_mid_frame_is_disconnect(self):
        a, b = socket.socketpair()
        try:
            raw = json.dumps({"type": "snapshot", "length": 100}).encode()
            import struct
            a.sendall(struct.pack("!I", len(raw)) + raw + b"only-20-bytes")
            a.close()
            with pytest.raises(Disconnect):
                recv_frame(b)
        finally:
            b.close()

    def test_oversized_payload_is_frame_error(self):
        a, b = socket.socketpair()
        try:
            send_frame(a, {"type": "snapshot", "length": 1 << 30})
            with pytest.raises(FrameError):
                recv_frame(b, max_bytes=1 << 20)
        finally:
            a.close()
            b.close()

    def test_headerless_garbage_is_frame_error(self):
        a, b = socket.socketpair()
        try:
            import struct
            a.sendall(struct.pack("!I", 4) + b"not{")
            with pytest.raises(FrameError):
                recv_frame(b)
        finally:
            a.close()
            b.close()

    def test_parse_addr(self):
        assert parse_addr("127.0.0.1:9000") == ("127.0.0.1", 9000)
        with pytest.raises(ValueError):
            parse_addr("no-port")
        with pytest.raises(ValueError):
            parse_addr(":9000")


# -- publisher <-> collector ----------------------------------------------

class TestPublishSpool:
    def test_round_trip_spool_bytes_identical(self, tmp_path):
        store = build_ring(tmp_path / "runA", "hosta", n=3)
        with Collector(str(tmp_path / "spool")) as col:
            pub = FleetPublisher("127.0.0.1:%d" % col.port,
                                 str(tmp_path / "runA"), run_id="runX",
                                 host="hosta")
            stats = pub.publish()
            pub.close()
        assert stats["shipped"] == 3 and stats["errors"] == 0
        for stem, ring in store.shards().items():
            for seq, path in ring:
                spooled = os.path.join(str(tmp_path / "spool"), "runX",
                                       "hosta", os.path.basename(path))
                with open(path, "rb") as f_local, \
                        open(spooled, "rb") as f_spool:
                    assert f_local.read() == f_spool.read(), (stem, seq)
        # the manifest was shipped too and the spool is a registered run
        assert os.path.exists(
            os.path.join(str(tmp_path / "spool"), "runX", "manifest.json"))

    def test_second_publish_ships_nothing(self, tmp_path):
        store = build_ring(tmp_path / "runA", "hosta", n=2)
        with Collector(str(tmp_path / "spool")) as col:
            pub = FleetPublisher("127.0.0.1:%d" % col.port,
                                 str(tmp_path / "runA"), run_id="runX",
                                 host="hosta")
            assert pub.publish()["shipped"] == 2
            assert pub.publish()["shipped"] == 0          # delta semantics
            store.write_shard(fold_event_log(EVENTS), label="trainer")
            s = pub.publish()
            pub.close()
        assert s["shipped"] == 1                          # only the new seq

    def test_reconnect_resumes_from_ack_state(self, tmp_path):
        store = build_ring(tmp_path / "runA", "hosta", n=2)
        spool = str(tmp_path / "spool")
        with Collector(spool) as col:
            pub = FleetPublisher("127.0.0.1:%d" % col.port,
                                 str(tmp_path / "runA"), run_id="runX",
                                 host="hosta")
            assert pub.publish()["shipped"] == 2
            pub.close()
        # collector restarted: a FRESH publisher (no client-side state)
        # must learn the resume point from the spool-rebuilt ack state
        store.write_shard(fold_event_log(EVENTS), label="trainer")
        with Collector(spool) as col2:
            pub2 = FleetPublisher("127.0.0.1:%d" % col2.port,
                                  str(tmp_path / "runA"), run_id="runX",
                                  host="hosta")
            s = pub2.publish()
            pub2.close()
        assert s["shipped"] == 1, s      # unacked suffix only, no re-ship
        names = [os.path.basename(p) for p in
                 spool_files(os.path.join(spool, "runX"))]
        assert len([n for n in names if n.endswith(".xfa.npz")]) == 3

    def test_dead_collector_degrades_not_raises(self, tmp_path):
        build_ring(tmp_path / "runA", "hosta", n=2)
        col = Collector(str(tmp_path / "spool"))
        port = col.port
        col.start()
        col.shutdown()                       # nobody listening anymore
        pub = FleetPublisher("127.0.0.1:%d" % port, str(tmp_path / "runA"),
                             run_id="runX", host="hosta", timeout=1.0,
                             retry_interval_s=0.0)
        stats = pub.publish()                # must NOT raise
        assert stats["errors"] == 1
        assert stats["pending"] == 2
        assert pub.last_error

    def test_checksum_mismatch_rejected_and_spool_untorn(self, tmp_path):
        spool = str(tmp_path / "spool")
        with Collector(spool) as col:
            sock = socket.create_connection(("127.0.0.1", col.port),
                                            timeout=5.0)
            sock.settimeout(5.0)
            send_frame(sock, {"type": "hello", "proto": PROTO_VERSION,
                              "run_id": "runX", "host": "hosta"})
            header, _ = recv_frame(sock)
            assert header["type"] == "ack_state"
            payload = b"corrupted-on-the-wire"
            send_frame(sock, {"type": "snapshot", "run_id": "runX",
                              "host": "hosta", "shard": "rank0", "seq": 1,
                              "length": len(payload),
                              "sha256": "0" * 64}, payload)
            reply, _ = recv_frame(sock)
            assert reply["type"] == "reject"
            # nothing spooled, not even a tmp file
            assert spool_files(os.path.join(spool, "runX")) == []
            # the re-sent (correct) frame is acked and lands atomically
            send_frame(sock, {"type": "snapshot", "run_id": "runX",
                              "host": "hosta", "shard": "rank0", "seq": 1},
                       payload)
            reply, _ = recv_frame(sock)
            assert reply["type"] == "ack" and not reply["dedup"]
            send_frame(sock, {"type": "bye"})
            sock.close()
        files = spool_files(os.path.join(spool, "runX"))
        assert [os.path.basename(p) for p in files] == \
            ["rank0.000001.xfa.npz"]
        with open(files[0], "rb") as f:
            assert f.read() == payload

    def test_publisher_resends_once_after_reject(self, tmp_path):
        build_ring(tmp_path / "runA", "hosta", n=1)
        a, b = socket.socketpair()
        a.settimeout(5.0)
        b.settimeout(5.0)
        seen = []

        def fake_collector():
            h1, p1 = recv_frame(b)
            seen.append((h1, p1))
            send_frame(b, {"type": "reject", "shard": h1["shard"],
                           "seq": h1["seq"], "reason": "checksum"})
            h2, p2 = recv_frame(b)
            seen.append((h2, p2))
            send_frame(b, {"type": "ack", "shard": h2["shard"],
                           "seq": h2["seq"], "dedup": False})

        t = threading.Thread(target=fake_collector)
        t.start()
        pub = FleetPublisher("127.0.0.1:1", str(tmp_path / "runA"),
                             run_id="runX", host="hosta")
        try:
            ok = pub._ship_one(a, {"type": "snapshot", "run_id": "runX",
                                   "host": "hosta", "shard": "rank0",
                                   "seq": 1}, b"payload", "rank0 seq 1")
        finally:
            t.join(timeout=5.0)
            a.close()
            b.close()
        assert ok
        assert len(seen) == 2                     # exactly one re-send
        assert seen[0][1] == seen[1][1] == b"payload"

    def test_mid_frame_disconnect_leaves_collector_healthy(self, tmp_path):
        spool = str(tmp_path / "spool")
        with Collector(spool) as col:
            sock = socket.create_connection(("127.0.0.1", col.port),
                                            timeout=5.0)
            send_frame(sock, {"type": "hello", "proto": PROTO_VERSION,
                              "run_id": "runX", "host": "hosta"})
            recv_frame(sock)
            # half a snapshot frame, then vanish
            import struct
            raw = json.dumps({"type": "snapshot", "run_id": "runX",
                              "host": "hosta", "shard": "rank0", "seq": 1,
                              "length": 10_000,
                              "sha256": "0" * 64}).encode()
            sock.sendall(struct.pack("!I", len(raw)) + raw + b"torn")
            sock.close()
            time.sleep(0.2)
            # no torn file, and the collector still serves new sessions
            assert spool_files(os.path.join(spool, "runX")) == []
            build_ring(tmp_path / "runA", "hosta", n=1)
            pub = FleetPublisher("127.0.0.1:%d" % col.port,
                                 str(tmp_path / "runA"), run_id="runX",
                                 host="hosta")
            assert pub.publish()["shipped"] == 1
            pub.close()

    def test_path_escaping_identity_is_rejected(self, tmp_path):
        with Collector(str(tmp_path / "spool")) as col:
            sock = socket.create_connection(("127.0.0.1", col.port),
                                            timeout=5.0)
            sock.settimeout(5.0)
            send_frame(sock, {"type": "hello", "proto": PROTO_VERSION,
                              "run_id": "..", "host": "hosta"})
            reply, _ = recv_frame(sock)
            assert reply["type"] == "error"
            sock.close()
        assert spool_files(str(tmp_path / "spool")) == []


# -- host-qualified identity ----------------------------------------------

class TestHostIdentity:
    def test_same_shard_name_from_two_hosts_never_aliases(self, tmp_path):
        spool = str(tmp_path / "spool")
        blob_a = b"host-a-bytes"
        blob_b = b"host-b-bytes-different"
        with Collector(spool) as col:
            for host, blob in (("hosta", blob_a), ("hostb", blob_b)):
                sock = socket.create_connection(("127.0.0.1", col.port),
                                                timeout=5.0)
                sock.settimeout(5.0)
                send_frame(sock, {"type": "hello", "proto": PROTO_VERSION,
                                  "run_id": "runX", "host": host})
                recv_frame(sock)
                send_frame(sock, {"type": "snapshot", "run_id": "runX",
                                  "host": host, "shard": "rank0", "seq": 1},
                           blob)
                reply, _ = recv_frame(sock)
                assert reply["type"] == "ack"
                sock.close()
        run_dir = os.path.join(spool, "runX")
        stems = sorted(ProfileStore(run_dir).shards())
        assert stems == ["hosta/rank0", "hostb/rank0"]

    def test_writers_record_host_label(self, tmp_path):
        build_ring(tmp_path / "runA", "hostq", n=1)
        from repro.profile import RunManifest
        m = RunManifest.load(str(tmp_path / "runA"))
        assert [w["host"] for w in m.writers] == ["hostq"]
        stems = list(ProfileStore(str(tmp_path / "runA")).shards())
        assert len(stems) == 1 and "-hostq-" in stems[0]

    def test_stem_host_parsing(self):
        from repro.analysis import stem_host
        assert stem_host("hosta/trainer-x") == "hosta"
        assert stem_host("trainer-hostb-123") == "hostb"
        assert stem_host("plain", {"host": "hc"}) == "hc"
        assert stem_host("plain") == "-"

    def test_host_graphs_merge_per_host(self, tmp_path):
        build_ring(tmp_path / "runA", "hosta", n=1, label="r0")
        build_ring(tmp_path / "runA", "hosta", n=1, label="r1")
        build_ring(tmp_path / "runA", "hostb", n=1, scale=2.0, label="r0")
        from repro.analysis import host_graphs
        hg = host_graphs(str(tmp_path / "runA"))
        assert sorted(hg) == ["hosta", "hostb"]
        one = fold_event_log(EVENTS).total_ns()
        assert hg["hosta"].total_ns() == 2 * one      # two ranks merged
        assert hg["hostb"].total_ns() == 2 * one      # one rank, scaled 2x


# -- registry concurrency + gc on the spool --------------------------------

class TestRegistryAndGC:
    def test_query_tolerates_run_vanishing_mid_scan(self, tmp_path,
                                                    monkeypatch):
        register_run(str(tmp_path / "a"), config="cfg")
        ghost = str(tmp_path / "ghost")      # listed, but manifest gone
        monkeypatch.setattr(
            RunRegistry, "run_dirs",
            lambda self: [str(tmp_path / "a"), ghost])
        runs = RunRegistry(str(tmp_path)).runs()      # must not raise
        assert [m.run_id for m in runs] == ["a"]

    def test_query_skips_corrupt_manifest_with_warning(self, tmp_path):
        register_run(str(tmp_path / "a"), config="cfg")
        os.makedirs(str(tmp_path / "b"))
        with open(str(tmp_path / "b" / "manifest.json"), "w") as f:
            f.write("{torn")
        with pytest.warns(UserWarning, match="unreadable manifest"):
            runs = RunRegistry(str(tmp_path)).runs()
        assert [m.run_id for m in runs] == ["a"]

    def test_gc_honors_spool_layout(self, tmp_path):
        spool = str(tmp_path / "spool")
        with Collector(spool) as col:
            for host in ("hosta", "hostb"):
                run = tmp_path / ("local_" + host)
                build_ring(run, host, n=3)
                pub = FleetPublisher("127.0.0.1:%d" % col.port, str(run),
                                     run_id="runX", host=host)
                assert pub.publish()["shipped"] == 3
                pub.close()
        run_dir = os.path.join(spool, "runX")
        doomed = RetentionPolicy(keep_last=1).doomed(run_dir)
        # per host-qualified ring: 2 of 3 doomed, newest survives
        assert len(doomed) == 4
        by_stem = ProfileStore(run_dir).shards()
        for stem, ring in by_stem.items():
            newest = ring[-1][1]
            assert newest not in doomed, stem
        env = dict(os.environ,
                   PYTHONPATH=SRC + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        p = subprocess.run(
            [sys.executable, "-m", "repro.profile", "gc", spool,
             "--keep-last", "1"],
            capture_output=True, text=True, timeout=120, env=env)
        assert p.returncode == 0, p.stderr
        left = ProfileStore(run_dir).shards()
        assert sorted(left) == sorted(by_stem)
        assert all(len(ring) == 1 for ring in left.values())


# -- fleet diagnosis -------------------------------------------------------

class TestFleetDiagnosis:
    @pytest.fixture()
    def fleet_spool(self, tmp_path):
        """One spooled run, two hosts, hostb injected as a 3x straggler."""
        spool = str(tmp_path / "spool")
        with Collector(spool) as col:
            for host, scale in (("hosta", 1.0), ("hostb", 3.0)):
                run = tmp_path / ("local_" + host)
                build_ring(run, host, n=2, scale=scale)
                pub = FleetPublisher("127.0.0.1:%d" % col.port, str(run),
                                     run_id="runX", host=host)
                assert pub.publish()["errors"] == 0
                pub.close()
        return spool

    def test_straggler_host_is_top_finding(self, fleet_spool):
        from repro.analysis import diagnose_fleet
        fd = diagnose_fleet(fleet_spool)
        ranked = fd.ranked()
        assert ranked, "expected findings"
        run_id, top = ranked[0]
        assert run_id == "runX"
        assert top.detector == "fleet-straggler"
        assert top.severity == "crit"            # 3x vs mean 2x -> rel 0.5
        assert top.subject == "host:hostb"
        assert top.evidence["widest_component"] == "runtime"

    def test_json_groups_by_severity_detector_host(self, fleet_spool):
        from repro.analysis import diagnose_fleet
        doc = diagnose_fleet(fleet_spool).to_json()
        assert doc["runs"][0]["hosts"] == ["hosta", "hostb"]
        groups = doc["groups"]
        assert groups[0]["severity"] == "crit"
        assert groups[0]["detector"] == "fleet-straggler"
        assert groups[0]["host"] == "hostb"
        keys = [(g["severity"], g["detector"], g["host"]) for g in groups]
        assert len(set(keys)) == len(keys)        # one group per triple
        sev_rank = {"crit": 2, "warn": 1, "info": 0}
        assert keys == sorted(
            keys, key=lambda k: (-sev_rank[k[0]], k[1], k[2]))

    def test_single_run_dir_degrades_to_one_run_fleet(self, fleet_spool):
        from repro.analysis import diagnose_fleet
        fd = diagnose_fleet(os.path.join(fleet_spool, "runX"))
        assert len(fd.runs) == 1
        assert any(f.detector == "fleet-straggler"
                   for _r, f in fd.ranked())

    def test_config_filter_selects_runs(self, fleet_spool):
        from repro.analysis import diagnose_fleet
        fd = diagnose_fleet(fleet_spool, config="fleetcfg")
        assert len(fd.runs) == 1
        with pytest.raises(LookupError):
            diagnose_fleet(fleet_spool, config="no-such-config")

    def test_cli_flag_validation(self, tmp_path):
        env = dict(os.environ,
                   PYTHONPATH=SRC + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        p = subprocess.run(
            [sys.executable, "-m", "repro.profile", "diagnose",
             str(tmp_path), "--config", "x"],
            capture_output=True, text=True, timeout=120, env=env)
        assert p.returncode == 2
        assert "--fleet" in p.stderr


# -- three-process localhost e2e -------------------------------------------

PUBLISHER_SCRIPT = """
import sys
from repro.core.folding import fold_event_log
from repro.profile import (FleetPublisher, ProfileStore, register_run,
                           set_host_label)

addr, run_dir, host, scale = sys.argv[1:5]
set_host_label(host)
register_run(run_dir, config="fleetcfg", kind="train", label=host)
store = ProfileStore(run_dir)
EVENTS = [("app", "runtime", "step", 3_000_000)] * 2 + \\
         [("app", "io", "load", 1_000_000)]
table = fold_event_log(EVENTS).scale_time(float(scale))

pub = FleetPublisher(addr, run_dir, run_id="fleetrun", host=host)
for _ in range(2):
    store.write_shard(table, label="trainer")
    stats = pub.publish()
    assert stats["errors"] == 0, stats
pub.close()

# reconnect: a fresh publisher resumes from the collector's acked seqs
store.write_shard(table, label="trainer")
pub2 = FleetPublisher(addr, run_dir, run_id="fleetrun", host=host)
stats = pub2.publish()
assert stats["shipped"] == 1, ("resume re-shipped acked entries", stats)
pub2.close()
print("PUBLISHED", host, stats["shipped"])
"""


@pytest.mark.slow
class TestThreeProcessE2E:
    def test_two_publishers_one_collector(self, tmp_path):
        env = dict(os.environ,
                   PYTHONPATH=SRC + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        spool = str(tmp_path / "spool")
        col = subprocess.Popen(
            [sys.executable, "-m", "repro.profile", "collect",
             "--spool", spool, "--port", "0", "--max-seconds", "300",
             "--self-profile-interval-s", "120"],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env)
        try:
            line = col.stdout.readline()
            assert "collector listening on" in line, line
            addr = line.split()[3]            # HOST:PORT
            pubs = []
            for host, scale in (("hosta", "1.0"), ("hostb", "3.0")):
                run_dir = str(tmp_path / ("local_" + host))
                pubs.append((host, run_dir, subprocess.Popen(
                    [sys.executable, "-c", PUBLISHER_SCRIPT, addr,
                     run_dir, host, scale],
                    stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                    text=True, env=env)))
            for host, _run_dir, p in pubs:
                out, err = p.communicate(timeout=180)
                assert p.returncode == 0, (host, out, err)
                assert f"PUBLISHED {host} 1" in out

            # spool snapshots byte-identical to each publisher's ring
            run_dir_spool = os.path.join(spool, "fleetrun")
            for host, run_dir, _p in pubs:
                for _stem, ring in ProfileStore(run_dir).shards().items():
                    for _seq, path in ring:
                        spooled = os.path.join(run_dir_spool, host,
                                               os.path.basename(path))
                        with open(path, "rb") as fl, \
                                open(spooled, "rb") as fs:
                            assert fl.read() == fs.read(), spooled

            # the spool is a run the rest of the CLI understands: merge
            merged = str(tmp_path / "merged.xfa.npz")
            p = subprocess.run(
                [sys.executable, "-m", "repro.profile", "merge",
                 run_dir_spool, "-o", merged],
                capture_output=True, text=True, timeout=120, env=env)
            assert p.returncode == 0, p.stderr
            assert os.path.exists(merged)

            # ... and diagnose --fleet flags the injected straggler host
            p = subprocess.run(
                [sys.executable, "-m", "repro.profile", "diagnose", spool,
                 "--fleet", "--config", "fleetcfg", "--json"],
                capture_output=True, text=True, timeout=120, env=env)
            assert p.returncode == 0, p.stderr
            doc = json.loads(p.stdout)
            top = doc["groups"][0]
            assert top["severity"] == "crit"
            assert top["detector"] == "fleet-straggler"
            assert top["host"] == "hostb"
        finally:
            if col.poll() is None:
                col.send_signal(signal.SIGTERM)
            out, err = col.communicate(timeout=60)
        assert col.returncode == 0, (out, err)
        assert "collector stopped" in out
