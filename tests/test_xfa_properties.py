"""Property-based tests (hypothesis) on the XFA invariants.

The fold algebra is the paper's correctness core: Relation-Aware Data
Folding must lose nothing that the views need, no matter how the event
stream is split across threads/devices/time.
"""

import numpy as np
import pytest

# CI installs hypothesis (requirements.txt); environments without it skip
# this module instead of aborting the whole collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (FoldedTable, fold_event_log)
from repro.core.attribution import attribute_parallel
from repro.core.device_fold import DeviceFoldSpec
from repro.core.views import api_view, component_view

CALLERS = ("app", "moe", "optimizer")
COMPONENTS = ("glibc", "alloc", "pthread")
APIS = ("read", "write", "malloc", "lock")

event = st.tuples(st.sampled_from(CALLERS), st.sampled_from(COMPONENTS),
                  st.sampled_from(APIS), st.integers(1, 10_000))
events = st.lists(event, max_size=200)


def total_ns(t: FoldedTable) -> int:
    return sum(e.total_ns for e in t.edges.values())


def total_count(t: FoldedTable) -> int:
    return sum(e.count for e in t.edges.values())


@settings(max_examples=60, deadline=None)
@given(events, st.integers(0, 200))
def test_fold_is_split_invariant(evs, cut):
    """Folding a stream == merging folds of any split of it (the property
    that makes per-thread tables + offline merge exact)."""
    cut = min(cut, len(evs))
    whole = fold_event_log(evs)
    parts = fold_event_log(evs[:cut]).merge(fold_event_log(evs[cut:]))
    assert whole.edges.keys() == parts.edges.keys()
    for k in whole.edges:
        w, p = whole.edges[k], parts.edges[k]
        assert (w.count, w.total_ns, w.min_ns, w.max_ns) == \
            (p.count, p.total_ns, p.min_ns, p.max_ns)


@settings(max_examples=40, deadline=None)
@given(events, events, events)
def test_merge_associative_commutative(e1, e2, e3):
    a, b, c = map(fold_event_log, (e1, e2, e3))
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    flipped = c.merge(b).merge(a)
    for other in (right, flipped):
        assert left.edges.keys() == other.edges.keys()
        for k in left.edges:
            assert left.edges[k].total_ns == other.edges[k].total_ns
            assert left.edges[k].count == other.edges[k].count


@settings(max_examples=40, deadline=None)
@given(events)
def test_fold_conserves_totals(evs):
    folded = fold_event_log(evs)
    assert total_ns(folded) == sum(e[3] for e in evs)
    assert total_count(folded) == len(evs)


@settings(max_examples=40, deadline=None)
@given(events)
def test_relation_awareness(evs):
    """Same API from different callers must stay distinguishable (the
    paper's defining property vs naive aggregation)."""
    folded = fold_event_log(evs)
    for (caller, comp, api), e in folded.edges.items():
        expected = [d for c2, m2, a2, d in evs
                    if (c2, m2, a2) == (caller, comp, api)]
        assert e.count == len(expected)
        assert e.total_ns == sum(expected)


@settings(max_examples=30, deadline=None)
@given(events, st.integers(1, 64))
def test_parallel_attribution_scales_linearly(evs, lanes):
    folded = fold_event_log(evs)
    scaled = attribute_parallel(folded, lanes).folded
    for k in folded.edges:
        assert scaled.edges[k].total_ns == int(
            folded.edges[k].total_ns * (1.0 / lanes))


@settings(max_examples=30, deadline=None)
@given(events)
def test_views_conserve_api_time(evs):
    """API view percentages sum to ~100 and times to the component total."""
    folded = fold_event_log(evs)
    for comp in COMPONENTS:
        inbound = sum(e.total_ns for (c, m, a), e in folded.edges.items()
                      if m == comp)
        if inbound == 0:
            continue
        view = api_view(folded, comp)
        assert sum(r.time_ns for r in view.rows) == inbound
        assert abs(sum(r.pct for r in view.rows) - 100.0) < 1e-6


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(("a", "b")),
                          st.floats(0, 1e6)), max_size=50))
def test_device_fold_accumulates_exactly(emissions):
    """The in-graph shadow table is an exact sum, slot by slot."""
    spec = DeviceFoldSpec()
    spec.declare("app", "moe", "dispatch", "a")
    spec.declare("app", "moe", "dispatch", "b")
    spec.freeze()
    table = spec.init_table()
    want = {"a": 0.0, "b": 0.0}
    for metric, v in emissions:
        table = spec.emit(table, "app", "moe", "dispatch", metric, v)
        want[metric] += np.float32(v)
    folded = spec.fold(np.asarray(table))
    got = folded.edges[("app", "moe", "dispatch")].metrics
    for m in ("a", "b"):
        np.testing.assert_allclose(got.get(m, 0.0), want[m], rtol=1e-4,
                                   atol=1e-3)


# ------------------------------------------------- profile store algebra ----

from conftest import assert_tables_equal as _edges_equal  # noqa: E402


@settings(max_examples=40, deadline=None)
@given(events)
def test_snapshot_roundtrip_lossless(evs):
    """FoldedTable -> columnar snapshot file -> FoldedTable is the identity
    (the persistence half of the offline merge must lose nothing)."""
    import os
    import tempfile

    from repro.profile import ProfileSnapshot
    folded = fold_event_log(evs)
    for i, k in enumerate(folded.edges):
        if i % 3 == 0:
            folded.edges[k].metrics = {"flops": float(i), "b[0]": 0.0}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.xfa.npz")
        ProfileSnapshot.from_folded(folded).save(path)
        _edges_equal(ProfileSnapshot.load(path).to_folded(), folded)


@settings(max_examples=40, deadline=None)
@given(events, events, events)
def test_columnar_merge_matches_pairwise(e1, e2, e3):
    """The vectorized shard reduce is the SAME algebra as EdgeStats.merge:
    associative, commutative, and equal to the pairwise loop edge-for-edge."""
    from repro.core.folding import merge_columns
    tables = [fold_event_log(e) for e in (e1, e2, e3)]
    want = FoldedTable.merge_all(tables)
    cols = [t.to_columns() for t in tables]
    _edges_equal(merge_columns(cols).to_folded(), want)
    _edges_equal(merge_columns(cols[::-1]).to_folded(), want)
    nested = merge_columns([cols[0], merge_columns(cols[1:])])
    _edges_equal(nested.to_folded(), want)


METRIC_NAMES = ("flops", "bytes", "load[0]")


@st.composite
def edge_stats_st(draw):
    """EdgeStats covering the full field space, INCLUDING count == 0 edges
    (device/static-style: declared + metrics, never timed) and explicit
    0.0-valued metrics (presence != value)."""
    from repro.core.folding import EdgeStats
    from repro.core.shadow import KIND_CALL, KIND_WAIT

    count = draw(st.integers(0, 50))
    kind = draw(st.sampled_from((KIND_CALL, KIND_WAIT)))
    metrics = draw(st.dictionaries(
        st.sampled_from(METRIC_NAMES),
        st.floats(min_value=0.0, max_value=1e6, allow_nan=False), max_size=3))
    if count == 0:
        return EdgeStats(kind=kind, metrics=metrics)
    total = draw(st.integers(1, 10**6))
    return EdgeStats(count=count, total_ns=total,
                     child_ns=draw(st.integers(0, total)),
                     min_ns=draw(st.integers(1, total)),
                     max_ns=draw(st.integers(1, total)),
                     kind=kind, metrics=metrics)


folded_table_st = st.dictionaries(
    st.tuples(st.sampled_from(CALLERS), st.sampled_from(COMPONENTS),
              st.sampled_from(APIS)),
    edge_stats_st(), max_size=12).map(FoldedTable)


@settings(max_examples=60, deadline=None)
@given(st.lists(folded_table_st, min_size=1, max_size=5))
def test_columnar_merge_equals_pairwise_with_masks_and_kinds(tables):
    """merge_columns ≡ FoldedTable.merge_all on the FULL field space:

    * metric PRESENCE is preserved exactly — an edge that never emitted a
      metric stays absent after the columnar merge (mask semantics), and an
      explicit 0.0 metric stays present;
    * kind tie-breaking matches the pairwise oracle even when the first
      part(s) carrying an edge have count == 0: the pairwise merge keeps
      deferring to the next part until one actually observed the edge, and
      the columnar `decided` vector must do the same.
    """
    from repro.core.folding import merge_columns
    want = FoldedTable.merge_all(tables)
    got = merge_columns([t.to_columns() for t in tables]).to_folded()
    _edges_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(st.lists(folded_table_st, min_size=2, max_size=4),
       st.randoms(use_true_random=False))
def test_columnar_merge_order_insensitive_on_full_fields(tables, rnd):
    """Shuffling shard order never changes stats or metric masks.  (kind is
    deliberately excluded: the algebra defines it as "first part that
    observed the edge", which is order-dependent when parts disagree — in
    real shards they never do, since kind comes from the shared slot
    registry.)"""
    from repro.core.folding import merge_columns
    cols = [t.to_columns() for t in tables]
    base = merge_columns(cols).to_folded()
    shuffled = list(cols)
    rnd.shuffle(shuffled)
    got = merge_columns(shuffled).to_folded()
    assert got.edges.keys() == base.edges.keys()
    for k in base.edges:
        a, b = base.edges[k], got.edges[k]
        assert (a.count, a.total_ns, a.child_ns, a.min_ns, a.max_ns) == \
            (b.count, b.total_ns, b.child_ns, b.min_ns, b.max_ns), k
        # metric PRESENCE is exact; values only to float-sum reassociation
        assert a.metrics.keys() == b.metrics.keys(), k
        for m in a.metrics:
            assert a.metrics[m] == pytest.approx(b.metrics[m], rel=1e-12), \
                (k, m)


@settings(max_examples=30, deadline=None)
@given(events, st.integers(1, 4))
def test_shard_split_invariance(evs, n_shards):
    """Splitting one process's event stream across N process shards and
    reducing them reproduces the single-process profile exactly — the
    cross-process lift of the per-thread split invariant above."""
    from repro.profile import ProfileSnapshot
    chunks = [evs[i::n_shards] for i in range(n_shards)]
    shards = [ProfileSnapshot.from_folded(fold_event_log(c)) for c in chunks]
    merged = ProfileSnapshot.merge(shards).to_folded()
    _edges_equal(merged, fold_event_log(evs))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=16))
def test_device_fold_vector_slots(loads):
    spec = DeviceFoldSpec()
    spec.declare("app", "moe", "dispatch", "expert_load", width=4)
    spec.freeze()
    table = spec.init_table()
    acc = np.zeros(4)
    for e in loads:
        onehot = np.zeros(4)
        onehot[e] = 1
        table = spec.emit(table, "app", "moe", "dispatch", "expert_load",
                          jnp.asarray(onehot))
        acc += onehot
    folded = spec.fold(np.asarray(table))
    m = folded.edges[("app", "moe", "dispatch")].metrics
    for i in range(4):
        assert m[f"expert_load[{i}]"] == acc[i]
