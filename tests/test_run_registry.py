"""Profile store v2: run registry (manifests + query), snapshot rings,
retention/GC, and the timeline drift view — including one real trainer run
feeding ≥3 sequence-numbered snapshots."""

import json
import os
import time

import pytest

from conftest import assert_tables_equal
from repro.core.folding import fold_event_log
from repro.profile import (MANIFEST_NAME, ProfileSnapshot, ProfileStore,
                           RetentionPolicy, RunManifest, RunRegistry,
                           build_timelines, register_run, render_timeline,
                           split_snapshot_name)
from repro.profile.snapshot import SCHEMA_VERSION

EVENTS = [
    ("app", "glibc", "read", 18), ("app", "glibc", "write", 35),
    ("app", "alloc", "malloc", 10), ("moe", "pthread", "lock", 900),
]


def make_run(root, name, *, config, mesh=None, label="train", kind="train",
             n_snaps=1, meta=None, started_at=None):
    run = os.path.join(str(root), name)
    store = ProfileStore(run)
    for i in range(1, n_snaps + 1):
        store.write_shard(fold_event_log(EVENTS * i), label=label,
                          meta={"step": i})
    register_run(run, config=config, arch="dense", mesh_shape=mesh,
                 label=label, kind=kind, meta=meta, started_at=started_at)
    return run


# ------------------------------------------------------------- registry ----
class TestRunRegistry:
    def test_register_writes_structured_manifest(self, tmp_path):
        run = make_run(tmp_path, "r1", config="tinyllama_1_1b", mesh="4x2",
                       meta={"exp": "pr2"})
        m = RunManifest.load(run)
        assert m.config == "tinyllama_1_1b"
        assert m.arch == "dense"
        assert m.mesh_shape == (4, 2)
        assert m.label == "train"
        assert m.kind == "train"
        assert m.schema == SCHEMA_VERSION
        assert m.started_at > 0
        assert m.meta["exp"] == "pr2"
        assert len(m.writers) == 1
        # manifest is plain indented json — greppable without repro
        with open(os.path.join(run, MANIFEST_NAME)) as f:
            assert json.load(f)["config"] == "tinyllama_1_1b"

    def test_register_is_idempotent_and_multi_writer(self, tmp_path):
        run = make_run(tmp_path, "r1", config="c", started_at=100.0)
        register_run(run, label="train-r1", meta={"rank1": True},
                     started_at=200.0)
        register_run(run, label="train-r1", started_at=200.0)  # re-register
        m = RunManifest.load(run)
        assert m.started_at == 100.0          # earliest start wins
        assert m.config == "c"                # rank1 didn't blank it
        assert m.meta["rank1"] is True
        # same (label, host, pid) registered once; distinct labels add up
        assert len(m.writers) == 2

    def test_query_filters_config_mesh_label(self, tmp_path):
        make_run(tmp_path, "a", config="tinyllama_1_1b", mesh="4x2",
                 label="train-r0")
        make_run(tmp_path, "nested/b", config="qwen3_14b", mesh="4x2",
                 label="train-r0")
        make_run(tmp_path, "c", config="qwen3_14b", mesh=(8,),
                 label="serve-0", kind="serve")
        reg = RunRegistry(str(tmp_path))
        assert len(reg.runs()) == 3            # recursive discovery

        got = {m.run_id for m in reg.query(config="qwen3_14b")}
        assert got == {"b", "c"}
        got = {m.run_id for m in reg.query(mesh="4x2")}
        assert got == {"a", "b"}
        got = {m.run_id for m in reg.query(mesh=(4, 2),
                                           config="tinyllama*")}
        assert got == {"a"}                    # globs + tuple mesh spelling
        got = {m.run_id for m in reg.query(label="serve-*")}
        assert got == {"c"}
        got = {m.run_id for m in reg.query(kind="serve")}
        assert got == {"c"}
        assert reg.query(config="nope") == []

    def test_query_where_and_since(self, tmp_path):
        make_run(tmp_path, "old", config="c", started_at=1000.0,
                 meta={"exp": "x"})
        make_run(tmp_path, "new", config="c", started_at=2000.0,
                 meta={"exp": "y"})
        reg = RunRegistry(str(tmp_path))
        assert [m.run_id for m in reg.query(since=1500.0)] == ["new"]
        assert [m.run_id for m in reg.query(where={"exp": "x"})] == ["old"]
        # `where` also reaches top-level manifest fields
        assert len(reg.query(where={"arch": "dense"})) == 2

    def test_concurrent_registration_loses_no_writers(self, tmp_path):
        """N concurrent register_run calls (the per-rank race at run
        start, here as threads) must all land in the writers list — the
        manifest lock serializes the load-modify-save."""
        import threading

        run = str(tmp_path / "race")
        n = 16
        ths = [threading.Thread(
            target=register_run, args=(run,),
            kwargs={"config": "c", "label": f"train-r{i}",
                    "meta": {f"rank{i}": i}}) for i in range(n)]
        for t in ths:
            t.start()
        for t in ths:
            t.join(timeout=30)
        m = RunManifest.load(run)
        assert len(m.writers) == n
        assert {w["label"] for w in m.writers} == \
            {f"train-r{i}" for i in range(n)}
        assert all(m.meta[f"rank{i}"] == i for i in range(n))

    def test_readers_do_not_create_run_dirs(self, tmp_path):
        """A typo'd path through the read-only surfaces must not leave
        empty directories behind to pollute later registry scans."""
        from repro.profile import build_timelines
        ghost = str(tmp_path / "typo-run")
        store = ProfileStore(ghost)
        assert store.snapshot_paths() == []
        with pytest.raises(FileNotFoundError):
            store.reduce()
        assert build_timelines(ghost) == []
        assert not os.path.exists(ghost)

    def test_unreadable_manifest_is_skipped_with_warning(self, tmp_path):
        make_run(tmp_path, "ok", config="c")
        bad = tmp_path / "bad"
        bad.mkdir()
        (bad / MANIFEST_NAME).write_text("{not json")
        with pytest.warns(UserWarning, match="unreadable manifest"):
            runs = RunRegistry(str(tmp_path)).runs()
        assert [m.run_id for m in runs] == ["ok"]


# ------------------------------------------------------- snapshot rings ----
class TestSnapshotRing:
    def test_writes_are_sequence_numbered(self, tmp_path):
        store = ProfileStore(str(tmp_path))
        for i in range(1, 4):
            store.write_shard(fold_event_log(EVENTS * i), label="t")
        names = [os.path.basename(p) for p in store.snapshot_paths()]
        assert [split_snapshot_name(n)[1] for n in names] == [1, 2, 3]
        stems = {split_snapshot_name(n)[0] for n in names}
        assert len(stems) == 1                 # one shard, one ring
        # reduce/aggregation consume only the newest (cumulative fold)
        assert len(store) == 1
        assert_tables_equal(store.reduce().to_folded(),
                            fold_event_log(EVENTS * 3))
        metas = [ProfileSnapshot.load(p).meta for p in store.snapshot_paths()]
        assert [m["seq"] for m in metas] == [1, 2, 3]

    def test_legacy_unnumbered_shard_still_reduces(self, tmp_path):
        legacy = str(tmp_path / "train-h-1.xfa.npz")
        ProfileSnapshot.from_folded(fold_event_log(EVENTS),
                                    meta={"label": "train"}).save(legacy)
        store = ProfileStore(str(tmp_path))
        assert split_snapshot_name(legacy) == ("train-h-1", 0)
        assert len(store) == 1
        assert_tables_equal(store.reduce().to_folded(),
                            fold_event_log(EVENTS))

    def test_writer_enforces_keep_last(self, tmp_path):
        store = ProfileStore(str(tmp_path),
                             retention=RetentionPolicy(keep_last=2))
        for i in range(1, 6):
            store.write_shard(fold_event_log(EVENTS * i), label="t")
        seqs = [split_snapshot_name(p)[1] for p in store.snapshot_paths()]
        assert seqs == [4, 5]                  # ring bounded, newest kept
        assert_tables_equal(store.reduce().to_folded(),
                            fold_event_log(EVENTS * 5))


# ------------------------------------------------------------ retention ----
class TestRetention:
    def _ring(self, root, stem, n, size=1):
        """n snapshots for `stem` with strictly increasing mtimes."""
        paths = []
        t = fold_event_log(EVENTS * size)
        now = time.time()
        for i in range(1, n + 1):
            p = os.path.join(str(root), f"{stem}.{i:06d}.xfa.npz")
            ProfileSnapshot.from_folded(t, meta={"label": stem}).save(p)
            # age the older entries without sleeping
            os.utime(p, (now - (n - i) * 100, now - (n - i) * 100))
            paths.append(p)
        return paths

    def test_max_age_spares_newest(self, tmp_path):
        paths = self._ring(tmp_path, "a", 4)
        policy = RetentionPolicy(keep_last=0, max_age_s=150)
        victims = policy.enforce(str(tmp_path))
        # entries older than 150s die; the newest survives regardless
        assert set(victims) == set(paths[:2])
        assert os.path.exists(paths[-1])

    def test_max_age_never_deletes_sole_snapshot(self, tmp_path):
        [p] = self._ring(tmp_path, "a", 1)
        os.utime(p, (1, 1))                    # ancient
        assert RetentionPolicy(keep_last=1, max_age_s=1).enforce(
            str(tmp_path)) == []
        assert os.path.exists(p)

    def test_max_bytes_evicts_oldest_across_shards(self, tmp_path):
        a = self._ring(tmp_path, "a", 3)
        b = self._ring(tmp_path, "b", 3)
        total = sum(os.path.getsize(p) for p in a + b)
        one = os.path.getsize(a[0])
        policy = RetentionPolicy(keep_last=0, max_bytes=total - one)
        victims = policy.enforce(str(tmp_path))
        assert len(victims) >= 1
        assert a[-1] not in victims and b[-1] not in victims
        left = sum(os.path.getsize(p) for p in a + b if os.path.exists(p))
        assert left <= total - one

    def test_max_bytes_one_byte_budget_keeps_newest_per_shard(self, tmp_path):
        a = self._ring(tmp_path, "a", 3)
        b = self._ring(tmp_path, "b", 2)
        RetentionPolicy(keep_last=0, max_bytes=1).enforce(str(tmp_path))
        alive = sorted(os.path.basename(p) for p in a + b
                       if os.path.exists(p))
        # over budget, but the newest of each LIVE shard is untouchable
        assert alive == ["a.000003.xfa.npz", "b.000002.xfa.npz"]

    def test_dry_run_deletes_nothing(self, tmp_path):
        paths = self._ring(tmp_path, "a", 4)
        victims = RetentionPolicy(keep_last=1).enforce(str(tmp_path),
                                                       dry_run=True)
        assert len(victims) == 3
        assert all(os.path.exists(p) for p in paths)

    def test_unbounded_policy_is_a_noop(self, tmp_path):
        self._ring(tmp_path, "a", 4)
        policy = RetentionPolicy(keep_last=0, max_age_s=0, max_bytes=0)
        assert policy.unbounded
        assert policy.enforce(str(tmp_path)) == []


# -------------------------------------------------------------- timeline ----
class TestTimeline:
    def test_trainer_run_produces_timeline(self, tmp_path):
        """Acceptance path: one real trainer run with per-step snapshots
        yields >= 3 sequence-numbered ring entries whose per-edge deltas
        show exactly one dispatch per interval."""
        import dataclasses

        import jax

        from repro.ckpt.manager import CheckpointManager
        from repro.configs import get_smoke
        from repro.configs.base import TrainConfig
        from repro.data.pipeline import SyntheticLMData
        from repro.models import build_model
        from repro.runtime.trainer import Trainer

        cfg = dataclasses.replace(get_smoke("tinyllama_1_1b"),
                                  n_layers=2, d_model=64, d_ff=128,
                                  vocab=512, n_heads=2, n_kv_heads=2,
                                  head_dim=32)
        model = build_model(cfg, impl="ref")
        run_dir = str(tmp_path / "run")
        trainer = Trainer(model, TrainConfig(ckpt_interval=0),
                          CheckpointManager(str(tmp_path / "ckpt")),
                          profile_dir=run_dir, profile_interval=1,
                          profile_meta={"exp": "timeline-test"})
        trainer.run(jax.random.key(0), SyntheticLMData(cfg, 2, 32),
                    n_steps=3, resume=False)

        # the run registered itself with structured metadata
        m = RunManifest.load(run_dir)
        assert m.config == cfg.name and m.kind == "train"
        assert m.jax_version == jax.__version__
        assert m.meta["exp"] == "timeline-test"
        assert RunRegistry(str(tmp_path)).query(config=cfg.name)

        [tl] = build_timelines(run_dir)
        assert len(tl) >= 3                    # >= 3 ring entries, in order
        assert tl.seqs == sorted(tl.seqs)
        key = ("app", "runtime", "dispatch_step")
        # per-interval deltas: exactly one dispatch per profiled step,
        # regardless of whatever the process-global tracer saw before
        deltas = tl.deltas(key, "count")[1:]
        assert deltas[:2] == [1.0, 1.0]
        assert sum(deltas) >= 2.0
        # the process-global tracer may carry hotter edges from earlier
        # tests in this process — filter instead of relying on top-N rank
        out = render_timeline(tl, fld="count", edge="dispatch_step")
        assert "dispatch_step" in out and f"{len(tl)} snapshots" in out

    def test_serving_engine_registers_and_rings(self, tmp_path):
        """The serving replica registers under kind=serve and its periodic
        shard refreshes honor the ServeConfig retention knobs."""
        import dataclasses

        import jax
        import numpy as np

        from repro.configs import get_smoke
        from repro.configs.base import ServeConfig
        from repro.models import build_model
        from repro.serving.engine import ServingEngine

        cfg = dataclasses.replace(get_smoke("tinyllama_1_1b"),
                                  n_layers=2, d_model=64, d_ff=128,
                                  vocab=512, n_heads=2, n_kv_heads=2,
                                  head_dim=32)
        model = build_model(cfg, impl="ref")
        run_dir = str(tmp_path / "serve-run")
        engine = ServingEngine(
            model, model.init(jax.random.key(0)),
            ServeConfig(max_batch=2, max_seq_len=64,
                        profile_dir=run_dir, profile_label="serve-0",
                        profile_keep_last=2,
                        profile_meta=(("fleet", "test"),)))
        m = RunManifest.load(run_dir)
        assert m.kind == "serve" and m.label == "serve-0"
        assert m.config == cfg.name and m.meta["fleet"] == "test"
        assert m.meta["max_batch"] == 2
        for _ in range(4):
            engine.write_profile_shard()
        store = ProfileStore(run_dir)
        assert len(store.snapshot_paths()) == 2   # keep_last honored
        assert len(store) == 1
        rng = np.random.default_rng(0)
        engine.submit(rng.integers(0, cfg.vocab, 5), 2)
        engine.run_until_drained()
        newest = store.reduce()
        assert newest.meta["label"] == "serve-0"
        assert newest.meta["completed"] == 1

    def test_timeline_deltas_and_series(self, tmp_path):
        store = ProfileStore(str(tmp_path))
        for i in (1, 2, 4):                    # cumulative folds
            store.write_shard(fold_event_log(EVENTS * i), label="t",
                              meta={"step": i})
        [tl] = build_timelines(str(tmp_path))
        key = ("app", "glibc", "read")
        assert tl.series(key, "count") == [1.0, 2.0, 4.0]
        assert tl.deltas(key, "count") == [1.0, 1.0, 2.0]
        assert tl.series(key, "total_ns") == [18.0, 36.0, 72.0]
        assert tl.steps() == [1, 2, 4]
        j = tl.to_json("count")
        assert j["edges"]["app -> glibc.read"]["deltas"] == [1.0, 1.0, 2.0]

    def test_timeline_mean_ns_is_per_interval_mean(self, tmp_path):
        """mean_ns is not cumulative: each interval shows its TRUE mean
        (delta total / delta count), so a speedup renders as a smaller
        mean, not as a bogus negative 'restart' delta."""
        store = ProfileStore(str(tmp_path))
        # interval 1: one 100ns call; interval 2: one MORE call at 10ns
        t1 = fold_event_log([("app", "glibc", "read", 100)])
        t2 = fold_event_log([("app", "glibc", "read", 100),
                             ("app", "glibc", "read", 10)])
        store.write_shard(t1, label="t")
        store.write_shard(t2, label="t")
        [tl] = build_timelines(str(tmp_path))
        key = ("app", "glibc", "read")
        assert tl.deltas(key, "mean_ns") == [100.0, 10.0]
        out = render_timeline(tl, fld="mean_ns")
        assert "per-interval means" in out
        assert "!" not in out                  # faster != restarted

    def test_timeline_marks_writer_restart(self, tmp_path):
        store = ProfileStore(str(tmp_path))
        store.write_shard(fold_event_log(EVENTS * 3), label="t")
        store.write_shard(fold_event_log(EVENTS), label="t")  # restarted
        [tl] = build_timelines(str(tmp_path))
        assert tl.deltas(("app", "glibc", "read"), "count") == [3.0, -2.0]
        assert "!" in render_timeline(tl, fld="count")

    def test_shard_filter_and_min_len(self, tmp_path):
        store = ProfileStore(str(tmp_path))
        store.write_shard(fold_event_log(EVENTS), label="aa")
        store.write_shard(fold_event_log(EVENTS), label="bb")
        store.write_shard(fold_event_log(EVENTS * 2), label="bb")
        assert [t.stem for t in build_timelines(str(tmp_path), shard="aa")
                ] == [store.shard_stem("aa")]
        assert [t.stem for t in build_timelines(str(tmp_path), min_len=2)
                ] == [store.shard_stem("bb")]


class TestTimelineDiff:
    """timeline --diff: two runs of the same config, rings aligned by
    sequence index, per-edge delta-of-deltas (ROADMAP open item)."""

    def _two_runs(self, tmp_path, mults_a=(1, 2, 4), mults_b=(1, 3, 6)):
        from repro.profile import build_timelines
        for name, mults in (("a", mults_a), ("b", mults_b)):
            store = ProfileStore(str(tmp_path / name))
            for i in mults:
                store.write_shard(fold_event_log(EVENTS * i), label="t",
                                  meta={"step": i})
        return (build_timelines(str(tmp_path / "a")),
                build_timelines(str(tmp_path / "b")))

    def test_delta_of_deltas(self, tmp_path):
        from repro.profile import pair_timelines, render_timeline_diff
        tls_a, tls_b = self._two_runs(tmp_path)
        [td] = pair_timelines(tls_a, tls_b)
        key = ("app", "glibc", "read")
        # A deltas: 1,1,2 ; B deltas: 1,2,3 -> B-minus-A: 0,1,1
        assert td.delta_of_deltas(key, "count") == [0.0, 1.0, 1.0]
        out = render_timeline_diff(td, fld="count")
        assert "timeline diff" in out and "B-minus-A" in out
        j = td.to_json("count")
        assert j["aligned"] == 3
        assert j["edges"]["app -> glibc.read"]["delta_of_deltas"] \
            == [0.0, 1.0, 1.0]

    def test_unequal_rings_align_on_prefix(self, tmp_path):
        from repro.profile import pair_timelines, render_timeline_diff
        tls_a, tls_b = self._two_runs(tmp_path, mults_a=(1, 2, 4, 8),
                                      mults_b=(2, 2))
        [td] = pair_timelines(tls_a, tls_b)
        assert len(td) == 2
        key = ("app", "glibc", "read")
        # A deltas: 1,1 ; B deltas: 2,0 -> 1,-1
        assert td.delta_of_deltas(key, "count") == [1.0, -1.0]
        assert "ring lengths differ" in render_timeline_diff(td, fld="count")

    def test_retention_trimmed_ring_aligns_by_seq(self, tmp_path):
        """A ring trimmed by keep-last retention must diff against the
        other run's SAME seq numbers — position alignment would pair its
        first entry (a cumulative fold) with the other run's first
        single-interval delta and report a huge phantom drift."""
        from repro.profile import RetentionPolicy, pair_timelines
        a = ProfileStore(str(tmp_path / "a"),
                         retention=RetentionPolicy(keep_last=2))
        b = ProfileStore(str(tmp_path / "b"))
        for i in (1, 2, 4, 8):
            a.write_shard(fold_event_log(EVENTS * i), label="t")
            b.write_shard(fold_event_log(EVENTS * i), label="t")
        from repro.profile import build_timelines
        [td] = pair_timelines(build_timelines(str(tmp_path / "a")),
                              build_timelines(str(tmp_path / "b")))
        # A keeps seqs {3, 4}; common interval is 3 -> 4 only
        assert td.columns() == [(3, 4)]
        # identical runs: zero drift (positional pairing would say -3)
        assert td.delta_of_deltas(("app", "glibc", "read"), "count") == [0.0]

    def test_cli_timeline_diff(self, tmp_path):
        from repro.profile.__main__ import main
        self._two_runs(tmp_path)
        rc = main(["timeline", str(tmp_path / "a"),
                   "--diff", str(tmp_path / "b"), "--field", "count"])
        assert rc == 0
        rc = main(["timeline", str(tmp_path / "a"),
                   "--diff", str(tmp_path / "b"), "--json"])
        assert rc == 0