"""internvl2-1b — InternViT frontend (STUB: input_specs supplies precomputed
patch embeddings) + qwen2-0.5b-class LM backbone [arXiv:2404.16821]."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, head_dim=64,
    n_patches=256, frontend_dim=1024, src_frontend="vit_patches",
    prefer_dp_only=True,
).validate()


def smoke():
    return reduced(CONFIG, n_heads=2, n_kv_heads=2, head_dim=32)
