"""Paper §4.3.2 analogue: offline analysis speed.

Scaler's visualizer runs in 0.43s vs perf's 33.3s (76x) because the online
fold already did the aggregation. We generate views from (a) folded tables
and (b) an equivalent append-style event log, and report the ratio."""

from __future__ import annotations

import time

import numpy as np

from repro.core.folding import FoldedTable, fold_event_log
from repro.core.views import api_view, component_view, flow_matrix


def run(n_events: int = 500_000):
    rng = np.random.default_rng(0)
    callers = np.array(["app", "moe", "optimizer", "serve"])
    comps = np.array(["glibc", "alloc", "collective", "data"])
    apis = np.array([f"api{i}" for i in range(32)])
    ev = list(zip(callers[rng.integers(0, 4, n_events)],
                  comps[rng.integers(0, 4, n_events)],
                  apis[rng.integers(0, 32, n_events)],
                  rng.integers(100, 10_000, n_events)))

    # online fold happens during recording; at analysis time it's free
    folded = fold_event_log(ev)

    t0 = time.perf_counter_ns()
    for comp in comps:
        component_view(folded, comp)
        api_view(folded, comp)
    flow_matrix(folded)
    t_fold = (time.perf_counter_ns() - t0) / 1e9

    # perf model: aggregation deferred to analysis time
    t0 = time.perf_counter_ns()
    folded2 = fold_event_log(ev)
    for comp in comps:
        component_view(folded2, comp)
        api_view(folded2, comp)
    flow_matrix(folded2)
    t_log = (time.perf_counter_ns() - t0) / 1e9

    return [
        ("offline.views_from_fold_s", t_fold, "paper Scaler: 0.43s"),
        ("offline.views_from_log_s", t_log, "paper perf: 33.3s"),
        ("offline.speedup_x", t_log / max(t_fold, 1e-9), "paper: 76x"),
    ]


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.3f},{note}")
