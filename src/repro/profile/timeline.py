"""Timeline — per-edge trajectories across a shard's snapshot ring.

A shard's ring entries are cumulative folds taken at increasing sequence
numbers, so differencing consecutive snapshots yields the per-interval
activity of every edge: count/total_ns/self_ns between step K and step
K+N.  Rendering those deltas side by side is the in-run drift detector —
an edge whose per-interval time creeps up (garbage accumulation, a cache
filling, a slot pool fragmenting) is flat in any single snapshot and
obvious on the timeline.

TimelineDiff extends the same idea ACROSS runs: two rings of the same
config align by ring index and render per-edge delta-of-deltas (how the
per-interval activity changed between run A and run B, interval by
interval) — `python -m repro.profile timeline RUN_A --diff RUN_B`.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..core.folding import FoldedTable
from ..core.histogram import jitter_ns as _hist_jitter, percentile_ns
from ..core.shadow import SlotKey, edge_label as _edge_key_str
from .snapshot import ProfileSnapshot
from .store import ProfileStore

#: fields a timeline can plot; self_ns/mean_ns derive per snapshot, and
#: the percentile/jitter fields need schema-v2 histograms (0.0 where a
#: snapshot has none for the edge).
TIMELINE_FIELDS = ("count", "total_ns", "self_ns", "mean_ns",
                   "p50_ns", "p95_ns", "p99_ns", "jitter_ns")

#: histogram-derived fields: per-interval values come from DIFFERENCED
#: cumulative histograms (exact — bucket counts are cumulative sums),
#: not from differencing the percentile series (meaningless).
_PCT_FIELDS = ("p50_ns", "p95_ns", "p99_ns", "jitter_ns")


def _pct_of(hist: Optional[np.ndarray], fld: str) -> float:
    if fld == "jitter_ns":
        return _hist_jitter(hist)
    return percentile_ns(hist, {"p50_ns": 0.50, "p95_ns": 0.95,
                                "p99_ns": 0.99}[fld])


@dataclass
class ShardTimeline:
    """One shard's ring, loaded: aligned (seq, meta, folded) triples."""

    stem: str
    seqs: List[int]
    metas: List[Dict[str, Any]]
    tables: List[FoldedTable]

    def __len__(self) -> int:
        return len(self.seqs)

    def edges(self) -> List[SlotKey]:
        keys = set()
        for t in self.tables:
            keys.update(t.edges)
        return sorted(keys)

    def series(self, key: SlotKey, fld: str = "total_ns") -> List[float]:
        """Cumulative value of `fld` at each snapshot (0 while absent)."""
        out = []
        for t in self.tables:
            e = t.edges.get(key)
            out.append(float(getattr(e, fld)) if e is not None else 0.0)
        return out

    def deltas(self, key: SlotKey, fld: str = "total_ns") -> List[float]:
        """Per-interval activity: first snapshot's value, then successive
        differences of the cumulative series.  A negative delta means the
        writer restarted (a new cumulative fold began) — rendered with a
        '!' marker.

        `mean_ns` is not cumulative, so differencing it would alias any
        ordinary speedup into a fake restart; instead each interval gets
        its TRUE mean, delta(total_ns) / delta(count) (0 for an idle
        interval, negative only on an actual counter regression).

        The percentile/jitter fields difference the cumulative HISTOGRAMS
        and read the quantile off each interval's exact distribution
        (bucket counts are cumulative, so the subtraction is loss-free);
        -1.0 marks a bucket-count regression (writer restart)."""
        if fld in _PCT_FIELDS:
            hists = self._hist_series(key)
            out = [_pct_of(hists[0], fld)]
            for i in range(1, len(hists)):
                prev, cur = hists[i - 1], hists[i]
                if cur is None:
                    out.append(0.0)
                elif prev is None:
                    out.append(_pct_of(cur, fld))
                else:
                    dh = cur.astype(np.int64) - prev.astype(np.int64)
                    out.append(-1.0 if (dh < 0).any() else _pct_of(dh, fld))
            return out
        if fld == "mean_ns":
            counts = self.series(key, "count")
            totals = self.series(key, "total_ns")
            out = [totals[0] / counts[0] if counts[0] else 0.0]
            for i in range(1, len(counts)):
                dc = counts[i] - counts[i - 1]
                dt = totals[i] - totals[i - 1]
                out.append(dt / dc if dc > 0 else (-1.0 if dc < 0 else 0.0))
            return out
        s = self.series(key, fld)
        return [s[0]] + [b - a for a, b in zip(s, s[1:])]

    def _hist_series(self, key: SlotKey) -> List[Optional[np.ndarray]]:
        """Each snapshot's cumulative histogram for `key` (None if absent)."""
        out: List[Optional[np.ndarray]] = []
        for t in self.tables:
            e = t.edges.get(key)
            out.append(e.hist if e is not None else None)
        return out

    def steps(self) -> List[Any]:
        """Per-snapshot progress marker from writer meta (step/ticks/seq)."""
        out = []
        for seq, meta in zip(self.seqs, self.metas):
            out.append(meta.get("step", meta.get("ticks", seq)))
        return out

    def kind_of(self, key: SlotKey) -> str:
        """'call' or 'wait' for `key` (from the newest table holding it)."""
        from ..core.shadow import KIND_NAMES
        for t in reversed(self.tables):
            e = t.edges.get(key)
            if e is not None:
                return KIND_NAMES[e.kind]
        return KIND_NAMES[0]

    def to_json(self, fld: str = "total_ns") -> dict:
        """Machine-readable ring: each edge carries its STRUCTURED key
        ([caller, component, api]) and kind alongside the rendered label,
        so calibration and external tooling consume rings without parsing
        'a -> b.c' strings back apart."""
        return {
            "stem": self.stem,
            "seqs": self.seqs,
            "steps": self.steps(),
            "field": fld,
            "edges": {
                _edge_key_str(k): {"key": list(k),
                                   "kind": self.kind_of(k),
                                   "series": self.series(k, fld),
                                   "deltas": self.deltas(k, fld)}
                for k in self.edges()
            },
        }


def build_timelines(root: str, shard: Optional[str] = None,
                    min_len: int = 1) -> List[ShardTimeline]:
    """Load every shard ring under run dir `root` (optionally filtered by a
    `shard` substring of the stem) with at least `min_len` snapshots."""
    store = ProfileStore(root)
    out = []
    for stem, ring in sorted(store.shards().items()):
        if shard is not None and shard not in stem:
            continue
        if len(ring) < min_len:
            continue
        seqs, metas, tables = [], [], []
        for seq, path in ring:
            snap = ProfileSnapshot.load(path)
            if "merged_from" in snap.meta:   # merge products are not shards
                continue
            seqs.append(seq)
            metas.append(snap.meta)
            tables.append(snap.to_folded())
        if len(seqs) >= min_len:
            out.append(ShardTimeline(stem, seqs, metas, tables))
    return out


@dataclass
class TimelineDiff:
    """Two shard rings (same config, two runs) aligned by SEQUENCE NUMBER.

    Both rings are written on the same cadence (profile_interval
    steps/ticks), so equal sequence numbers mark the same phase of each
    run.  Alignment uses the *intersection* of the two rings' seq sets:
    each aligned column is the interval between consecutive common seqs
    (plus a from-run-start column when both rings still hold seq 1), and
    each ring's per-interval value is differenced between exactly those
    two snapshots.  This stays correct when retention trimmed the rings
    differently — naive ring-position alignment would pair a trimmed
    ring's first entry (a CUMULATIVE fold of everything before it) with
    the other run's single-interval delta and rank the artifact as the
    top drift.  The payload is the per-edge delta-of-deltas: how much
    more (or less) per-interval count/time an edge spent in B than in A,
    interval by interval — the cross-run drift detector (run-level `diff`
    compares only cumulative totals and cannot see WHEN a regression
    develops)."""

    a: ShardTimeline
    b: ShardTimeline

    def columns(self) -> List[Tuple[Optional[int], int]]:
        """Aligned intervals as (prev_seq, seq); prev None = run start."""
        common = sorted(set(self.a.seqs) & set(self.b.seqs))
        cols: List[Tuple[Optional[int], int]] = []
        if common and common[0] == 1:    # both rings begin at the true start
            cols.append((None, 1))
        cols += list(zip(common[:-1], common[1:]))
        return cols

    def __len__(self) -> int:
        return len(self.columns())

    def edges(self) -> List[SlotKey]:
        return sorted(set(self.a.edges()) | set(self.b.edges()))

    def deltas(self, tl: ShardTimeline, key: SlotKey,
               fld: str = "total_ns") -> List[float]:
        """One ring's per-aligned-interval activity for `key` (one pass:
        the seq->index map and series are built once per call)."""
        cols = self.columns()
        idx = {s: i for i, s in enumerate(tl.seqs)}
        if fld in _PCT_FIELDS:           # interval quantile from hist diffs
            hists = tl._hist_series(key)
            out = []
            for prev, cur in cols:
                hc = hists[idx[cur]]
                hp = hists[idx[prev]] if prev is not None else None
                if hc is None:
                    out.append(0.0)
                elif hp is None:
                    out.append(_pct_of(hc, fld))
                else:
                    dh = hc.astype(np.int64) - hp.astype(np.int64)
                    out.append(-1.0 if (dh < 0).any() else _pct_of(dh, fld))
            return out
        if fld == "mean_ns":             # true per-interval mean (cf. deltas)
            tot = tl.series(key, "total_ns")
            cnt = tl.series(key, "count")
            out = []
            for prev, cur in cols:
                dt = tot[idx[cur]] - (tot[idx[prev]] if prev is not None
                                      else 0.0)
                dc = cnt[idx[cur]] - (cnt[idx[prev]] if prev is not None
                                      else 0.0)
                out.append(dt / dc if dc > 0 else (-1.0 if dc < 0 else 0.0))
            return out
        s = tl.series(key, fld)
        return [s[idx[cur]] - (s[idx[prev]] if prev is not None else 0.0)
                for prev, cur in cols]

    def delta_of_deltas(self, key: SlotKey, fld: str = "total_ns"
                        ) -> List[float]:
        """Per-aligned-interval activity of B minus A."""
        return [y - x for x, y in zip(self.deltas(self.a, key, fld),
                                      self.deltas(self.b, key, fld))]

    def to_json(self, fld: str = "total_ns") -> dict:
        cols = self.columns()
        edges = {}
        b_keys = set(self.b.edges())
        for k in self.edges():
            da = self.deltas(self.a, k, fld)
            db = self.deltas(self.b, k, fld)
            edges[_edge_key_str(k)] = {
                "key": list(k),
                "kind": (self.b if k in b_keys else self.a).kind_of(k),
                "deltas_a": da,
                "deltas_b": db,
                "delta_of_deltas": [y - x for x, y in zip(da, db)],
            }
        return {
            "a": {"stem": self.a.stem, "seqs": self.a.seqs},
            "b": {"stem": self.b.stem, "seqs": self.b.seqs},
            "aligned": len(cols),
            "columns": [[p, c] for p, c in cols],
            "field": fld,
            "edges": edges,
        }


def pair_timelines(a: List[ShardTimeline], b: List[ShardTimeline]
                   ) -> List[TimelineDiff]:
    """Pair two runs' shards for diffing: by stem-order (stems embed the
    label, so replicas labelled serve-0/serve-1 pair with their cross-run
    counterparts; host/pid parts differ across runs by construction)."""
    aa = sorted(a, key=lambda t: t.stem)
    bb = sorted(b, key=lambda t: t.stem)
    return [TimelineDiff(x, y) for x, y in zip(aa, bb)]


def render_timeline_diff(td: TimelineDiff, fld: str = "total_ns",
                         top: int = 12, edge: Optional[str] = None) -> str:
    """Tabular per-edge delta-of-deltas, largest absolute drift first.

    Cells are signed B-minus-A per-interval increments; a consistently
    positive row is an edge whose per-interval cost GREW between runs."""
    if fld not in TIMELINE_FIELDS:
        raise ValueError(f"unknown timeline field {fld!r}; "
                         f"choose from {TIMELINE_FIELDS}")
    cols = td.columns()
    if not cols:
        return (f"timeline diff {td.a.stem} -> {td.b.stem}: no common "
                f"sequence numbers (A holds {td.a.seqs}, B holds "
                f"{td.b.seqs}) — rings were retained past each other; "
                f"nothing comparable")
    n = len(cols)
    keys = td.edges()
    if edge:
        keys = [k for k in keys if edge in _edge_key_str(k)]
    dd = {k: td.delta_of_deltas(k, fld) for k in keys}   # computed once
    keys.sort(key=lambda k: -sum(abs(v) for v in dd[k]))
    shown = keys[:top]
    head = [f"timeline diff {td.a.stem} -> {td.b.stem}: {n} aligned "
            f"intervals, field={fld} (per-interval B-minus-A)"]
    marks = [f"s{0 if p is None else p}>s{c}" for p, c in cols]
    if len(td.a) != len(td.b):
        head.append(f"  (ring lengths differ: {len(td.a)} vs {len(td.b)} "
                    f"snapshots; only common seqs are compared)")
    width = max([len(m) for m in marks] + [10])
    label_w = max([len(_edge_key_str(k)) for k in shown] + [20])
    head.append("  ".join([" " * label_w] + [m.rjust(width) for m in marks]))
    for k in shown:
        cells = [f"{v:+.0f}".rjust(width) for v in dd[k]]
        head.append("  ".join([_edge_key_str(k).ljust(label_w)] + cells))
    if len(keys) > top:
        head.append(f"  ... ({len(keys) - top} more edges)")
    return "\n".join(head)


def render_timeline(tl: ShardTimeline, fld: str = "total_ns",
                    top: int = 12, edge: Optional[str] = None) -> str:
    """Tabular per-edge deltas across the ring, hottest edges first.

    First column is the value at the first snapshot, later columns the
    per-interval increments ('+N'); '!' marks a negative delta (writer
    restart).  `edge` filters rows by substring.
    """
    if fld not in TIMELINE_FIELDS:
        raise ValueError(f"unknown timeline field {fld!r}; "
                         f"choose from {TIMELINE_FIELDS}")
    keys = tl.edges()
    if edge:
        keys = [k for k in keys if edge in _edge_key_str(k)]
    keys.sort(key=lambda k: -tl.series(k, fld)[-1])
    shown = keys[:top]
    what = "per-interval means" if fld == "mean_ns" \
        else "per-interval deltas"
    head = [f"timeline {tl.stem}: {len(tl)} snapshots, field={fld} "
            f"(first value, then {what})"]
    marks = [f"seq{s}" + (f"@{st}" if st != s else "")
             for s, st in zip(tl.seqs, tl.steps())]
    width = max([len(m) for m in marks] + [10])
    label_w = max([len(_edge_key_str(k)) for k in shown] + [20])
    head.append("  ".join([" " * label_w] + [m.rjust(width) for m in marks]))
    for k in shown:
        d = tl.deltas(k, fld)
        cells = [f"{d[0]:.0f}".rjust(width)]
        for v in d[1:]:
            cell = f"{v:+.0f}" + ("!" if v < 0 else "")
            cells.append(cell.rjust(width))
        head.append("  ".join([_edge_key_str(k).ljust(label_w)] + cells))
    if len(keys) > top:
        head.append(f"  ... ({len(keys) - top} more edges)")
    return "\n".join(head)
